"""Launch entry points: meshes, dry-run lowering, roofline, serving, training."""
