"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

For every (arch × shape) cell on the single-pod mesh, derive the three
per-chip roofline terms from the compiled dry-run:

    compute    = HLO_FLOPs        / 197 TFLOP/s   (bf16 peak, v5e)
    memory     = HLO_bytes        / 819 GB/s      (HBM)
    collective = wire_bytes       / 50 GB/s       (ICI, ring-equivalent)

plus MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE for training; 2·N_active·D
for serving), the useful-compute ratio MODEL/HLO, the dominant term, the
roofline fraction (ideal useful-compute time / dominant-term time — the
number a perfect implementation would push to 1.0), and a one-line note on
what would move the dominant term.

    python -m repro.launch.roofline [--mesh 16x16] [--markdown]
"""
import argparse
import json
import pathlib
from typing import Dict, List

from repro.config import get_arch, get_shape
from repro.launch.mesh import (
    V5E_HBM_BANDWIDTH,
    V5E_ICI_LINK_BW,
    V5E_PEAK_BF16_FLOPS,
)
from repro.obs import get_logger

log = get_logger("launch.roofline")

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"
CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(arch: str, shape_name: str) -> float:
    """Useful FLOPs per step, GLOBAL (6·N·D train, 2·N·D serving)."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze_cell(rec: Dict) -> Dict:
    chips = CHIPS[rec["mesh"]]
    t_compute = rec["flops"] / V5E_PEAK_BF16_FLOPS
    t_memory = rec["hbm_bytes"] / V5E_HBM_BANDWIDTH
    t_coll = rec["collective_wire_bytes"] / V5E_ICI_LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf_global = model_flops(rec["arch"], rec["shape"])
    mf_dev = mf_global / chips
    useful_ratio = mf_dev / rec["flops"] if rec["flops"] else 0.0
    t_ideal = mf_dev / V5E_PEAK_BF16_FLOPS
    frac = t_ideal / max(terms.values()) if max(terms.values()) > 0 else 0.0

    notes = {
        "compute": "cut non-useful FLOPs (remat policy, triangular attention, MoE capacity)",
        "memory": "fuse/tile the dominant streams (Pallas flash/scan kernels keep them in VMEM)",
        "collective": "reshard to cut gathers (SP boundaries, bf16 grads, overlap with compute)",
    }
    return {
        **{k: rec[k] for k in ("arch", "shape", "mode", "mesh", "layout")},
        "microbatches": rec.get("microbatches", 1),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_global": mf_global,
        "useful_ratio": useful_ratio,
        "roofline_fraction": frac,
        "peak_gib": rec.get("peak_bytes_per_device", 0) / 2**30,
        "peak_adj_gib": rec.get("peak_tpu_adjusted", rec.get("peak_bytes_per_device", 0)) / 2**30,
        "note": notes[dominant],
    }


def load(mesh: str) -> List[Dict]:
    out = []
    for f in sorted(RESULTS_DIR.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec["mesh"] == mesh:
            out.append(analyze_cell(rec))
    return out


OPTIMIZED_LAYOUTS = ("tri_bigchunk", "tri_gather_bigchunk", "bigchunk", "triangular")


def compare(mesh: str) -> None:
    """Baseline vs best optimized layout per cell (§Perf summary)."""
    rows = load(mesh)
    by_cell: Dict = {}
    for r in rows:
        by_cell.setdefault((r["arch"], r["shape"]), {})[r["layout"]] = r
    hdr = (f"{'arch':22s} {'shape':12s} {'base_bound':>10s} {'base_roof':>9s} "
           f"{'opt_layout':>20s} {'opt_roof':>8s} {'gain':>6s}")
    print(hdr + "\n" + "-" * len(hdr))
    for (arch, shape), variants in sorted(by_cell.items()):
        base = variants.get("baseline") or variants.get("int8_cache")
        if base is None:
            continue
        opts = [variants[l] for l in OPTIMIZED_LAYOUTS if l in variants]
        if not opts:
            continue
        best = max(opts, key=lambda r: r["roofline_fraction"])
        gain = best["roofline_fraction"] / max(base["roofline_fraction"], 1e-9)
        print(
            f"{arch:22s} {shape:12s} {base['dominant']:>10s} "
            f"{base['roofline_fraction']:9.4f} {best['layout']:>20s} "
            f"{best['roofline_fraction']:8.4f} {gain:5.1f}x"
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16", choices=list(CHIPS))
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--compare", action="store_true")
    args = ap.parse_args()
    if args.compare:
        compare(args.mesh)
        return
    rows = load(args.mesh)
    if not rows:
        raise SystemExit(f"no dry-run results for mesh {args.mesh} under {RESULTS_DIR}")

    if args.markdown:
        print(
            "| arch | shape | layout | t_comp (s) | t_mem (s) | t_coll (s) "
            "| bound | useful/HLO | roofline | peak GiB (adj) |"
        )
        print("|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(
                f"| {r['arch']} | {r['shape']} | {r['layout']}"
                f"{'/mb' + str(r['microbatches']) if r['microbatches'] > 1 else ''} "
                f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} "
                f"| **{r['dominant'][:4]}** | {r['useful_ratio']:.2f} "
                f"| {r['roofline_fraction']:.3f} "
                f"| {r['peak_gib']:.1f} ({r['peak_adj_gib']:.1f}) |"
            )
    else:
        hdr = (
            f"{'arch':22s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
            f"{'t_coll':>9s} {'bound':>6s} {'use':>5s} {'roof':>6s} {'peak':>6s}"
        )
        print(hdr + "\n" + "-" * len(hdr))
        for r in rows:
            print(
                f"{r['arch']:22s} {r['shape']:12s} {r['t_compute_s']:9.3e} {r['t_memory_s']:9.3e} "
                f"{r['t_collective_s']:9.3e} {r['dominant'][:6]:>6s} {r['useful_ratio']:5.2f} "
                f"{r['roofline_fraction']:6.3f} {r['peak_adj_gib']:5.1f}G"
            )
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    coll = max(rows, key=lambda r: r["t_collective_s"] / max(r["t_compute_s"], 1e-12))
    log.info("worst roofline fraction",
             cell=f"{worst['arch']}:{worst['shape']}",
             fraction=worst["roofline_fraction"])
    log.info("most collective-bound", cell=f"{coll['arch']}:{coll['shape']}")


if __name__ == "__main__":
    main()
