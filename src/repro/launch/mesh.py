"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Sequence

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16×16 = 256 chips/pod; 2 pods = 512 chips.

    The ``pod`` axis is pure data parallelism (one gradient all-reduce per
    step crosses the DCN); ``data`` is within-pod DP/FSDP; ``model`` is
    tensor/expert parallelism over ICI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Arbitrary mesh (elastic re-meshing, tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist locally (smoke tests: 1 CPU)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
V5E_PEAK_BF16_FLOPS = 197e12     # 197 TFLOP/s bf16
V5E_HBM_BANDWIDTH = 819e9        # 819 GB/s
V5E_ICI_LINK_BW = 50e9           # ~50 GB/s per ICI link
V5E_HBM_BYTES = 16 * 1024**3     # 16 GiB HBM per chip
