import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production mesh with 512 placeholder host devices, and extract the roofline
inputs (FLOPs, HBM bytes, per-device memory, collective traffic) from the
compiled artifact. No arrays are ever allocated — inputs are
ShapeDtypeStructs.

Usage:
    python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--layout baseline]
    python -m repro.launch.dryrun --cell qwen3-4b:train_4k --layout seqpar

Results land in results/dryrun/<arch>__<shape>__<mesh>__<layout>.json and
feed EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import pathlib
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import (
    ShardingLayout,
    TrainConfig,
    get_arch,
    get_shape,
    runnable_cells,
)
from repro.dist import (
    batch_shardings,
    cache_shardings,
    make_activation_constrainer,
    opt_state_shardings,
    param_shardings,
)
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, input_specs
from repro.obs import get_logger

log = get_logger("launch.dryrun")
from repro.models.common import abstract_params
from repro.train.steps import (
    abstract_train_state,
    build_decode_step,
    build_prefill_step,
    build_train_step,
)

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


LAYOUTS: Dict[str, ShardingLayout] = {
    "baseline": ShardingLayout(),
    "triangular": ShardingLayout(name="triangular", attn_impl="triangular"),
    "seqpar": ShardingLayout(
        name="seqpar", sequence_shard_activations=True, attn_impl="triangular"
    ),
    "tp_only": ShardingLayout(name="tp_only", param_rules="tp_only"),
    "bf16_grads": ShardingLayout(
        name="bf16_grads", gradient_allreduce_dtype="bfloat16", attn_impl="triangular"
    ),
    "remat_dots": ShardingLayout(name="remat_dots", remat="dots", attn_impl="triangular"),
    "fsdp_heavy": ShardingLayout(name="fsdp_heavy", param_rules="fsdp_heavy"),
    "int8_cache": ShardingLayout(name="int8_cache", int8_kv_cache=True),
    "decode_unroll": ShardingLayout(name="decode_unroll", decode_unroll=True),
    "naive": ShardingLayout(
        name="naive", sequence_shard_activations=False, fused_ce=False
    ),
    # --- §Perf hillclimb variants ---
    "attn_gather": ShardingLayout(name="attn_gather", attn_gather_kv=True),
    "tri_gather": ShardingLayout(
        name="tri_gather", attn_impl="triangular", attn_gather_kv=True
    ),
    "tri_gather_bf16g": ShardingLayout(
        name="tri_gather_bf16g", attn_impl="triangular", attn_gather_kv=True,
        gradient_allreduce_dtype="bfloat16",
    ),
    "bigchunk": ShardingLayout(
        name="bigchunk", attn_impl="triangular", q_chunk=2048, kv_chunk=4096
    ),
    "tri_gather_bigchunk": ShardingLayout(
        name="tri_gather_bigchunk", attn_impl="triangular", attn_gather_kv=True,
        q_chunk=2048, kv_chunk=4096,
    ),
    "tri_bigchunk": ShardingLayout(
        name="tri_bigchunk", attn_impl="triangular", q_chunk=2048, kv_chunk=4096
    ),
    "tri_bigchunk_dots": ShardingLayout(
        name="tri_bigchunk_dots", attn_impl="triangular",
        q_chunk=2048, kv_chunk=4096, remat="dots",
    ),
    "moe_tp": ShardingLayout(name="moe_tp", param_rules="moe_tp"),
    "tri_zero1": ShardingLayout(
        name="tri_zero1", attn_impl="triangular",
        param_rules="tp_only", opt_rules="baseline",
    ),
    "tri_zero1_bigchunk": ShardingLayout(
        name="tri_zero1_bigchunk", attn_impl="triangular",
        param_rules="tp_only", opt_rules="baseline",
        q_chunk=2048, kv_chunk=4096,
    ),
}


def _tree_shardings_like(tree: Any, leaf_sharding) -> Any:
    return jax.tree_util.tree_map(lambda _: leaf_sharding, tree)


# Per-arch gradient-accumulation defaults for train_4k: big models need
# microbatching to fit the 16 GiB/chip activation budget at global batch 256
# over 16 data shards (production config, not a hack — every framework does
# this). 1 = no accumulation.
TRAIN_MICROBATCHES: Dict[str, int] = {
    "qwen1.5-32b": 2,
    "mixtral-8x7b": 2,
    "phi3.5-moe-42b-a6.6b": 4,
    "internvl2-26b": 4,
    "gemma-7b": 2,
}

# Per-cell production-config overrides applied when --layout baseline:
# qwen1.5-32b is MHA (40 KV heads) — its bf16 32k cache is 21.5 GiB/chip and
# cannot fit 16 GiB at the assigned batch; int8 KV cache is the config a
# real deployment would run.
CELL_LAYOUT_OVERRIDES: Dict[tuple, str] = {
    ("qwen1.5-32b", "decode_32k"): "int8_cache",
}


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    layout: ShardingLayout = ShardingLayout(),
    microbatches: int = 1,
):
    """Lower + compile one cell. Returns (compiled, lowered, meta)."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    constrain = make_activation_constrainer(mesh, layout, cfg)
    p_sh = param_shardings(model.specs, mesh, layout)
    inputs = input_specs(cfg, shape)
    in_sh = batch_shardings(inputs, mesh)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    with mesh:
        if shape.mode == "train":
            tc = TrainConfig(microbatches=microbatches)
            step = build_train_step(model, tc, layout, constrain)
            state = abstract_train_state(model)
            o_sh = opt_state_shardings(model.specs, mesh, layout)
            state_sh = type(state)(
                params=p_sh,
                opt=type(state.opt)(
                    m=o_sh, v=o_sh, count=repl
                ),
                step=repl,
            )
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, in_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state, inputs)
        elif shape.mode == "prefill":
            step = build_prefill_step(model, layout, shape.seq_len, constrain)
            params = abstract_params(model.specs)
            jitted = jax.jit(step, in_shardings=(p_sh, in_sh))
            lowered = jitted.lower(params, inputs)
        else:  # decode
            step = build_decode_step(model, layout, constrain)
            params = abstract_params(model.specs)
            c_specs = model.cache_specs(
                shape.global_batch, shape.seq_len, int8=layout.int8_kv_cache
            )
            cache = abstract_params(c_specs)
            c_sh = cache_shardings(c_specs, mesh, layout)
            tok_sh = batch_shardings(inputs, mesh)["tokens"]
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, tok_sh, repl),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                params, cache, inputs["tokens"], jax.ShapeDtypeStruct((), jnp.int32)
            )
        compiled = lowered.compile()
    meta = {
        "arch": arch,
        "shape": shape_name,
        "mode": shape.mode,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "layout": layout.name,
        "params": model.param_count(),
    }
    if shape.mode == "decode":
        # per-device bytes of the donated cache: on TPU the output cache
        # aliases the input (donation); the CPU backend ignores donation and
        # double-counts it — analyze() reports a TPU-adjusted peak.
        import numpy as _np

        from repro.models.common import ParamSpec as _PS

        total = 0
        flat_specs = jax.tree_util.tree_leaves(
            c_specs, is_leaf=lambda x: isinstance(x, _PS)
        )
        flat_sh = jax.tree_util.tree_leaves(c_sh)
        for s, sh in zip(flat_specs, flat_sh):
            local = sh.shard_shape(s.shape)
            total += int(_np.prod(local)) * jnp.dtype(s.dtype).itemsize
        meta["cache_bytes_per_device"] = total
    return compiled, lowered, meta


def analyze(compiled, meta: Dict[str, Any]) -> Dict[str, Any]:
    out = dict(meta)
    # raw XLA numbers (loop bodies counted ONCE — kept for reference only)
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per partition
        ca = ca[0] if ca else {}
    out["xla_flops_loop_once"] = float(ca.get("flops", 0.0))
    out["xla_bytes_loop_once"] = float(
        ca.get("bytes accessed", ca.get("bytes accessed0{}", 0.0))
    )
    mem = None
    try:
        mem = compiled.memory_analysis()
    except Exception:
        pass
    if mem is not None:
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            out[k] = int(getattr(mem, k, 0))
        out["peak_bytes_per_device"] = int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        )
    hlo = compiled.as_text()
    # trip-count-aware walker (per-device FLOPs / HBM bytes / collectives)
    walk = hlo_cost.analyze_hlo(hlo)
    out["flops"] = walk["flops"]
    out["hbm_bytes"] = walk["hbm_bytes"]
    out["collectives"] = {
        k.replace("coll_", ""): v for k, v in walk.items() if k.startswith("coll_")
    }
    out["collectives"]["count"] = int(walk["collective_count"])
    out["collective_wire_bytes"] = walk["collective_wire_bytes"]
    out["hlo_instructions"] = hlo.count("\n")

    # XLA-CPU measurement artifact: CPU float-normalization rewrites the
    # decode while-loop so the carried KV-cache stack is kept in f32 (TPU
    # has native bf16/int8 dots — no such copy exists there). Detect the
    # hoisted f32 stack(s) in the HLO and report a TPU-adjusted peak.
    if meta.get("mode") == "decode" and "peak_bytes_per_device" in out:
        import re as _re

        # (a) hoisted f32 copies of the bf16 cache stack (CPU float
        # normalization rewrites the while carry; TPU has native bf16 dots)
        artifact = 0
        seen = set()
        for m in _re.finditer(
            r"%([\w\.\-]+)\s*=\s*f32\[(\d+(?:,\d+){3,5})\]\S*\s+(?:convert|dynamic-update-slice)\(",
            hlo,
        ):
            name, dim_s = m.groups()
            dims = tuple(int(d) for d in dim_s.split(","))
            n = 1
            for d in dims:
                n *= d
            if n * 4 >= (1 << 30) and name not in seen:  # cache-stack sized
                seen.add(name)
                artifact += n * 4
        # one live f32 stack per (k, v), not every textual occurrence:
        artifact = min(artifact, 2 * 4 * max(
            (int(_np_prod(d)) for d in (tuple(int(x) for x in m2.split(","))
             for m2 in _re.findall(r"f32\[(\d+(?:,\d+){3,5})\]", hlo))), default=0,
        )) if artifact else 0
        # (b) donation is a no-op on CPU: the donated cache is double-counted
        donated = meta.get("cache_bytes_per_device", 0)
        out["cpu_f32_cache_artifact_bytes"] = int(artifact)
        out["cpu_no_donation_artifact_bytes"] = int(donated)
        out["peak_tpu_adjusted"] = int(
            out["peak_bytes_per_device"] - artifact - donated
        )
    return out


def _np_prod(t):
    n = 1
    for x in t:
        n *= x
    return n


def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    layout_name: str = "baseline",
    save: bool = True,
) -> Dict[str, Any]:
    if layout_name == "baseline":
        layout_name = CELL_LAYOUT_OVERRIDES.get((arch, shape_name), layout_name)
    layout = LAYOUTS[layout_name]
    t0 = time.time()
    mb = TRAIN_MICROBATCHES.get(arch, 1) if get_shape(shape_name).mode == "train" else 1
    compiled, lowered, meta = lower_cell(
        arch, shape_name, multi_pod=multi_pod, layout=layout, microbatches=mb
    )
    meta["microbatches"] = mb
    result = analyze(compiled, meta)
    result["compile_seconds"] = round(time.time() - t0, 1)
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        fname = f"{arch.replace('/', '_')}__{shape_name}__{result['mesh']}__{layout_name}.json"
        (RESULTS_DIR / fname).write_text(json.dumps(result, indent=2))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--cell", help="arch:shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--layout", default="baseline", choices=sorted(LAYOUTS))
    args = ap.parse_args()

    if args.cell:
        args.arch, args.shape = args.cell.split(":")

    cells = runnable_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}:{shape} mesh={'2x16x16' if mp else '16x16'} layout={args.layout}"
            try:
                r = run_cell(arch, shape, multi_pod=mp, layout_name=args.layout)
                log.info(f"OK {tag}",
                         flops=f"{r['flops']:.3e}",
                         hbm=f"{r['hbm_bytes']:.3e}",
                         coll=f"{r['collective_wire_bytes']:.3e}",
                         peak_gib=r.get("peak_bytes_per_device", 0) / 2**30,
                         compile_s=r["compile_seconds"])
            except Exception as e:  # noqa: BLE001 — report and continue the sweep
                failures.append((tag, repr(e)))
                log.warn(f"FAIL {tag}", error=repr(e))
                traceback.print_exc()
    if failures:
        log.warn("dry-run sweep had failures", count=len(failures))
        for t, e in failures:
            log.warn(f"failed cell {t}", error=e)
        raise SystemExit(1)
    log.info("all cells compiled", count=len(cells) * len(meshes))


if __name__ == "__main__":
    main()
