"""Serving launcher: batched prefill + greedy decode on the host mesh.

    python -m repro.launch.serve --arch <id> [--batch 4] [--prompt-len 64]
        [--new-tokens 16] [--int8-cache]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import ShardingLayout, get_arch, list_archs
from repro.models import build_model
from repro.train.steps import run_opts_from_layout


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--int8-cache", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    layout = ShardingLayout(int8_kv_cache=args.int8_cache)
    opts = run_opts_from_layout(layout)

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size, jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(jax.random.key(2), (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    if cfg.vision_tokens:
        batch["patches"] = jax.random.normal(jax.random.key(3), (B, cfg.vision_tokens, cfg.vision_width), jnp.bfloat16)

    total = S + args.new_tokens
    t0 = time.perf_counter()
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, total, opts))(params, batch)
    jax.block_until_ready(logits)
    print(f"prefill {S} tokens x{B}: {(time.perf_counter()-t0)*1e3:.0f} ms")

    decode = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos, opts))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t0 = time.perf_counter()
    toks = [tok]
    for i in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(S + i))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        toks.append(tok)
    jax.block_until_ready(tok)
    dt = (time.perf_counter() - t0) / max(args.new_tokens - 1, 1)
    print(f"decode: {dt*1e3:.1f} ms/token (int8_cache={args.int8_cache})")
    print("first row:", jnp.concatenate(toks, axis=1)[0].tolist())


if __name__ == "__main__":
    main()
