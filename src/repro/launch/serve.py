"""Serving launcher: batched prefill + greedy decode on the host mesh,
with the same sharded step construction train/dryrun use.

Params, KV cache, and input batch all get NamedShardings resolved from the
layout's rule tables (``param_shardings`` / ``cache_shardings`` /
``batch_shardings``), the activation constrainer is threaded through the
steps, and the decode cache is donated — on a 1-device host mesh this
degenerates to the unsharded path, on a multi-device pool it serves
sharded with zero code change.

    python -m repro.launch.serve --arch <id> [--batch 4] [--prompt-len 64]
        [--new-tokens 16] [--int8-cache] [--model-parallel 1]

``--plan`` mode (the serving-fleet subsystem, ``repro.serve``): serve on
an :class:`ElasticMeshManager` plan instead of the host mesh, so a
serving replica can migrate between instance shapes like training does.
``--plan 8,4 --revoke-after 3`` decodes 3 tokens on the 8-device plan,
then simulates a spot revocation: the params move to the 4-device plan as
a PARAMS-ONLY cross-mesh reshard (asserted strictly smaller than the
training path's restore — no optimizer state exists to move) and the KV
cache either rides along over the DCN (``--cache-policy migrate``) or is
dropped and re-prefilled from the tokens generated so far
(``--cache-policy drop``, the default). Decode then continues on the new
mesh. A ``PLAN_JSON`` line reports the byte accounting and the decoded
rows for the subprocess round-trip test. Without ``--plan`` the legacy
host-mesh path below runs unchanged (bit-exact with pre-plan serve.py).

    python -m repro.launch.serve --arch <id> --plan 8,4 --revoke-after 3
        [--cache-policy drop|migrate]
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ShardingLayout, get_arch, list_archs
from repro.dist import (
    batch_shardings,
    cache_shardings,
    make_activation_constrainer,
    param_shardings,
)
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.obs import get_logger
from repro.train.steps import build_decode_step, build_prefill_step

log = get_logger("launch.serve")


def _serve_batch(cfg, B, S):
    """The (seeded, deterministic) serving inputs both paths share."""
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size, jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16
        )
    if cfg.vision_tokens:
        batch["patches"] = jax.random.normal(
            jax.random.key(3), (B, cfg.vision_tokens, cfg.vision_width), jnp.bfloat16
        )
    return batch


def _serve_steps(model, cfg, layout, mesh, batch, total, int8):
    """Sharded prefill/decode jits for one mesh — identical construction to
    the legacy host-mesh path (same shardings, same donation, same
    constrainer), parameterized by the plan's mesh."""
    constrain = make_activation_constrainer(mesh, layout, cfg)
    p_sh = param_shardings(model.specs, mesh, layout)
    in_sh = batch_shardings(batch, mesh)
    c_specs = model.cache_specs(batch["tokens"].shape[0], total, int8=int8)
    c_sh = cache_shardings(c_specs, mesh, layout)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    prefill = jax.jit(
        build_prefill_step(model, layout, total, constrain),
        in_shardings=(p_sh, in_sh),
        out_shardings=(None, c_sh),
    )
    decode = jax.jit(
        build_decode_step(model, layout, constrain),
        in_shardings=(p_sh, c_sh, in_sh["tokens"], repl),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    )
    return p_sh, c_sh, in_sh, prefill, decode


def engine_plan_main(args) -> None:
    """Serve on ElasticMeshManager plans through the continuous-batching
    decode engine (paged KV pool). A revocation sheds every in-flight
    request from the dying engine and resumes it — committed tokens
    included — on a fresh engine over the replacement plan, with the same
    params-only byte accounting as the legacy path (the paged pool always
    follows drop-and-reprefill semantics: pages die with the instance)."""
    from repro.dist import ElasticMeshManager, reshard_tree
    from repro.dist.meshplan import ThroughputTracker
    from repro.models.layers import PAGE_SIZE
    from repro.serve.autoscale import drain_replica
    from repro.serve.engine import DecodeEngine, Request
    from repro.serve.migrate import (
        assert_params_only,
        replica_param_bytes_moved,
    )

    if args.cache_policy != "drop":
        raise SystemExit("--engine supports --cache-policy drop only "
                         "(pool pages die with the instance)")

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    layout = ShardingLayout(int8_kv_cache=args.int8_cache)
    man = ElasticMeshManager()
    counts = [int(x) for x in args.plan.split(",")]
    tracker = ThroughputTracker()

    B, S = args.batch, args.prompt_len
    total = S + args.new_tokens
    num_pages = B * (-(-total // PAGE_SIZE)) + 1
    prompts = np.asarray(_serve_batch(cfg, B, S)["tokens"])
    params_host = model.init(jax.random.key(0))

    plan = man.plan_for(counts[0])
    engine = DecodeEngine(
        model, layout, plan.mesh, lanes=B, num_pages=num_pages,
        max_context=total, tracker=tracker, tracker_key=plan.key,
    )
    params = jax.device_put(params_host, engine.param_sh)
    for b in range(B):
        engine.submit(Request(rid=b, prompt=prompts[b],
                              max_new_tokens=args.new_tokens))
    log.info("engine plan up", devices=plan.device_count,
             mesh=str(plan.mesh_shape), lanes=B, pages=num_pages)

    migrated = {"params_bytes": 0, "cache_bytes": 0, "train_path_bytes": 0,
                "migrated_at": None, "cache_policy": "drop"}
    revoke_after = args.revoke_after if len(counts) > 1 else 0
    i = 0
    while engine.in_flight:
        if revoke_after and i == revoke_after:
            # the revocation is the same move a scale-down makes: drain
            # the dying engine's streams onto the replacement replica
            dying = engine
            plan = man.plan_for(counts[1])
            engine = DecodeEngine(
                model, layout, plan.mesh, lanes=B, num_pages=num_pages,
                max_context=total, tracker=tracker, tracker_key=plan.key,
            )
            moved = replica_param_bytes_moved(params, engine.param_sh)
            params = reshard_tree(params, engine.param_sh)
            migrated["params_bytes"] = moved
            migrated["train_path_bytes"] = assert_params_only(moved, model)
            migrated["migrated_at"] = i
            n_drained = drain_replica(dying, engine)
            log.info("revoked: streams drained to replacement", step=i,
                     shed=n_drained, devices=plan.device_count,
                     mesh=str(plan.mesh_shape),
                     params_bytes=migrated["params_bytes"],
                     train_path_bytes=migrated["train_path_bytes"])
        engine.step(params)
        i += 1

    done = {c.rid: c.tokens for c in engine.completions}
    rows = np.asarray([done[b] for b in range(B)], np.int32)
    sps = {f"{k[1][0]}x{k[1][1]}": round(v, 3) for k, v in tracker.measured.items()}
    print("first row:", rows[0].tolist())
    print("PLAN_JSON " + json.dumps({
        "plans": counts,
        "engine": True,
        "tokens": rows.tolist(),
        "measured_steps_per_sec": sps,
        "engine_tokens_per_sec": round(engine.measured_tokens_per_sec, 3),
        **migrated,
    }))


def plan_main(args) -> None:
    """Serve on ElasticMeshManager plans with a live shape migration."""
    from repro.dist import ElasticMeshManager, reshard_tree
    from repro.dist.meshplan import (
        ThroughputTracker,
        live_shardings,
        reshard_bytes,
    )
    from repro.serve.migrate import (
        assert_params_only,
        replica_param_bytes_moved,
    )

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    layout = ShardingLayout(int8_kv_cache=args.int8_cache)
    man = ElasticMeshManager()
    counts = [int(x) for x in args.plan.split(",")]
    tracker = ThroughputTracker()

    B, S = args.batch, args.prompt_len
    total = S + args.new_tokens
    batch = _serve_batch(cfg, B, S)
    params_host = model.init(jax.random.key(0))

    plan = man.plan_for(counts[0])
    p_sh, c_sh, in_sh, prefill, decode = _serve_steps(
        model, cfg, layout, plan.mesh, batch, total, args.int8_cache
    )
    params = jax.device_put(params_host, p_sh)
    batch = jax.device_put(batch, in_sh)

    migrated = {"params_bytes": 0, "cache_bytes": 0, "train_path_bytes": 0,
                "migrated_at": None, "cache_policy": args.cache_policy}
    revoke_after = args.revoke_after if len(counts) > 1 else 0
    toks = []
    with plan.mesh:
        logits, cache = prefill(params, batch)
        jax.block_until_ready(logits)
        tok = jax.device_put(
            jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None],
            in_sh["tokens"],
        )
        toks.append(np.asarray(tok))
    log.info("plan up", devices=plan.device_count, mesh=str(plan.mesh_shape))

    i = 0
    while i < args.new_tokens - 1:
        if revoke_after and i == revoke_after:
            # --- spot revocation: live shape migration -----------------
            gen = np.concatenate(toks, axis=1)
            plan = man.plan_for(counts[1])
            p_sh, c_sh, in_sh, prefill, decode = _serve_steps(
                model, cfg, layout, plan.mesh, batch, total, args.int8_cache
            )
            moved = replica_param_bytes_moved(params, p_sh)
            params = reshard_tree(params, p_sh)
            migrated["params_bytes"] = moved
            migrated["train_path_bytes"] = assert_params_only(moved, model)
            migrated["migrated_at"] = i
            if args.cache_policy == "migrate":
                migrated["cache_bytes"] = reshard_bytes(
                    cache, live_shardings(cache), c_sh
                )
                cache = reshard_tree(cache, c_sh)
                batch = jax.device_put(batch, in_sh)
            else:
                # drop: the cache died with the instance; re-prefill the
                # prompt + every token already fed to the old cache (the
                # newest token rides the next decode call), billed as
                # recompute on the replacement
                batch = jax.device_put(batch, in_sh)
                refill = dict(batch)
                refill["tokens"] = jax.device_put(
                    jnp.asarray(
                        np.concatenate(
                            [np.asarray(batch["tokens"]), gen[:, :i]], axis=1
                        )
                    ),
                    in_sh["tokens"],
                )
                with plan.mesh:
                    _, cache = prefill(params, refill)
            tok = jax.device_put(tok, in_sh["tokens"])
            log.info("revoked: migrated to replacement plan", token=i,
                     devices=plan.device_count, mesh=str(plan.mesh_shape),
                     params_bytes=migrated["params_bytes"],
                     train_path_bytes=migrated["train_path_bytes"],
                     cache_policy=args.cache_policy)
        with plan.mesh:
            t0 = time.perf_counter()
            logits, cache = decode(params, cache, tok, jnp.int32(S + i))
            tok = jax.device_put(
                jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None],
                in_sh["tokens"],
            )
            jax.block_until_ready(tok)
            tracker.observe(plan.key, 1, time.perf_counter() - t0)
        toks.append(np.asarray(tok))
        i += 1

    rows = np.concatenate(toks, axis=1)
    sps = {f"{k[1][0]}x{k[1][1]}": round(v, 3) for k, v in tracker.measured.items()}
    print("first row:", rows[0].tolist())
    print("PLAN_JSON " + json.dumps({
        "plans": counts,
        "tokens": rows.tolist(),
        "measured_steps_per_sec": sps,
        **migrated,
    }))


def host_main(args) -> None:
    """The legacy host-mesh path: lock-step batched prefill + decode."""
    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    layout = ShardingLayout(int8_kv_cache=args.int8_cache)
    mesh = make_host_mesh(model_parallel=args.model_parallel)
    constrain = make_activation_constrainer(mesh, layout, cfg)

    p_sh = param_shardings(model.specs, mesh, layout)
    params = jax.device_put(model.init(jax.random.key(0)), p_sh)

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size, jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16
        )
    if cfg.vision_tokens:
        batch["patches"] = jax.random.normal(
            jax.random.key(3), (B, cfg.vision_tokens, cfg.vision_width), jnp.bfloat16
        )

    total = S + args.new_tokens
    in_sh = batch_shardings(batch, mesh)
    c_specs = model.cache_specs(B, total, int8=args.int8_cache)
    c_sh = cache_shardings(c_specs, mesh, layout)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    prefill = jax.jit(
        build_prefill_step(model, layout, total, constrain),
        in_shardings=(p_sh, in_sh),
        # commit the produced cache to the same shardings decode declares,
        # or the decode jit rejects the GSPMD-chosen layout on >1 device
        out_shardings=(None, c_sh),
    )
    decode = jax.jit(
        build_decode_step(model, layout, constrain),
        in_shardings=(p_sh, c_sh, in_sh["tokens"], repl),
        # the returned cache feeds the next decode call: pin it to the same
        # shardings or GSPMD drifts the layout and the next call rejects it
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    )

    with mesh:
        t0 = time.perf_counter()
        logits, cache = prefill(params, batch)
        jax.block_until_ready(logits)
        log.info("prefill done", tokens=S, batch=B,
                 ms=round((time.perf_counter() - t0) * 1e3),
                 mesh=str(dict(mesh.shape)))

        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        t0 = time.perf_counter()
        toks = [tok]
        for i in range(args.new_tokens - 1):
            logits, cache = decode(params, cache, tok, jnp.int32(S + i))
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            toks.append(tok)
        jax.block_until_ready(tok)
    dt = (time.perf_counter() - t0) / max(args.new_tokens - 1, 1)
    log.info("decode done", ms_per_token=dt * 1e3, int8_cache=args.int8_cache)
    print("first row:", jnp.concatenate(toks, axis=1)[0].tolist())


def _dispatch(args) -> None:
    if args.plan and args.engine:
        return engine_plan_main(args)
    if args.plan:
        return plan_main(args)
    if args.engine:
        raise SystemExit("--engine requires --plan")
    return host_main(args)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--int8-cache", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--plan", default="",
                    help="serve on ElasticMeshManager plans: comma-separated "
                         "device counts; the second entry is the migration "
                         "target (e.g. 8,4)")
    ap.add_argument("--revoke-after", type=int, default=0,
                    help="decode this many tokens, then revoke + migrate to "
                         "the second --plan entry")
    ap.add_argument("--cache-policy", choices=("drop", "migrate"),
                    default="drop",
                    help="on migration: drop the KV cache and re-prefill, "
                         "or reshard it over the DCN")
    ap.add_argument("--engine", action="store_true",
                    help="with --plan: serve through the continuous-batching "
                         "decode engine (paged KV pool) instead of the "
                         "lock-step dense-cache loop")
    ap.add_argument("--trace", default="",
                    help="record the structured event timeline to this JSONL "
                         "path (replay with python -m repro.obs.replay, "
                         "render with python -m repro.obs.export)")
    args = ap.parse_args()
    if args.trace:
        from repro.obs.export import write_jsonl
        from repro.obs.recorder import recording

        with recording() as rec:
            _dispatch(args)
        log.info("trace written", path=args.trace,
                 events=write_jsonl(args.trace, rec.events))
        return
    _dispatch(args)


if __name__ == "__main__":
    main()
