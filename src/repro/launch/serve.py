"""Serving launcher: batched prefill + greedy decode on the host mesh,
with the same sharded step construction train/dryrun use.

Params, KV cache, and input batch all get NamedShardings resolved from the
layout's rule tables (``param_shardings`` / ``cache_shardings`` /
``batch_shardings``), the activation constrainer is threaded through the
steps, and the decode cache is donated — on a 1-device host mesh this
degenerates to the unsharded path, on a multi-device pool it serves
sharded with zero code change.

    python -m repro.launch.serve --arch <id> [--batch 4] [--prompt-len 64]
        [--new-tokens 16] [--int8-cache] [--model-parallel 1]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import ShardingLayout, get_arch, list_archs
from repro.dist import (
    batch_shardings,
    cache_shardings,
    make_activation_constrainer,
    param_shardings,
)
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.train.steps import build_decode_step, build_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--int8-cache", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    layout = ShardingLayout(int8_kv_cache=args.int8_cache)
    mesh = make_host_mesh(model_parallel=args.model_parallel)
    constrain = make_activation_constrainer(mesh, layout, cfg)

    p_sh = param_shardings(model.specs, mesh, layout)
    params = jax.device_put(model.init(jax.random.key(0)), p_sh)

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size, jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(jax.random.key(2), (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    if cfg.vision_tokens:
        batch["patches"] = jax.random.normal(jax.random.key(3), (B, cfg.vision_tokens, cfg.vision_width), jnp.bfloat16)

    total = S + args.new_tokens
    in_sh = batch_shardings(batch, mesh)
    c_specs = model.cache_specs(B, total, int8=args.int8_cache)
    c_sh = cache_shardings(c_specs, mesh, layout)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    prefill = jax.jit(
        build_prefill_step(model, layout, total, constrain),
        in_shardings=(p_sh, in_sh),
        # commit the produced cache to the same shardings decode declares,
        # or the decode jit rejects the GSPMD-chosen layout on >1 device
        out_shardings=(None, c_sh),
    )
    decode = jax.jit(
        build_decode_step(model, layout, constrain),
        in_shardings=(p_sh, c_sh, in_sh["tokens"], repl),
        # the returned cache feeds the next decode call: pin it to the same
        # shardings or GSPMD drifts the layout and the next call rejects it
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    )

    with mesh:
        t0 = time.perf_counter()
        logits, cache = prefill(params, batch)
        jax.block_until_ready(logits)
        print(f"prefill {S} tokens x{B}: {(time.perf_counter()-t0)*1e3:.0f} ms "
              f"(mesh {dict(mesh.shape)})")

        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        t0 = time.perf_counter()
        toks = [tok]
        for i in range(args.new_tokens - 1):
            logits, cache = decode(params, cache, tok, jnp.int32(S + i))
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            toks.append(tok)
        jax.block_until_ready(tok)
    dt = (time.perf_counter() - t0) / max(args.new_tokens - 1, 1)
    print(f"decode: {dt*1e3:.1f} ms/token (int8_cache={args.int8_cache})")
    print("first row:", jnp.concatenate(toks, axis=1)[0].tolist())


if __name__ == "__main__":
    main()
