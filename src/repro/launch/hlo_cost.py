"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE — useless for
scan-over-layers programs (a 64-layer model reports 1/64th of its FLOPs).
This module walks the post-SPMD HLO text recursively:

* ``while``      — body cost × known_trip_count (from backend_config)
* ``fusion``     — FLOPs recurse into the fused computation; bytes are
                   counted at the fusion *boundary* (operands + output),
                   matching what actually moves through HBM
* ``call``/``conditional`` — recurse (conditional: max of branches)
* ``dot``        — 2 × prod(output dims) × prod(contracting dims)
* elementwise/reduce — 1 FLOP per output (transcendentals too: roofline
                   noise, dots dominate)
* collectives    — per-kind output bytes, × enclosing trip counts

All shapes in the partitioned module are per-device, so every number this
produces is per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = {
    "all-gather": "all-gather",
    "all-gather-start": "all-gather",
    "all-reduce": "all-reduce",
    "all-reduce-start": "all-reduce",
    "reduce-scatter": "reduce-scatter",
    "all-to-all": "all-to-all",
    "collective-permute": "collective-permute",
    "collective-permute-start": "collective-permute",
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "tanh", "sqrt", "rsqrt",
    "cbrt", "negate", "maximum", "minimum", "compare", "select", "and", "or",
    "xor", "not", "abs", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "clamp", "cosine", "sine", "tan", "atan2", "logistic",
    "erf", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "is-finite",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"((?:\([^()]*\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+([a-z][\w\-]*)\((.*)$"
)
_COMP_RE = re.compile(r"^\s*(ENTRY\s+)?%([\w\.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_text: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(shape_text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n
    return total


def _first_shape_dims(shape_text: str) -> List[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",") if d] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str  # operand list + attributes (raw tail of the line)

    def operands(self) -> List[str]:
        # operand refs appear as %name before the closing paren of the op
        depth, i, end = 1, 0, len(self.rest)
        while i < end and depth:
            if self.rest[i] == "(":
                depth += 1
            elif self.rest[i] == ")":
                depth -= 1
            i += 1
        arglist = self.rest[: i - 1] if depth == 0 else self.rest
        return re.findall(r"%([\w\.\-]+)", arglist)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_marker: Optional[str] = None
    for line in hlo.splitlines():
        mc = _COMP_RE.match(line)
        if mc and ("->" in line):
            cur = Computation(mc.group(2), [], {})
            comps[cur.name] = cur
            if mc.group(1):
                entry_marker = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, shape, opcode, rest = mi.groups()
        cur.instrs.append(Instr(name, shape, opcode, rest))
        cur.shapes[name] = shape
    if entry_marker:
        comps["__entry__"] = comps[entry_marker]
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in
            ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")}
    )
    collective_count: int = 0

    def scaled(self, k: float) -> "Cost":
        return Cost(
            self.flops * k,
            self.bytes * k,
            self.transcendentals * k,
            {n: v * k for n, v in self.collectives.items()},
            int(self.collective_count * k),
        )

    def add(self, o: "Cost") -> None:
        self.flops += o.flops
        self.bytes += o.bytes
        self.transcendentals += o.transcendentals
        for n, v in o.collectives.items():
            self.collectives[n] += v
        self.collective_count += o.collective_count


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    out_elems = _shape_elems(instr.shape)
    mcon = _CONTRACT_RE.search(instr.rest)
    contract = 1
    ops = instr.operands()
    if mcon and ops:
        lhs_dims = _first_shape_dims(shapes.get(ops[0], ""))
        for idx in (int(x) for x in mcon.group(1).split(",") if x):
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
    return 2.0 * out_elems * contract


def compute_cost(
    comps: Dict[str, Computation],
    comp_name: str,
    *,
    bytes_at_boundary: bool,
    _memo: Optional[Dict[Tuple[str, bool], Cost]] = None,
) -> Cost:
    if _memo is None:
        _memo = {}
    key = (comp_name, bytes_at_boundary)
    if key in _memo:
        return _memo[key]
    comp = comps[comp_name]
    total = Cost()
    for ins in comp.instrs:
        op = ins.opcode
        if op == "while":
            trip = 1
            mt = _TRIP_RE.search(ins.rest)
            if mt:
                trip = int(mt.group(1))
            mb = _BODY_RE.search(ins.rest)
            if mb:
                body = compute_cost(
                    comps, mb.group(1),
                    bytes_at_boundary=bytes_at_boundary, _memo=_memo,
                )
                total.add(body.scaled(trip))
        elif op == "fusion":
            mcall = _CALLS_RE.search(ins.rest)
            if mcall:
                inner = compute_cost(comps, mcall.group(1), bytes_at_boundary=False, _memo=_memo)
                total.flops += inner.flops
                total.transcendentals += inner.transcendentals
                for n, v in inner.collectives.items():
                    total.collectives[n] += v
            # bytes at the fusion boundary: operands + output
            total.bytes += _shape_bytes(ins.shape)
            for o in ins.operands():
                total.bytes += _shape_bytes(comp.shapes.get(o, ""))
        elif op in ("call", "async-start"):
            mcall = _CALLS_RE.search(ins.rest) or _TO_APPLY_RE.search(ins.rest)
            if mcall:
                total.add(
                    compute_cost(
                        comps, mcall.group(1),
                        bytes_at_boundary=bytes_at_boundary, _memo=_memo,
                    )
                )
        elif op == "conditional":
            mb = _BRANCHES_RE.search(ins.rest)
            if mb:
                branches = re.findall(r"%([\w\.\-]+)", mb.group(1))
                costs = [
                    compute_cost(comps, b, bytes_at_boundary=bytes_at_boundary, _memo=_memo)
                    for b in branches
                ]
                if costs:
                    worst = max(costs, key=lambda c: c.flops + c.bytes)
                    total.add(worst)
        elif op in ("dot", "convolution"):
            total.flops += _dot_flops(ins, comp.shapes)
            total.bytes += _shape_bytes(ins.shape)
            for o in ins.operands():
                total.bytes += _shape_bytes(comp.shapes.get(o, ""))
        elif op in COLLECTIVE_OPS:
            kind = COLLECTIVE_OPS[op]
            b = _shape_bytes(ins.shape)
            total.collectives[kind] += b
            total.collective_count += 1
            total.bytes += b
        elif op in _ELEMENTWISE:
            n = _shape_elems(ins.shape)
            total.flops += n
            if op in ("exponential", "log", "tanh", "logistic", "erf", "cosine",
                      "sine", "power", "sqrt", "rsqrt", "cbrt"):
                total.transcendentals += n
            if not bytes_at_boundary:
                pass  # inside a fusion: no HBM traffic
            else:
                total.bytes += _shape_bytes(ins.shape)
                for o in ins.operands():
                    total.bytes += _shape_bytes(comp.shapes.get(o, ""))
        elif op in ("reduce", "reduce-window"):
            ops_ = ins.operands()
            if ops_:
                total.flops += _shape_elems(comp.shapes.get(ops_[0], ""))
            if bytes_at_boundary:
                total.bytes += _shape_bytes(ins.shape)
                for o in ins.operands():
                    total.bytes += _shape_bytes(comp.shapes.get(o, ""))
        elif op in ("copy", "transpose", "reshape", "broadcast", "concatenate",
                    "slice", "dynamic-slice", "dynamic-update-slice", "gather",
                    "scatter", "pad", "reverse", "sort", "iota", "convert",
                    "bitcast-convert"):
            if bytes_at_boundary and op not in ("reshape", "bitcast-convert", "iota"):
                total.bytes += _shape_bytes(ins.shape)
                for o in ins.operands():
                    total.bytes += _shape_bytes(comp.shapes.get(o, ""))
    _memo[key] = total
    return total


def analyze_hlo(hlo_text: str) -> Dict[str, float]:
    comps = parse_module(hlo_text)
    if "__entry__" not in comps:
        raise ValueError("no ENTRY computation found")
    c = compute_cost(comps, "__entry__", bytes_at_boundary=True)
    wire = (
        2.0 * c.collectives["all-reduce"]
        + c.collectives["all-gather"]
        + c.collectives["reduce-scatter"]
        + c.collectives["all-to-all"]
        + c.collectives["collective-permute"]
    )
    return {
        "flops": c.flops,
        "hbm_bytes": c.bytes,
        "transcendentals": c.transcendentals,
        "collective_count": c.collective_count,
        "collective_wire_bytes": wire,
        **{f"coll_{k}": v for k, v in c.collectives.items()},
    }
