"""Training launcher.

    python -m repro.launch.train --arch <id> [--steps N] [--reduced]
        [--spot-mode siwoft|checkpoint|hybrid|none] [--layout baseline]

On real hardware this binds to the production mesh (jax.distributed over
pods); on this container it runs the reduced config on the host mesh. With
``--spot-mode`` the run goes through the P-SIWOFT orchestrator (the paper's
provisioning layer); with ``none`` it is a plain training loop.
"""
import argparse
import tempfile

import jax

from repro.ckpt import CheckpointManager
from repro.config import ShardingLayout, TrainConfig, get_arch, list_archs
from repro.core import generate_markets, split_history_future
from repro.core.orchestrator import SpotTrainingOrchestrator
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.obs import get_logger
from repro.train.loop import run_segment
from repro.train.steps import init_train_state

log = get_logger("launch.train")


def _run(args) -> None:
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = make_host_mesh()
    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    tc = TrainConfig(total_steps=args.steps, warmup_steps=min(20, args.steps // 10 + 1))
    log.info("launching", arch=cfg.name,
             params_m=model.param_count() / 1e6, mode=args.spot_mode)

    if args.spot_mode == "none":
        ckpt = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
        state = init_train_state(model, jax.random.key(args.seed))
        res = run_segment(
            model, state, ds, mesh, tc, ShardingLayout(),
            num_steps=args.steps, ckpt=ckpt, ckpt_every=50,
        )
        if ckpt:
            ckpt.close()
        log.info("training done",
                 loss_first=res.losses[0], loss_last=res.losses[-1],
                 mean_step_ms=sum(res.step_seconds) / len(res.step_seconds) * 1e3)
        return

    ms = generate_markets(seed=3, n_hours=24 * 90 + 24 * 30)
    hist, fut = split_history_future(ms, 24 * 90)
    with tempfile.TemporaryDirectory() as d:
        orch = SpotTrainingOrchestrator(
            model, ds, mesh, hist, fut, mode=args.spot_mode, tc=tc,
            segment_steps=max(args.steps // 5, 1), steps_per_trace_hour=200,
            ckpt_dir=args.ckpt_dir or d, ckpt_every=10, seed=args.seed,
        )
        rep = orch.run(args.steps)
    log.info("spot training done", useful=rep.useful_steps,
             wasted=rep.wasted_steps, revocations=rep.revocations,
             goodput=rep.goodput, cost_dollars=rep.cost_dollars,
             loss_first=rep.losses[0], loss_last=rep.losses[-1])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--spot-mode", default="none",
                    choices=["none", "siwoft", "checkpoint", "hybrid"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="",
                    help="record the structured event timeline to this JSONL "
                         "path (replay with python -m repro.obs.replay)")
    args = ap.parse_args()
    if args.trace:
        from repro.obs.export import write_jsonl
        from repro.obs.recorder import recording

        with recording() as rec:
            _run(args)
        log.info("trace written", path=args.trace,
                 events=write_jsonl(args.trace, rec.events))
        return
    _run(args)


if __name__ == "__main__":
    main()
