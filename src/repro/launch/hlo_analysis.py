"""Parse compiled HLO for roofline inputs.

``cost_analysis()`` gives HLO FLOPs and HBM bytes but NOT collective traffic;
we parse the post-SPMD (per-device) HLO text and sum the output operand
sizes of every collective op, bucketed by kind. Shapes in the partitioned
module are per-device, so the sums are per-chip bytes on the wire (for
all-reduce we count the ring-equivalent 2× payload explicitly in roofline).
"""
from __future__ import annotations

import re
from typing import Dict


COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# one shaped buffer like  f32[16,128]  or  bf16[4,8,128]  or  f32[]
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# an HLO instruction line:  %name = <shape-or-tuple> opcode(...)
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+("
    + "|".join(k.replace("-", "\\-") for k in COLLECTIVE_KINDS)
    + r")(-start|-done)?\("
)


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-kind output bytes of every collective in a (per-device) HLO."""
    out = {k: 0 for k in COLLECTIVE_KINDS}
    out["count"] = 0
    for m in _INSTR_RE.finditer(hlo_text):
        shape_text, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":  # avoid double counting async pairs
            continue
        out[kind] += _shape_bytes(shape_text)
        out["count"] += 1
    return out


def collective_wire_bytes(cbytes: Dict[str, int]) -> float:
    """Approximate per-chip wire traffic from per-kind output bytes.

    Ring algorithms: all-reduce moves ~2× the buffer over the slowest link;
    all-gather/reduce-scatter move ~1× the (full) buffer; all-to-all and
    collective-permute move their payload once.
    """
    return (
        2.0 * cbytes["all-reduce"]
        + cbytes["all-gather"]
        + cbytes["reduce-scatter"]
        + cbytes["all-to-all"]
        + cbytes["collective-permute"]
    )
