"""Straggler watchdog: EWMA step-time anomaly detector.

At pod scale a single slow host (thermal throttling, failing HBM, noisy
neighbor on the DCN) drags every synchronous step. The watchdog keeps an
exponential moving mean/variance of step latency and flags steps beyond
``mean + k·sigma`` (and a relative floor). On a real deployment the flag
feeds the coordinator (drop-to-quorum or re-slice); here it is fully unit-
tested logic plus a callback hook.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional


@dataclasses.dataclass
class StragglerWatchdog:
    alpha: float = 0.1          # EWMA weight for new observations
    k_sigma: float = 4.0        # flag threshold in sigmas
    rel_floor: float = 1.5      # and at least 1.5× the mean
    warmup: int = 5             # steps before flagging starts
    on_straggler: Optional[Callable[[int, float, float], None]] = None

    _mean: float = 0.0
    _var: float = 0.0
    _n: int = 0
    flagged: List[int] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Record one step latency; returns True when the step is a straggler."""
        self._n += 1
        if self._n == 1:
            self._mean = dt
            self._var = 0.0
            return False
        is_slow = False
        if self._n > self.warmup:
            sigma = math.sqrt(max(self._var, 1e-12))
            is_slow = dt > self._mean + self.k_sigma * sigma and dt > self.rel_floor * self._mean
        if is_slow:
            self.flagged.append(step)
            if self.on_straggler is not None:
                self.on_straggler(step, dt, self._mean)
            return True  # don't poison the EWMA with the anomaly
        d = dt - self._mean
        self._mean += self.alpha * d
        self._var = (1 - self.alpha) * (self._var + self.alpha * d * d)
        return False

    @property
    def mean(self) -> float:
        return self._mean
