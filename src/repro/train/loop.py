"""Training loop: jitted step + prefetch + watchdog + checkpoint hooks +
revocation signals.

``run_segment`` executes a bounded slice of steps — the orchestrator's unit
of provisioning. A ``revoke_at_step`` callback injects spot-instance
revocations (2-minute-notice semantics are simulated by the orchestrator);
the loop raises :class:`Revoked` carrying the last step completed, so the
caller decides what survives (nothing for P-SIWOFT, the last checkpoint for
the FT baseline, the in-memory boundary state for segment handoff).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax

from repro.ckpt import CheckpointManager
from repro.config.base import ShardingLayout, TrainConfig
from repro.data import Prefetcher, SyntheticLM
from repro.dist import make_activation_constrainer, param_shardings
from repro.models import zoo
from repro.optim import OptState
from repro.train.steps import TrainState, build_train_step
from repro.train.watchdog import StragglerWatchdog


class Revoked(Exception):
    def __init__(self, last_step: int):
        super().__init__(f"spot instance revoked after step {last_step}")
        self.last_step = last_step


@dataclasses.dataclass
class SegmentResult:
    state: TrainState
    steps_done: int
    losses: List[float]
    step_seconds: List[float]
    stragglers: List[int]


def make_jitted_step(model: zoo.Model, tc: TrainConfig, layout: ShardingLayout, mesh):
    constrain = make_activation_constrainer(mesh, layout, model.cfg)
    step_fn = build_train_step(model, tc, layout, constrain)
    p_sh = param_shardings(model.specs, mesh, layout)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    state_sh = TrainState(
        params=p_sh, opt=OptState(m=p_sh, v=p_sh, count=repl), step=repl
    )
    return (
        jax.jit(step_fn, in_shardings=(state_sh, None), out_shardings=(state_sh, None)),
        state_sh,
    )


def run_segment(
    model: zoo.Model,
    state: TrainState,
    dataset: SyntheticLM,
    mesh,
    tc: TrainConfig,
    layout: ShardingLayout,
    *,
    num_steps: int,
    start_step: int = 0,
    ckpt: Optional[CheckpointManager] = None,
    ckpt_every: int = 0,
    revoke_at_step: Optional[Callable[[int], bool]] = None,
    watchdog: Optional[StragglerWatchdog] = None,
    jitted=None,
) -> SegmentResult:
    if jitted is None:
        jitted, _ = make_jitted_step(model, tc, layout, mesh)
    wd = watchdog or StragglerWatchdog()
    losses: List[float] = []
    times: List[float] = []
    pre = Prefetcher(dataset, start_step=start_step)
    try:
        with mesh:
            for i in range(num_steps):
                step = start_step + i
                if revoke_at_step is not None and revoke_at_step(step):
                    raise Revoked(step - 1)
                batch = pre.next()
                t0 = time.perf_counter()
                state, metrics = jitted(state, batch)
                loss = float(metrics["loss"])  # blocks; = device sync
                dt = time.perf_counter() - t0
                losses.append(loss)
                times.append(dt)
                wd.observe(step, dt)
                if ckpt is not None and ckpt_every and (step + 1) % ckpt_every == 0:
                    ckpt.save(step + 1, state)
    finally:
        pre.close()
    return SegmentResult(
        state=state,
        steps_done=num_steps,
        losses=losses,
        step_seconds=times,
        stragglers=list(wd.flagged),
    )
