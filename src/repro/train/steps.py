"""train_step / serve_step builders — the functions the dry-run lowers.

``build_train_step`` returns a jit-able ``(state, batch) -> (state, metrics)``
with:

* vocab-sharded cross-entropy (logits never gathered to a full-vocab array:
  the logsumexp reduction runs on the sharded dim and GSPMD inserts a small
  all-reduce instead of an all-gather),
* microbatch gradient accumulation (``lax.scan`` over microbatches),
* optional bf16 gradient all-reduce compression (params are cast once at the
  top of the loss so backward — and hence the cross-data-shard gradient
  reduction — runs in bf16, halving collective bytes),
* remat + scan-over-layers via RunOpts,
* AdamW with global-norm clip and warmup-cosine schedule.

``build_prefill_step`` / ``build_decode_step`` are the serving pair; decode
updates the KV cache in place (donated) via dynamic_update_slice.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ShardingLayout, TrainConfig
from repro.models import zoo
from repro.models.transformer import RunOpts
from repro.optim import (
    OptState,
    adamw_update,
    clip_by_global_norm,
    init_opt_state,
)
from repro.optim.schedule import warmup_cosine


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    step: jax.Array


def init_train_state(model: zoo.Model, key: jax.Array) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=init_opt_state(params), step=jnp.zeros((), jnp.int32))


def abstract_train_state(model: zoo.Model) -> TrainState:
    params = model.abstract_params()
    zeros_like = lambda t: jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), t
    )
    return TrainState(
        params=params,
        opt=OptState(
            m=zeros_like(params),
            v=zeros_like(params),
            count=jax.ShapeDtypeStruct((), jnp.int32),
        ),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )


def run_opts_from_layout(layout: ShardingLayout, constrain=None) -> RunOpts:
    kw = dict(
        attn_impl=layout.attn_impl,
        q_chunk=layout.q_chunk,
        kv_chunk=layout.kv_chunk,
        remat=layout.remat,
        scan_layers=layout.scan_layers,
        decode_unroll=layout.decode_unroll,
        int8_kv_cache=layout.int8_kv_cache,
    )
    if constrain is not None:
        kw["constrain"] = constrain
    return RunOpts(**kw)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def cross_entropy(
    logits: jax.Array, labels: jax.Array, label_smoothing: float = 0.0
) -> jax.Array:
    """Token-mean CE. logits (B,S,V) may be vocab-sharded — no full gather:
    logsumexp reduces the sharded axis; the gold logit comes via a 1-element
    take_along_axis (a tiny cross-shard gather)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)                       # (B, S)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if label_smoothing:
        smooth = lse - jnp.mean(logits, axis=-1)
        nll = (1.0 - label_smoothing) * nll + label_smoothing * smooth
    return jnp.mean(nll)


def chunked_cross_entropy(
    x: jax.Array,
    w: jax.Array,
    labels: jax.Array,
    chunk: int = 256,
    label_smoothing: float = 0.0,
) -> jax.Array:
    """Fused unembed+CE: scan over sequence chunks, jax.checkpoint per chunk.

    Never materializes (B, S, V) logits — forward holds one (B, chunk, V)
    slab, backward recomputes it per chunk. This is the memory-decisive
    optimization for 150k-vocab archs (qwen/gemma) at 4k×256 batches.
    """
    B, S, d = x.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # ragged fallback: single slab
    n = S // chunk
    xc = x.reshape(B, n, chunk, d).swapaxes(0, 1)      # (n, B, c, d)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)    # (n, B, c)

    @jax.checkpoint
    def body(total, xs):
        xi, li = xs
        logits = jnp.einsum("bcd,dv->bcv", xi, w.astype(xi.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = lse - gold
        if label_smoothing:
            smooth = lse - jnp.mean(logits, axis=-1)
            nll = (1.0 - label_smoothing) * nll + label_smoothing * smooth
        return total + jnp.sum(nll), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (B * S)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def build_train_step(
    model: zoo.Model,
    tc: TrainConfig,
    layout: ShardingLayout,
    constrain=None,
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, Dict[str, jax.Array]]]:
    opts = run_opts_from_layout(layout, constrain)
    compress = layout.gradient_allreduce_dtype == "bfloat16"

    def loss_fn(params, batch):
        if compress:
            params = jax.tree_util.tree_map(
                lambda p: p.astype(jnp.bfloat16)
                if jnp.issubdtype(p.dtype, jnp.floating) else p,
                params,
            )
        if layout.fused_ce:
            x, aux = model.forward_hidden(params, batch, opts)
            x = opts.constrain(x, "loss_input")
            loss = chunked_cross_entropy(
                x, model.unembed_weight(params), batch["labels"],
                layout.ce_chunk, tc.label_smoothing,
            )
        else:
            logits, aux = model.forward(params, batch, opts)
            loss = cross_entropy(logits, batch["labels"], tc.label_smoothing)
        return loss + aux, (loss, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def split_microbatches(batch):
        def split(x):
            b = x.shape[0]
            assert b % tc.microbatches == 0, (b, tc.microbatches)
            return x.reshape(tc.microbatches, b // tc.microbatches, *x.shape[1:])

        return jax.tree_util.tree_map(split, batch)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if tc.microbatches > 1:
            mb = split_microbatches(batch)

            def acc_step(carry, mb_i):
                g_acc, l_acc, a_acc = carry
                (_, (loss, aux)), grads = grad_fn(state.params, mb_i)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(a.dtype), g_acc, grads
                )
                return (g_acc, l_acc + loss, a_acc + aux), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (grads, loss, aux), _ = jax.lax.scan(
                acc_step, (g0, jnp.zeros(()), jnp.zeros(())), mb
            )
            scale = 1.0 / tc.microbatches
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
            loss, aux = loss * scale, aux * scale
        else:
            (_, (loss, aux)), grads = grad_fn(state.params, batch)

        grads, grad_norm = clip_by_global_norm(grads, tc.grad_clip)
        lr = warmup_cosine(state.step, tc)
        new_params, new_opt = adamw_update(grads, state.opt, state.params, lr, tc)
        metrics = {
            "loss": loss.astype(jnp.float32),
            "aux_loss": aux.astype(jnp.float32),
            "grad_norm": grad_norm,
            "lr": lr,
        }
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def build_prefill_step(model: zoo.Model, layout: ShardingLayout, cache_seq_len: int,
                       constrain=None):
    opts = run_opts_from_layout(layout, constrain)

    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch, cache_seq_len, opts)
        return logits, cache

    return prefill_step


def build_decode_step(model: zoo.Model, layout: ShardingLayout, constrain=None):
    opts = run_opts_from_layout(layout, constrain)

    def decode_step(params, cache, tokens, pos):
        logits, new_cache = model.decode_step(params, cache, tokens, pos, opts)
        return logits, new_cache

    return decode_step


def build_paged_decode_step(
    model: zoo.Model, layout: ShardingLayout, constrain=None,
    *, use_kernel: bool = False, interpret: bool = False,
):
    """Continuous-batching decode step against the paged KV pool.

    Signature: (params, cache, tokens (B,1), seq_lens (B,), block_table
    (B,nb)) -> (logits, cache). The block table and per-lane lengths are
    small host-side int32 arrays re-fed each step (not donated); the pool
    itself is donation-friendly like the dense cache.
    """
    opts = run_opts_from_layout(layout, constrain)

    def paged_decode_step(params, cache, tokens, seq_lens, block_table):
        logits, new_cache = model.decode_step_paged(
            params, cache, tokens, seq_lens, block_table, opts,
            use_kernel=use_kernel, interpret=interpret,
        )
        return logits, new_cache

    return paged_decode_step
