from repro.train.steps import (
    TrainState,
    abstract_train_state,
    build_decode_step,
    build_prefill_step,
    build_train_step,
    cross_entropy,
    init_train_state,
    run_opts_from_layout,
)

__all__ = [
    "TrainState", "abstract_train_state", "build_decode_step",
    "build_prefill_step", "build_train_step", "cross_entropy",
    "init_train_state", "run_opts_from_layout",
]
