"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 (InternLM2-20B backbone); InternViT frontend is a STUB
(input_specs provides precomputed patch embeddings). [arXiv:2404.16821; hf]
"""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="internvl2-26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=92553,
        vision_tokens=1025,      # 448px / 14 patch -> 1024 + cls, pixel-shuffled stub
        vision_width=3200,       # InternViT-6B width
    )
)
