"""Assigned architecture configs.

One module per architecture id (module names sanitize ``.``/``-`` to ``_``;
the registered arch id is exact). Importing ``repro.config`` registry APIs
auto-loads every module here.
"""
