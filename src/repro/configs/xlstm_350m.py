"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304,
sLSTM + mLSTM blocks (1 sLSTM every 6 blocks), no separate FFN (the xLSTM
block carries its own up/down projection). [arXiv:2405.04517; unverified]
"""
from repro.config import AttentionKind, BlockKind, ModelConfig, SSMConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="xlstm-350m",
        family="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        block=BlockKind.MLSTM,
        attention=AttentionKind.NONE,
        slstm_every=6,
        ssm=SSMConfig(chunk=256),  # chunkwise-parallel mLSTM chunk length
    )
)
