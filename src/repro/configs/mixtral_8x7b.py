"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, sliding-window attention. [arXiv:2401.04088; hf]
"""
from repro.config import AttentionKind, BlockKind, ModelConfig, MoEConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        block=BlockKind.MOE,
        attention=AttentionKind.SLIDING,
        window=4096,
        moe=MoEConfig(num_experts=8, top_k=2),
    )
)
