"""whisper-tiny [audio] — 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865,
enc-dec; conv frontend is a STUB (input_specs provides precomputed frame
embeddings of shape (B, 1500, 384)). [arXiv:2212.04356; unverified]
"""
from repro.config import BlockKind, ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="whisper-tiny",
        family="audio",
        num_layers=4,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        block=BlockKind.ENCDEC,
        encoder_layers=4,
        encoder_seq_len=1500,
        gated_mlp=False,          # whisper uses plain GELU MLP
        mlp_activation="gelu",
        qkv_bias=True,
        tie_embeddings=True,     # whisper ties the decoder embedding
    )
)
