"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16, parallel attention + mamba heads, sliding-window
attention (global attn only on a few layers in the paper; we use SWA so the
arch is sub-quadratic, per its long-context design). [arXiv:2411.13676; hf]
"""
from repro.config import AttentionKind, BlockKind, ModelConfig, SSMConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        d_ff=5504,
        vocab_size=32001,
        block=BlockKind.HYBRID_PARALLEL,
        attention=AttentionKind.SLIDING,
        window=1024,
        ssm=SSMConfig(state_dim=16, expand=2),
    )
)
