"""gemma-7b [dense] — 28L d_model=3072 16H (GQA kv=16) d_ff=24576
vocab=256000, GeGLU, head_dim=256. [arXiv:2403.08295; hf]
"""
from repro.config import ModelConfig, register_arch

CONFIG = register_arch(
    ModelConfig(
        name="gemma-7b",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=16,
        num_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab_size=256000,
        mlp_activation="gelu",   # GeGLU
        tie_embeddings=True,     # gemma ties the LM head to the embedding
        embed_scale=True,        # gemma multiplies embeddings by sqrt(d_model)
    )
)
