"""The frozen event registry: every telemetry record is one of these types.

Each event is an immutable dataclass whose first field ``t`` is the trace
clock in **hours** on the emitting subsystem's timeline (wall-clock hours
for the orchestrator, trace hours for the fleet/simulator, step index for
the decode engine). Within one run the recorder stamps a global
append-order sequence number, so ``t`` only needs to be monotone per
track, not globally.

Events carry *plain data only* (ints, floats, strings, tuples) so a JSONL
round-trip through :mod:`repro.obs.export` is lossless: Python's ``json``
writes shortest-round-trip floats, which re-read bit-exactly — the
property the replay oracle relies on.

The registry (`EVENT_TYPES`) maps the snake_case wire name of each event
to its class. repro-lint rule O001 enforces that instrumented modules
only ever emit these types — no ad-hoc dict events.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Type

# -- run framing -------------------------------------------------------------


@dataclass(frozen=True)
class RunStart:
    """Opens one replayable unit: everything until the next RunStart."""

    t: float
    subsystem: str  # "orchestrator" | "simulator" | "fleet"
    label: str  # policy / sizing mode, e.g. "siwoft", "static", "auto"
    horizon_hours: float


@dataclass(frozen=True)
class PriceTrace:
    """The price matrix the run billed against, row per market."""

    t: float
    prices: Tuple[Tuple[float, ...], ...]


@dataclass(frozen=True)
class RunEnd:
    t: float
    wall_hours: float


# -- provisioning lifecycle --------------------------------------------------


@dataclass(frozen=True)
class Provision:
    t: float
    market_id: int  # primary (first) leg
    legs: Tuple[int, ...]
    replica_id: int = -1  # serving only; -1 for training/sim
    rate_tokens_per_sec: float = 0.0


@dataclass(frozen=True)
class Revoke:
    t: float
    market_id: int
    replica_id: int = -1


@dataclass(frozen=True)
class ReshardStart:
    t: float
    bytes_moved: int
    gbps: float = 0.0  # 0.0 when the emitter only knows the wire time


@dataclass(frozen=True)
class ReshardDone:
    t: float
    hours: float


# -- autoscaler decisions ----------------------------------------------------


@dataclass(frozen=True)
class ScaleDecision:
    """What the scaler saw when it decided: its full input vector."""

    t: float
    kind: str  # "hold" | "up" | "down"
    offered_tokens_per_sec: float
    forecast_tokens_per_sec: float
    capacity_tokens_per_sec: float
    target_tokens_per_sec: float


@dataclass(frozen=True)
class ScaleUp:
    t: float
    added: int
    target_tokens_per_sec: float


@dataclass(frozen=True)
class ScaleDown:
    t: float
    retired: int
    target_tokens_per_sec: float


# -- decode-engine lane events (t = step index) ------------------------------


@dataclass(frozen=True)
class Admit:
    t: float
    request_id: int
    lane: int
    pages_reserved: int


@dataclass(frozen=True)
class Evict:
    t: float
    request_id: int
    lane: int
    reason: str  # "eos" | "length" | "shed"


@dataclass(frozen=True)
class Shed:
    """Carries everything needed to re-prefill the request elsewhere."""

    t: float
    request_id: int
    lane: int
    prompt_tokens: int
    resume_tokens: int  # tokens generated before the shed


@dataclass(frozen=True)
class Drain:
    t: float
    moved_requests: int


@dataclass(frozen=True)
class GaugeSample:
    t: float
    name: str
    value: float


# -- billing (the replay oracle's inputs) ------------------------------------


@dataclass(frozen=True)
class SessionBilled:
    """A Session handed to ``bill_session``, verbatim.

    ``price_const`` of ``None`` means the run's PriceTrace matrix priced
    this session; a float means a constant price (on-demand reference).
    """

    t: float
    market_id: int
    start_wall: float
    intervals: Tuple[Tuple[str, float], ...]
    legs: Tuple[int, ...]
    leg_anchors: Optional[Tuple[float, ...]] = None
    leg_releases: Optional[Tuple[bool, ...]] = None
    price_const: Optional[float] = None


@dataclass(frozen=True)
class LegSettled:
    """A carried anchor settled via ``settle_leg`` outside any session."""

    t: float
    market_id: int
    anchor: float
    end_wall: float


@dataclass(frozen=True)
class RouterInterval:
    """One closed-form drain interval: the six RouterStats scalars."""

    t: float
    t0: float
    t1: float
    offered_tokens: float
    served_tokens: float
    shed_tokens: float
    queued_token_seconds: float
    slo_violation_seconds: float
    q_end: float
    delay_segments: Tuple[Tuple[float, float, float], ...]


@dataclass(frozen=True)
class SloViolation:
    t: float
    seconds: float


@dataclass(frozen=True)
class BreakdownPin:
    """The run's own Breakdown, recorded at return: replay's expected side."""

    t: float
    time: Tuple[Tuple[str, float], ...]
    cost: Tuple[Tuple[str, float], ...]
    leg_cost: Tuple[Tuple[int, float], ...]
    revocations: int
    sessions: int
    wall_time: float
    served_tokens: float
    shed_tokens: float
    queued_token_seconds: float


# -- registry ----------------------------------------------------------------

_CAMEL = re.compile(r"(?<!^)(?=[A-Z])")


def wire_name(cls: type) -> str:
    """``ReshardStart`` → ``"reshard_start"``: the JSONL ``type`` tag."""
    return _CAMEL.sub("_", cls.__name__).lower()


EVENT_TYPES: Dict[str, Type] = {
    wire_name(cls): cls
    for cls in (
        RunStart,
        PriceTrace,
        RunEnd,
        Provision,
        Revoke,
        ReshardStart,
        ReshardDone,
        ScaleDecision,
        ScaleUp,
        ScaleDown,
        Admit,
        Evict,
        Shed,
        Drain,
        GaugeSample,
        SessionBilled,
        LegSettled,
        RouterInterval,
        SloViolation,
        BreakdownPin,
    )
}


def as_dict(event) -> dict:
    """Event → JSON-ready dict with its wire name under ``"type"``."""
    d = {"type": wire_name(type(event))}
    d.update(dataclasses.asdict(event))
    return d


def _tuplize(value):
    if isinstance(value, list):
        return tuple(_tuplize(v) for v in value)
    return value


def from_dict(d: dict):
    """Inverse of :func:`as_dict`: rebuild the typed event.

    JSON turns tuples into lists; every sequence field is declared as a
    tuple, so lists are converted back wholesale. Unknown keys (from a
    newer schema) are rejected loudly rather than dropped.
    """
    payload = dict(d)
    cls = EVENT_TYPES[payload.pop("type")]
    return cls(**{k: _tuplize(v) for k, v in payload.items()})


# -- emission helpers (registry-typed constructors for the fat events) -------


def price_trace(t: float, prices) -> PriceTrace:
    """Snapshot a ``(n_markets, n_hours)`` price matrix (any ``.tolist()``
    carrier: ndarray or nested sequence)."""
    return PriceTrace(t=t, prices=tuple(tuple(row) for row in prices.tolist()))


def session_billed(t: float, session, price_const: Optional[float] = None) -> SessionBilled:
    """Snapshot a ``repro.core.accounting.Session`` verbatim, at the moment
    it is handed to ``bill_session``."""
    return SessionBilled(
        t=t,
        market_id=int(session.market_id),
        start_wall=session.start_wall,
        intervals=tuple(session.intervals),
        legs=tuple(int(leg) for leg in session.legs),
        leg_anchors=None if session.leg_anchors is None else tuple(session.leg_anchors),
        leg_releases=None if session.leg_releases is None else tuple(session.leg_releases),
        price_const=price_const,
    )


def breakdown_pin(t: float, bd) -> BreakdownPin:
    """Snapshot a ``Breakdown`` as the run's expected replay result."""
    return BreakdownPin(
        t=t,
        time=tuple(bd.time.items()),
        cost=tuple(bd.cost.items()),
        leg_cost=tuple(sorted((int(m), c) for m, c in bd.leg_cost.items())),
        revocations=bd.revocations,
        sessions=bd.sessions,
        wall_time=bd.wall_time,
        served_tokens=bd.served_tokens,
        shed_tokens=bd.shed_tokens,
        queued_token_seconds=bd.queued_token_seconds,
    )


def router_interval(t: float, t0: float, t1: float, stats) -> RouterInterval:
    """Snapshot one ``drain_interval`` result (a ``RouterStats``)."""
    return RouterInterval(
        t=t,
        t0=t0,
        t1=t1,
        offered_tokens=stats.offered_tokens,
        served_tokens=stats.served_tokens,
        shed_tokens=stats.shed_tokens,
        queued_token_seconds=stats.queued_token_seconds,
        slo_violation_seconds=stats.slo_violation_seconds,
        q_end=stats.q_end,
        delay_segments=tuple(tuple(s) for s in stats.delay_segments),
    )
