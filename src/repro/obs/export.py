"""Event-log serialization: JSONL on disk, Chrome ``trace_event`` for eyes.

JSONL is the canonical format — one ``events.as_dict`` object per line.
Python's ``json`` emits shortest-round-trip floats, so a write/read
cycle reconstructs every float bit-exactly; the replay oracle depends on
this (and ``tests/test_obs.py`` pins it).

The Chrome export is lossy-by-design visualization for
``chrome://tracing`` / https://ui.perfetto.dev: one process per run, one
track (thread) per market / replica / engine lane, sessions and router
intervals as complete ("X") slices, revocations as instants, gauges and
scaler decisions as counter tracks. One trace-hour renders as one
second (1 h = 1e6 µs) so day-scale runs stay navigable.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Iterable, List

from repro.obs import events as ev

US_PER_HOUR = 1_000_000  # render 1 trace-hour as 1 second


def write_jsonl(path, event_seq: Iterable) -> int:
    """Write events as JSONL; returns the number of lines written."""
    n = 0
    with open(path, "w") as fh:
        for event in event_seq:
            fh.write(json.dumps(ev.as_dict(event), separators=(",", ":")))
            fh.write("\n")
            n += 1
    return n


def read_jsonl(path) -> List:
    """Read a JSONL event log back into typed event instances."""
    out: List = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(ev.from_dict(json.loads(line)))
    return out


def _us(t_hours: float) -> int:
    return int(round(t_hours * US_PER_HOUR))


def to_chrome_trace(event_seq: Iterable) -> dict:
    """Build a Chrome ``trace_event`` JSON object from an event stream."""
    trace: List[dict] = []
    pid = 0
    run_label = "trace"

    def meta(name: str, tid: int, sort: int) -> None:
        trace.append(
            {
                "name": "thread_name",
                "ph": "M",
                "pid": pid,
                "tid": tid,
                "args": {"name": name},
            }
        )
        trace.append(
            {
                "name": "thread_sort_index",
                "ph": "M",
                "pid": pid,
                "tid": tid,
                "args": {"sort_index": sort},
            }
        )

    def slice_(name: str, tid: int, t0: float, dur: float, args: dict) -> None:
        trace.append(
            {
                "name": name,
                "ph": "X",
                "pid": pid,
                "tid": tid,
                "ts": _us(t0),
                "dur": max(_us(t0 + dur) - _us(t0), 1),
                "args": args,
            }
        )

    def instant(name: str, tid: int, t: float, args: dict) -> None:
        trace.append(
            {
                "name": name,
                "ph": "i",
                "pid": pid,
                "tid": tid,
                "ts": _us(t),
                "s": "t",
                "args": args,
            }
        )

    def counter(name: str, t: float, values: dict) -> None:
        trace.append(
            {
                "name": name,
                "ph": "C",
                "pid": pid,
                "ts": _us(t),
                "args": values,
            }
        )

    # Track ids: markets get their market_id, replicas 1000+replica_id,
    # engine lanes 2000+lane, the router 3000.
    ROUTER_TID = 3000

    seen_tids = set()

    def market_tid(market_id: int) -> int:
        tid = int(market_id)
        if tid not in seen_tids:
            seen_tids.add(tid)
            meta(f"market {market_id}", tid, tid)
        return tid

    def replica_tid(replica_id: int) -> int:
        tid = 1000 + int(replica_id)
        if tid not in seen_tids:
            seen_tids.add(tid)
            meta(f"replica {replica_id}", tid, tid)
        return tid

    def lane_tid(lane: int) -> int:
        tid = 2000 + int(lane)
        if tid not in seen_tids:
            seen_tids.add(tid)
            meta(f"lane {lane}", tid, tid)
        return tid

    for event in event_seq:
        if isinstance(event, ev.RunStart):
            pid += 1
            seen_tids.clear()
            run_label = f"{event.subsystem}:{event.label}"
            trace.append(
                {
                    "name": "process_name",
                    "ph": "M",
                    "pid": pid,
                    "args": {"name": run_label},
                }
            )
            meta("router", ROUTER_TID, ROUTER_TID)
            seen_tids.add(ROUTER_TID)
        elif isinstance(event, ev.Provision):
            tid = (
                replica_tid(event.replica_id)
                if event.replica_id >= 0
                else market_tid(event.market_id)
            )
            instant(
                "provision",
                tid,
                event.t,
                {"market": event.market_id, "legs": list(event.legs)},
            )
        elif isinstance(event, ev.Revoke):
            tid = (
                replica_tid(event.replica_id)
                if event.replica_id >= 0
                else market_tid(event.market_id)
            )
            instant("revoke", tid, event.t, {"market": event.market_id})
        elif isinstance(event, ev.ReshardStart):
            instant(
                "reshard_start",
                ROUTER_TID,
                event.t,
                {"bytes": event.bytes_moved, "gbps": event.gbps},
            )
        elif isinstance(event, ev.ReshardDone):
            slice_(
                "reshard",
                ROUTER_TID,
                event.t - event.hours,
                event.hours,
                {"hours": event.hours},
            )
        elif isinstance(event, ev.SessionBilled):
            tid = market_tid(event.market_id)
            cursor = event.start_wall
            for component, hours in event.intervals:
                slice_(component, tid, cursor, hours, {"hours": hours})
                cursor += hours
        elif isinstance(event, ev.LegSettled):
            instant(
                "leg_settled",
                market_tid(event.market_id),
                event.t,
                {"anchor": event.anchor, "end_wall": event.end_wall},
            )
        elif isinstance(event, ev.RouterInterval):
            slice_(
                "interval",
                ROUTER_TID,
                event.t0,
                event.t1 - event.t0,
                {
                    "served": event.served_tokens,
                    "shed": event.shed_tokens,
                    "q_end": event.q_end,
                },
            )
            counter("backlog_tokens", event.t0, {"q": event.q_end})
        elif isinstance(event, ev.SloViolation):
            instant(
                "slo_violation", ROUTER_TID, event.t, {"seconds": event.seconds}
            )
        elif isinstance(event, ev.ScaleDecision):
            counter(
                "scaler_tokens_per_sec",
                event.t,
                {
                    "offered": event.offered_tokens_per_sec,
                    "forecast": event.forecast_tokens_per_sec,
                    "capacity": event.capacity_tokens_per_sec,
                },
            )
        elif isinstance(event, (ev.ScaleUp, ev.ScaleDown)):
            name = "scale_up" if isinstance(event, ev.ScaleUp) else "scale_down"
            delta = event.added if isinstance(event, ev.ScaleUp) else event.retired
            instant(name, ROUTER_TID, event.t, {"replicas": delta})
        elif isinstance(event, ev.Admit):
            instant(
                "admit",
                lane_tid(event.lane),
                event.t,
                {"request": event.request_id, "pages": event.pages_reserved},
            )
        elif isinstance(event, ev.Evict):
            instant(
                "evict",
                lane_tid(event.lane),
                event.t,
                {"request": event.request_id, "reason": event.reason},
            )
        elif isinstance(event, ev.Shed):
            instant(
                "shed",
                lane_tid(event.lane),
                event.t,
                {
                    "request": event.request_id,
                    "prompt": event.prompt_tokens,
                    "resume": event.resume_tokens,
                },
            )
        elif isinstance(event, ev.GaugeSample):
            counter(event.name, event.t, {"value": event.value})
        # RunEnd / BreakdownPin / PriceTrace / Drain carry no geometry.

    return {"traceEvents": trace, "displayTimeUnit": "ms"}


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Convert a JSONL event log to Chrome trace_event JSON."
    )
    ap.add_argument("trace", type=Path, help="input .jsonl event log")
    ap.add_argument(
        "-o",
        "--out",
        type=Path,
        default=None,
        help="output path (default: <trace>.chrome.json)",
    )
    args = ap.parse_args(argv)
    out = args.out or args.trace.with_suffix(".chrome.json")
    event_seq = read_jsonl(args.trace)
    with open(out, "w") as fh:
        json.dump(to_chrome_trace(event_seq), fh)
    print(f"CHROME_TRACE {out} events={len(event_seq)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
