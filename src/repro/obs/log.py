"""A small structured stderr logger for the launchers.

stdout stays machine-owned (``PLAN_JSON`` lines, ``SPLIT_JSON``, CSV
rows, roofline tables); human status goes to stderr in one greppable
shape::

    [serve] INFO fleet plan chosen replicas=3 cost_per_hour=1.2750

Levels are ``debug`` < ``info`` < ``warn``; the threshold comes from the
``REPRO_LOG`` environment variable (default ``info``). No timestamps —
launcher output stays deterministic run to run.
"""
from __future__ import annotations

import os
import sys
from typing import Dict

_LEVELS = {"debug": 10, "info": 20, "warn": 30}


def _threshold() -> int:
    return _LEVELS.get(os.environ.get("REPRO_LOG", "info").lower(), 20)


def _format_value(value) -> str:
    if isinstance(value, float):
        return f"{value:.4f}"
    return str(value)


class Logger:
    """Leveled stderr logger with a machine-greppable key=value tail."""

    def __init__(self, name: str) -> None:
        self.name = name

    def _log(self, level: str, message: str, fields: dict) -> None:
        if _LEVELS[level] < _threshold():
            return
        tail = "".join(
            f" {key}={_format_value(value)}" for key, value in fields.items()
        )
        print(
            f"[{self.name}] {level.upper()} {message}{tail}",
            file=sys.stderr,
            flush=True,
        )

    def debug(self, message: str, **fields) -> None:
        self._log("debug", message, fields)

    def info(self, message: str, **fields) -> None:
        self._log("info", message, fields)

    def warn(self, message: str, **fields) -> None:
        self._log("warn", message, fields)


_loggers: Dict[str, Logger] = {}


def get_logger(name: str) -> Logger:
    if name not in _loggers:
        _loggers[name] = Logger(name)
    return _loggers[name]
