"""``repro.obs`` — typed, zero-overhead-when-off event telemetry.

One traced timeline across the five loops that used to run blind — the
training orchestrator, both simulator engines, the serving fleet (static
and autoscaled), the decode engine, and the router — with the accounting
ledger as its correctness oracle: replaying a run's event log re-drives
the REAL billing functions (``bill_session`` / ``settle_leg`` /
``RouterStats.add``) and must reconstruct every ``Breakdown`` time/cost
component bit-exactly. Every billed hour is justified by events, the same
discipline the scalar billing oracles enforce on the vectorized core.

* :mod:`repro.obs.events`   — the frozen event registry (~15 dataclasses
  sharing the monotone trace clock ``t``);
* :mod:`repro.obs.recorder` — the append-only in-memory recorder plus the
  :class:`~repro.obs.recorder.NullRecorder` DEFAULT: with telemetry off,
  instrumented code performs one attribute check per loop and constructs
  nothing, so every pinned bit-exact path stays byte-identical;
* :mod:`repro.obs.export`   — JSONL event logs (exact float round-trip)
  and Chrome/Perfetto ``trace_event`` export, one track per
  market/replica/engine lane;
* :mod:`repro.obs.replay`   — the load-bearing piece: event log →
  ``Breakdown``, bit-exact, with a CLI (``python -m repro.obs.replay``)
  CI uses to validate bench traces against their recorded breakdowns;
* :mod:`repro.obs.log`      — the small structured stderr logger the
  launchers use instead of ad-hoc ``print`` (stdout stays machine-owned:
  ``PLAN_JSON`` lines, CSV rows, trace files).

See ``docs/observability.md`` for the event schema and replay contract.
"""
from repro.obs import events
from repro.obs.log import get_logger
from repro.obs.recorder import NullRecorder, Recorder, current, recording

__all__ = [
    "NullRecorder",
    "Recorder",
    "current",
    "events",
    "get_logger",
    "recording",
]
