"""Recorders: where events go, and the null default that makes them free.

The module-level *current recorder* is what instrumented code consults.
It defaults to a :class:`NullRecorder` whose ``enabled`` attribute is
``False``; every instrumentation site reads the recorder once per
run/function and guards each emission with ``if rec.enabled:`` — with
telemetry off no event object is ever constructed and no arithmetic
changes, so every pinned bit-exact path stays byte-identical.

Enable telemetry for a scope with::

    from repro import obs

    with obs.recording() as rec:
        fleet.run()
    rec.events  # the typed timeline, in emission order
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Dict, Iterator, List


class Recorder:
    """Append-only in-memory event sink with counters/gauges/histograms.

    ``events`` holds typed event instances in emission order (the global
    order *is* the sequence number — ``events[i]`` was the i-th emit).
    Counters/gauges/histograms are side telemetry and never participate
    in the replay oracle.
    """

    enabled = True

    def __init__(self) -> None:
        self.events: List[object] = []
        self.counters: Dict[str, int] = {}
        self.gauge_values: Dict[str, float] = {}
        self.gauge_series: Dict[str, List[tuple]] = {}
        self.histograms: Dict[str, List[float]] = {}

    def emit(self, event) -> None:
        self.events.append(event)

    def count(self, name: str, delta: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + delta

    def gauge(self, name: str, t: float, value: float) -> None:
        self.gauge_values[name] = value
        self.gauge_series.setdefault(name, []).append((t, value))

    def observe(self, name: str, value: float) -> None:
        self.histograms.setdefault(name, []).append(value)

    def clear(self) -> None:
        self.events.clear()
        self.counters.clear()
        self.gauge_values.clear()
        self.gauge_series.clear()
        self.histograms.clear()


class NullRecorder:
    """The default sink: ``enabled`` is False, every method is a no-op.

    Instrumented code never calls these when it honours the
    ``if rec.enabled:`` guard; they exist so unguarded calls still work.
    """

    enabled = False

    def emit(self, event) -> None:  # pragma: no cover - guarded out
        pass

    def count(self, name: str, delta: int = 1) -> None:  # pragma: no cover
        pass

    def gauge(self, name: str, t: float, value: float) -> None:  # pragma: no cover
        pass

    def observe(self, name: str, value: float) -> None:  # pragma: no cover
        pass


_NULL = NullRecorder()
_current = _NULL


def current():
    """The active recorder: consult once per run, guard on ``.enabled``."""
    return _current


def set_current(recorder) -> None:
    global _current
    _current = recorder if recorder is not None else _NULL


@contextmanager
def recording(recorder: Recorder | None = None) -> Iterator[Recorder]:
    """Install ``recorder`` (a fresh one by default) for the with-block."""
    rec = recorder if recorder is not None else Recorder()
    prev = _current
    set_current(rec)
    try:
        yield rec
    finally:
        set_current(prev)
