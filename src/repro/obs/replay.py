"""The replay oracle: event log → ``Breakdown``, bit-exactly.

A trace is a sequence of runs, each opened by a ``RunStart``. Replaying a
run re-drives the REAL accounting code — ``bill_session`` on every
``SessionBilled`` (against the run's ``PriceTrace`` table, or the
session's constant price), ``settle_leg`` on every ``LegSettled``, and
the router's own ``RouterStats.add`` fold over ``RouterInterval`` events
followed by one ``merge_into`` — in emission order. Because every
``Breakdown`` mutation in the instrumented loops goes through exactly
those three functions, the replayed breakdown matches the run's own,
float for float: every billed hour is justified by events.

Replay always prices through a :class:`PriceTable`, whatever engine
emitted the log — table and scalar billing are pinned bit-identical
repo-wide, and this is what makes the reference and vectorized simulator
engines emit *identical* logs (no engine-specific event fields exist).

Each instrumented run records its own breakdown as a ``BreakdownPin``
just before returning; :func:`verify_events` compares replay against pin
with ``==`` per component. The CLI (``python -m repro.obs.replay
trace.jsonl``) exits nonzero on any mismatch — CI runs it on the bench
traces every build.
"""
from __future__ import annotations

import dataclasses
import sys
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.accounting import Breakdown, PriceTable, Session, bill_session, settle_leg
from repro.obs import events as ev
from repro.serve.router import RouterStats


@dataclasses.dataclass
class ReplayedRun:
    subsystem: str
    label: str
    breakdown: Breakdown
    pin: Optional[ev.BreakdownPin]
    n_events: int


def split_runs(event_seq: Sequence) -> List[List]:
    """Split a trace into runs on ``RunStart`` boundaries.

    Events before the first ``RunStart`` (engine-lane telemetry from a
    bare decode run, say) form no run and are dropped.
    """
    runs: List[List] = []
    for event in event_seq:
        if isinstance(event, ev.RunStart):
            runs.append([event])
        elif runs:
            runs[-1].append(event)
    return runs


def replay_run(run_events: Sequence) -> ReplayedRun:
    """Re-drive the billing code over one run's events."""
    start = run_events[0]
    assert isinstance(start, ev.RunStart), "a run must open with RunStart"
    table: Optional[PriceTable] = None
    bd = Breakdown()
    router = RouterStats()
    routed = False
    revocations = 0
    wall_hours = 0.0
    pin: Optional[ev.BreakdownPin] = None

    for event in run_events:
        if isinstance(event, ev.PriceTrace):
            table = PriceTable(np.array(event.prices, dtype=float))
        elif isinstance(event, ev.SessionBilled):
            if event.price_const is not None:
                price = PriceTable.constant(event.price_const)
            else:
                assert table is not None, "SessionBilled before PriceTrace"
                price = table
            session = Session(
                market_id=event.market_id,
                start_wall=event.start_wall,
                intervals=[(c, h) for c, h in event.intervals],
                legs=tuple(event.legs),
                leg_anchors=event.leg_anchors,
                leg_releases=event.leg_releases,
            )
            bill_session(session, price, bd)
        elif isinstance(event, ev.LegSettled):
            assert table is not None, "LegSettled before PriceTrace"
            settle_leg(bd, event.market_id, event.anchor, event.end_wall, table)
        elif isinstance(event, ev.RouterInterval):
            routed = True
            router.add(
                RouterStats(
                    offered_tokens=event.offered_tokens,
                    served_tokens=event.served_tokens,
                    shed_tokens=event.shed_tokens,
                    queued_token_seconds=event.queued_token_seconds,
                    slo_violation_seconds=event.slo_violation_seconds,
                    q_end=event.q_end,
                    delay_segments=[tuple(s) for s in event.delay_segments],
                )
            )
        elif isinstance(event, ev.Revoke):
            revocations += 1
        elif isinstance(event, ev.RunEnd):
            wall_hours = event.wall_hours
        elif isinstance(event, ev.BreakdownPin):
            pin = event

    if routed:
        router.merge_into(bd)
    bd.revocations = revocations
    bd.wall_time = wall_hours
    return ReplayedRun(
        subsystem=start.subsystem,
        label=start.label,
        breakdown=bd,
        pin=pin,
        n_events=len(run_events),
    )


def mismatches(bd: Breakdown, pin: ev.BreakdownPin) -> List[str]:
    """Every field where replay and pin disagree — compared with ``==``,
    not approx: the oracle's whole point is bit-exactness."""
    bad: List[str] = []
    for name, expected in pin.time:
        if bd.time[name] != expected:
            bad.append(f"time[{name}]: replay {bd.time[name]!r} != run {expected!r}")
    for name, expected in pin.cost:
        if bd.cost[name] != expected:
            bad.append(f"cost[{name}]: replay {bd.cost[name]!r} != run {expected!r}")
    pin_legs: Dict[int, float] = {m: c for m, c in pin.leg_cost}
    for market in sorted(set(bd.leg_cost) | set(pin_legs)):
        got, expected = bd.leg_cost.get(market), pin_legs.get(market)
        if got != expected:
            bad.append(f"leg_cost[{market}]: replay {got!r} != run {expected!r}")
    scalars: Tuple[Tuple[str, object, object], ...] = (
        ("revocations", bd.revocations, pin.revocations),
        ("sessions", bd.sessions, pin.sessions),
        ("wall_time", bd.wall_time, pin.wall_time),
        ("served_tokens", bd.served_tokens, pin.served_tokens),
        ("shed_tokens", bd.shed_tokens, pin.shed_tokens),
        (
            "queued_token_seconds",
            bd.queued_token_seconds,
            pin.queued_token_seconds,
        ),
    )
    for name, got, expected in scalars:
        if got != expected:
            bad.append(f"{name}: replay {got!r} != run {expected!r}")
    return bad


def verify_events(event_seq: Sequence) -> Tuple[List[ReplayedRun], List[str]]:
    """Replay every run and collect mismatch descriptions (empty == pass).

    A run without a ``BreakdownPin`` cannot be validated and is reported
    as a problem — instrumented loops always pin before returning.
    """
    problems: List[str] = []
    runs = [replay_run(run) for run in split_runs(event_seq)]
    for i, run in enumerate(runs):
        tag = f"run {i} ({run.subsystem}:{run.label})"
        if run.pin is None:
            problems.append(f"{tag}: no BreakdownPin recorded")
            continue
        problems.extend(f"{tag}: {m}" for m in mismatches(run.breakdown, run.pin))
    return runs, problems


def main(argv=None) -> int:
    import argparse

    from repro.obs.export import read_jsonl

    ap = argparse.ArgumentParser(
        description=(
            "Replay JSONL event logs through the real billing code and "
            "verify each run's Breakdown bit-exactly against its pin."
        )
    )
    ap.add_argument("traces", nargs="+", help="JSONL event logs")
    args = ap.parse_args(argv)

    failed = False
    for path in args.traces:
        event_seq = read_jsonl(path)
        runs, problems = verify_events(event_seq)
        if not runs:
            print(f"REPLAY {path}: no runs (only {len(event_seq)} loose events)")
            continue
        for problem in problems:
            print(f"REPLAY {path}: MISMATCH {problem}", file=sys.stderr)
            failed = True
        ok = sum(1 for r in runs if r.pin is not None)
        print(
            f"REPLAY {path}: {len(runs)} run(s), {len(event_seq)} event(s), "
            f"{len(problems)} mismatch(es), {ok} pinned"
        )
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
