"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config.base import TrainConfig


def warmup_cosine(step, tc: TrainConfig):
    step = step.astype(jnp.float32)
    warm = tc.learning_rate * jnp.minimum(1.0, step / max(tc.warmup_steps, 1))
    frac = jnp.clip(
        (step - tc.warmup_steps) / max(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    decayed = tc.learning_rate * (0.1 + 0.9 * cos)
    return jnp.where(step < tc.warmup_steps, warm, decayed)


def linear(step, tc: TrainConfig):
    step = step.astype(jnp.float32)
    warm = tc.learning_rate * jnp.minimum(1.0, step / max(tc.warmup_steps, 1))
    frac = jnp.clip(
        (step - tc.warmup_steps) / max(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0
    )
    return jnp.where(step < tc.warmup_steps, warm, tc.learning_rate * (1.0 - 0.9 * frac))


SCHEDULES = {"warmup_cosine": warmup_cosine, "linear": linear}
