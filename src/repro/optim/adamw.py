"""AdamW with decoupled weight decay + global-norm clipping.

Implemented directly on pytrees (no external deps). Optimizer state shards
exactly like the parameters (same tree structure → same NamedShardings), so
FSDP-sharded params get FSDP-sharded (m, v) for free — ZeRO-style.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import TrainConfig


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array  # int32 step counter


def init_opt_state(params: Any) -> OptState:
    zeros = lambda p: jnp.zeros_like(p)
    return OptState(
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(
    grads: Any,
    state: OptState,
    params: Any,
    lr: jax.Array,
    tc: TrainConfig,
) -> Tuple[Any, OptState]:
    count = state.count + 1
    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        step = lr * (mhat / (jnp.sqrt(vhat) + tc.eps) + tc.weight_decay * p32)
        return (p32 - step).astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(m=new_m, v=new_v, count=count)
