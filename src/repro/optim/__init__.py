from repro.optim.adamw import (
    OptState,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
)
from repro.optim.schedule import SCHEDULES, linear, warmup_cosine

__all__ = [
    "OptState", "adamw_update", "clip_by_global_norm", "global_norm",
    "init_opt_state", "SCHEDULES", "linear", "warmup_cosine",
]
