"""Deterministic open-loop request router for a serving fleet.

The router is the DEMAND side of the serving subsystem: an open-loop
request trace (tokens/sec offered per wall interval — requests never slow
down because the fleet is struggling, which is what makes the accounting
honest) drains through whatever aggregate capacity the live replicas
provide. Everything is closed-form over piecewise-constant intervals, so
the same trace always produces bit-identical token and SLO accounting —
the serving analogue of the seeded price traces the batch simulator runs
on.

Queue model over one interval of ``seconds`` with constant offered rate
``a`` (tokens/s) and constant fleet capacity ``c`` (tokens/s):

* the backlog evolves linearly, ``q(t) = q0 + (a - c)·t``, floored at 0;
* **SLO violation** — the estimated queueing delay is ``q(t) / c``; every
  second where it exceeds ``max_delay_seconds`` is an SLO-violation
  second (capacity 0 with any demand is a violation outright). The
  crossing times of the linear backlog are solved exactly.
* **shedding** — clients abandon after ``shed_delay_seconds``: the
  backlog is capped at ``c × shed_delay`` and every token that would
  grow it past the cap is shed (with zero capacity the cap is zero —
  everything offered is shed). Shed tokens are *lost demand*, the
  serving analogue of the batch simulator's lost work.
* **queued token·seconds** — the exact integral of the backlog over the
  interval (trapezoids between crossing points), the Little's-law
  numerator for mean latency.

Token conservation holds exactly per interval and is pinned by tests:
``q0 + offered == served + shed + q_end``.

* **latency percentiles** — every linear backlog segment also records the
  estimated delay ``d(t) = q(t)/c`` seen by tokens *arriving* during it,
  as a ``(token_weight, d_start, d_end)`` triple. Arrivals are uniform in
  time, so within a segment the delay is uniform on ``[d_start, d_end]``
  (an atom when the backlog is flat). ``RouterStats.latency_percentile``
  inverts the resulting piecewise-linear CDF exactly — p50/p99 come from
  the same closed-form segments as the violation clock, never from
  sampling. Shed tokens carry no weight (they are lost demand, not a
  latency sample), and a zero-capacity interval contributes violation
  seconds but no finite delay sample.

The counters land on :class:`repro.core.accounting.Breakdown` as
first-class components: the violation clock in ``time["slo_violation"]``,
the token volumes in ``served_tokens`` / ``shed_tokens`` /
``queued_token_seconds``. Percentiles stay on :class:`RouterStats` (they
are diagnostics over the same conserved tokens, not a new component).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.accounting import Breakdown
from repro.core.units import SECONDS_PER_HOUR
from repro.obs import events as obs_ev
from repro.obs.recorder import current as obs_current


@dataclasses.dataclass
class RouterStats:
    """Token/SLO accounting over routed intervals (all exact sums)."""

    offered_tokens: float = 0.0
    served_tokens: float = 0.0
    shed_tokens: float = 0.0
    queued_token_seconds: float = 0.0
    slo_violation_seconds: float = 0.0
    #: backlog (tokens) left at the end of the routed span — the ``q_end``
    #: term of the conservation identity ``q0 + offered == served + shed
    #: + q_end``.
    q_end: float = 0.0
    #: ``(token_weight, delay_start_s, delay_end_s)`` per linear backlog
    #: segment: the estimated delay seen by tokens arriving during the
    #: segment, weighted by how many arrived (shed tokens excluded).
    delay_segments: List[Tuple[float, float, float]] = dataclasses.field(
        default_factory=list
    )

    def add(self, other: "RouterStats") -> "RouterStats":
        self.offered_tokens += other.offered_tokens
        self.served_tokens += other.served_tokens
        self.shed_tokens += other.shed_tokens
        self.queued_token_seconds += other.queued_token_seconds
        self.slo_violation_seconds += other.slo_violation_seconds
        # ``other`` is the later span: its backlog is the running backlog
        self.q_end = other.q_end
        self.delay_segments.extend(other.delay_segments)
        return self

    def latency_percentile(self, frac: float) -> float:
        """Invert the exact token-weighted delay CDF at ``frac`` ∈ [0, 1].

        Each segment spreads its token weight uniformly over
        ``[d_start, d_end]`` (delay is linear in time, arrivals uniform in
        time); a flat segment is an atom. The CDF is piecewise linear with
        jumps at atoms, and the inversion is exact — no sampling, no
        interpolation error beyond float arithmetic. Returns 0.0 when no
        tokens carried a delay sample.
        """
        segs = [
            (min(d0, d1), max(d0, d1), w)
            for (w, d0, d1) in self.delay_segments
            if w > 0.0
        ]
        if not segs:
            return 0.0
        total = sum(w for _, _, w in segs)
        target = min(max(float(frac), 0.0), 1.0) * total

        def cdf(d: float) -> float:
            mass = 0.0
            for lo, hi, w in segs:
                if hi <= lo:
                    mass += w if d >= lo else 0.0
                else:
                    mass += w * min(max((d - lo) / (hi - lo), 0.0), 1.0)
            return mass

        points = sorted({p for lo, hi, _ in segs for p in (lo, hi)})
        prev, prev_mass = points[0], cdf(points[0])
        if prev_mass >= target:
            return prev
        for point in points[1:]:
            mass = cdf(point)
            if mass >= target:
                slope = sum(
                    w / (hi - lo)
                    for lo, hi, w in segs
                    if hi > lo and lo <= prev and point <= hi
                )
                if slope <= 0.0:
                    return point  # target sits inside an atom's jump
                return min(prev + (target - prev_mass) / slope, point)
            prev, prev_mass = point, mass
        return points[-1]

    @property
    def p50_delay_seconds(self) -> float:
        return self.latency_percentile(0.50)

    @property
    def p99_delay_seconds(self) -> float:
        return self.latency_percentile(0.99)

    def merge_into(self, bd: Breakdown) -> None:
        """Land the counters on the shared Breakdown: the violation clock
        as a first-class time component (hours, like every other clock),
        the token volumes on the serving counter fields."""
        bd.time["slo_violation"] += self.slo_violation_seconds / SECONDS_PER_HOUR
        bd.served_tokens += self.served_tokens
        bd.shed_tokens += self.shed_tokens
        bd.queued_token_seconds += self.queued_token_seconds


def drain_interval(
    queue_tokens: float,
    offered_tokens_per_sec: float,
    capacity_tokens_per_sec: float,
    seconds: float,
    *,
    max_delay_seconds: float,
    shed_delay_seconds: float,
) -> Tuple[float, RouterStats]:
    """Route one piecewise-constant interval; returns (backlog after,
    stats). Closed form — no time discretization, so interval splitting is
    associative: routing [0, T] equals routing [0, s] then [s, T].
    """
    a = max(float(offered_tokens_per_sec), 0.0)
    c = max(float(capacity_tokens_per_sec), 0.0)
    T = float(seconds)
    q0 = max(float(queue_tokens), 0.0)
    if T <= 0:
        return q0, RouterStats(q_end=q0)
    stats = RouterStats(offered_tokens=a * T)

    cap = c * float(shed_delay_seconds)
    slo_q = c * float(max_delay_seconds)

    # tokens already waiting past the abandonment bound shed immediately
    # (capacity just dropped under the backlog's feet)
    q = min(q0, cap)
    stats.shed_tokens += q0 - q

    if c <= 0.0:
        # no live capacity: cap is 0, every offered token sheds, and any
        # demand at all is out-of-SLO for the whole interval
        stats.shed_tokens += a * T
        if a > 0.0 or q0 > 0.0:
            stats.slo_violation_seconds += T
        return 0.0, stats

    net = a - c
    if net > 0.0 and q + net * T > cap:
        # backlog hits the abandonment cap at t_cap and rides it, shedding
        # the net inflow from then on; only the admitted rate (c) of the
        # cap-riding arrivals carries latency weight
        t_cap = (cap - q) / net
        stats.shed_tokens += net * (T - t_cap)
        pre = _linear_segments(q, net, t_cap)
        segs = pre + [(T - t_cap, cap, cap)]
        weights = [a * dur for dur, _, _ in pre] + [c * (T - t_cap)]
    else:
        segs = _linear_segments(q, net, T)
        weights = [a * dur for dur, _, _ in segs]

    q_end = segs[-1][2]
    for (dur, qa, qb), w in zip(segs, weights):
        stats.queued_token_seconds += 0.5 * (qa + qb) * dur
        stats.slo_violation_seconds += _time_above(qa, qb, dur, slo_q)
        if w > 0.0:
            stats.delay_segments.append((w, qa / c, qb / c))
    # conservation: served = inflow - shed - backlog growth (exact)
    stats.served_tokens = q0 + a * T - stats.shed_tokens - q_end
    stats.q_end = q_end
    return q_end, stats


def _linear_segments(
    q0: float, net: float, T: float
) -> List[Tuple[float, float, float]]:
    """Split a linear backlog q(t) = q0 + net·t (floored at 0) over [0, T]
    into (duration, q_start, q_end) pieces where it is exactly linear."""
    if T <= 0:
        return [(0.0, q0, q0)]
    if net < 0.0 and q0 + net * T < 0.0:
        t_empty = q0 / -net
        return [(t_empty, q0, 0.0), (T - t_empty, 0.0, 0.0)]
    return [(T, q0, q0 + net * T)]


def _time_above(qa: float, qb: float, dur: float, threshold: float) -> float:
    """Seconds a linear segment from qa to qb (over ``dur`` s) spends
    strictly above ``threshold``."""
    if dur <= 0:
        return 0.0
    above_a, above_b = qa > threshold, qb > threshold
    if above_a and above_b:
        return dur
    if not above_a and not above_b:
        return 0.0
    t_cross = dur * (threshold - qa) / (qb - qa)
    return dur - t_cross if above_b else t_cross


@dataclasses.dataclass(frozen=True)
class CapacityEvent:
    """Fleet capacity from ``at_hours`` (wall) onward, tokens/sec."""

    at_hours: float
    tokens_per_sec: float


def route_trace(
    rate_tokens_per_sec: Sequence[float],
    capacity_events: Iterable[CapacityEvent],
    *,
    max_delay_seconds: float,
    shed_delay_seconds: float,
    hours: Optional[float] = None,
) -> RouterStats:
    """Drain an hourly offered-rate trace through a piecewise-constant
    capacity timeline. ``rate_tokens_per_sec[h]`` is the offered rate over
    wall hour ``[h, h+1)``; ``capacity_events`` is a sorted (by time)
    sequence of capacity changes, the first at hour 0. Intervals are split
    at every hour mark and capacity change — closed-form inside each.
    """
    events = sorted(capacity_events, key=lambda e: e.at_hours)
    assert events and events[0].at_hours <= 0.0, "capacity at t=0 required"
    end = float(hours if hours is not None else len(rate_tokens_per_sec))
    # hoist the per-interval element conversions out of the walk: one
    # float array instead of a Sequence __getitem__ + float() per interval
    rate = np.asarray(rate_tokens_per_sec, dtype=float)
    # all boundaries: hour marks + event times
    marks = sorted(
        {float(h) for h in range(int(end) + 1)}
        | {e.at_hours for e in events if 0.0 < e.at_hours < end}
        | {end}
    )
    cap_i = 0
    stats = RouterStats()
    q = 0.0
    rec = obs_current()
    for t0, t1 in zip(marks, marks[1:]):
        if t1 <= t0:
            continue
        while cap_i + 1 < len(events) and events[cap_i + 1].at_hours <= t0 + 1e-12:
            cap_i += 1
        rate_idx = min(int(t0), rate.size - 1)
        q, s = drain_interval(
            q,
            float(rate[rate_idx]),
            events[cap_i].tokens_per_sec,
            (t1 - t0) * SECONDS_PER_HOUR,
            max_delay_seconds=max_delay_seconds,
            shed_delay_seconds=shed_delay_seconds,
        )
        if rec.enabled:
            # one event per closed-form interval: replay re-folds these
            # through RouterStats.add in the same order, so the merged
            # totals land on the Breakdown bit-exactly
            rec.emit(obs_ev.router_interval(t0, t0, t1, s))
            if s.slo_violation_seconds > 0.0:
                rec.emit(obs_ev.SloViolation(t=t0, seconds=s.slo_violation_seconds))
        stats.add(s)
    return stats


def idle_headroom_tokens(
    rate_tokens_per_sec: Sequence[float],
    capacity_events: Iterable[CapacityEvent],
    *,
    hours: Optional[float] = None,
) -> float:
    """Tokens of provisioned capacity the offered trace never used:
    ``∫ max(capacity(t) − offered(t), 0) dt`` over the window, walking the
    exact hour-mark/event-time boundaries of :func:`route_trace`. This is
    the over-provisioning the demand-driven autoscaler exists to shed —
    a statically peak-sized fleet burns it all night."""
    events = sorted(capacity_events, key=lambda e: e.at_hours)
    assert events and events[0].at_hours <= 0.0, "capacity at t=0 required"
    end = float(hours if hours is not None else len(rate_tokens_per_sec))
    rate = np.asarray(rate_tokens_per_sec, dtype=float)
    marks = sorted(
        {float(h) for h in range(int(end) + 1)}
        | {e.at_hours for e in events if 0.0 < e.at_hours < end}
        | {end}
    )
    cap_i = 0
    idle = 0.0
    for t0, t1 in zip(marks, marks[1:]):
        if t1 <= t0:
            continue
        while cap_i + 1 < len(events) and events[cap_i + 1].at_hours <= t0 + 1e-12:
            cap_i += 1
        rate_idx = min(int(t0), rate.size - 1)
        headroom = events[cap_i].tokens_per_sec - float(rate[rate_idx])
        if headroom > 0.0:
            idle += headroom * (t1 - t0) * SECONDS_PER_HOUR
    return idle
