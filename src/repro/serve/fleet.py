"""SLO-aware spot provisioning for a fleet of inference replicas.

The paper admits a BATCH job to a spot market when the market's MTTR
dominates the job's wall time; a serving fleet has no wall time — it runs
until turned off. The serving analogue (Qu et al., *A Reliable and
Cost-Efficient Auto-Scaling System for Web Applications Using
Heterogeneous Spot Instances*) is availability from market diversity:

* **footprint** — a replica holds params + KV cache at the configured
  batch/context (``dist.meshplan.serve_state_bytes``), never optimizer
  state, so suitability runs the same ``find_suitable_allocations`` path
  as training with a strictly smaller memory requirement;
* **admission** — a market is admitted when its MTTR dominates a *rolling
  SLO horizon* (``lifetime_factor × slo_horizon_hours``), the window over
  which the operator promises the SLO, instead of a job length. The
  horizon is WALL clock: a faster shape does not shrink its exposure the
  way it shrinks a batch job's, so admission deliberately does not divide
  by throughput;
* **diversity** — replicas spread across low-correlation markets
  (``find_low_correlation``): one zone-wide price spike may take one
  replica, never the fleet. Capacity is sized so the aggregate tokens/sec
  meets the target with ``capacity_headroom``;
* **revocation** — a revoked replica is a params-only migration onto a
  replacement shape (``repro.serve.migrate``); the dead replica's load
  re-routes to the survivors through the open-loop router until the
  replacement is live. No checkpoints, no standby over-replication.

Per-replica billing runs through ``core.accounting``, one session per
replica tenure: each replica's whole-hour billing cycles start at its own
provisioning instant (naturally staggered clocks — a repair bills only
its own partial hours), and ``Breakdown.leg_cost`` decomposes the fleet
bill exactly. The explicit ``leg_anchors``/``leg_releases`` machinery is
the multi-leg-session form of the same rule, used by the training
orchestrator's split-repair path.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import provisioner as alg
from repro.core.accounting import Breakdown, PriceTable, Session, bill_session
from repro.obs import events as obs_ev
from repro.obs.recorder import current as obs_current
from repro.core.allocation import Allocation
from repro.core.market import MarketSet, next_revocation_table, shape_throughput
from repro.core.policies import Job, OverheadModel, SiwoftPolicy
from repro.core.units import SECONDS_PER_HOUR
from repro.serve.autoscale import AutoscalePolicy, AutoScaler
from repro.serve.migrate import CACHE_POLICIES, MigrationCost, migration_cost
from repro.serve.router import (
    CapacityEvent,
    RouterStats,
    idle_headroom_tokens,
    route_trace,
)

#: measured-throughput correction hook: allocation → multiplicative factor
RateCorrection = Callable[[Allocation], float]


@dataclasses.dataclass(frozen=True)
class ServePolicy:
    """Fleet provisioning + SLO knobs (the serving face of SiwoftPolicy)."""

    name: str = "serve_fleet"
    lifetime_factor: float = 2.0          # MTTR ≥ factor × SLO horizon
    slo_horizon_hours: float = 24.0       # rolling horizon the SLO covers
    correlation_threshold: float = 0.2    # pairwise spread across replicas
    cache_policy: str = "drop"            # "drop" | "migrate" (migrate.py)
    capacity_headroom: float = 1.1        # provision target × headroom
    # N-1 sizing: keep adding replicas until the fleet still meets the raw
    # target with its LARGEST replica gone — one revocation (the failure
    # unit the MTTR admission prices) must not break the SLO while the
    # params-only repair migrates in. This is capacity planning, not
    # standby over-replication: every replica serves traffic.
    survive_one_loss: bool = True
    max_replicas: int = 32
    max_legs: int = 2                     # split replicas when none fits
    # SLO definition the router enforces
    max_delay_seconds: float = 30.0       # queueing delay above = violation
    shed_delay_seconds: float = 120.0     # clients abandon past this

    def __post_init__(self):
        assert self.cache_policy in CACHE_POLICIES, self.cache_policy

    def as_siwoft(self) -> SiwoftPolicy:
        """The SiwoftPolicy the shared Alg.-1 primitives consume."""
        return SiwoftPolicy(
            lifetime_factor=self.lifetime_factor,
            correlation_threshold=self.correlation_threshold,
            max_legs=self.max_legs,
        )


@dataclasses.dataclass(frozen=True)
class ServingWorkload:
    """What the fleet must deliver and what one replica costs to hold.

    ``replica_tokens_per_sec`` is the decode rate of a replica on the
    1-device reference shape; a replica on an allocation with relative
    throughput θ delivers ``θ ×`` that (``shape_throughput`` — corrected
    online by a ``ThroughputTracker`` when one is wired in).
    """

    target_tokens_per_sec: float
    replica_tokens_per_sec: float
    state_gb: float                 # serving footprint: params + KV cache
    param_bytes: int                # migration pricing (params move)
    cache_bytes: int = 0            # migration pricing (cache per policy)
    prefill_tokens_per_sec: float = 0.0   # 0 -> 8× the decode rate
    inflight_context_tokens: float = 0.0  # re-prefilled on a cache drop

    @property
    def prefill_rate(self) -> float:
        return self.prefill_tokens_per_sec or 8.0 * self.replica_tokens_per_sec


@dataclasses.dataclass(frozen=True)
class Replica:
    replica_id: int
    allocation: Allocation
    tokens_per_sec: float


@dataclasses.dataclass
class FleetPlan:
    replicas: List[Replica]
    relaxed_correlation: bool = False  # diversity filter had to be relaxed

    @property
    def capacity_tokens_per_sec(self) -> float:
        return sum(r.tokens_per_sec for r in self.replicas)

    @property
    def markets(self) -> Tuple[int, ...]:
        return tuple(m for r in self.replicas for m in r.allocation.markets)


def replica_rate(
    workload: ServingWorkload,
    feats: alg.MarketFeatures,
    alloc: Allocation,
    correction: float = 1.0,
) -> float:
    """Tokens/sec a replica on ``alloc`` delivers: the reference decode
    rate scaled by the allocation's relative throughput (analytic or
    measured, see ``MarketFeatures.throughput``), times a measured
    correction (``ThroughputTracker.correction``) when available."""
    return (
        workload.replica_tokens_per_sec
        * alg.allocation_throughput(alloc, feats)
        * max(float(correction), 1e-9)
    )


def _admitted(
    workload: ServingWorkload,
    feats: alg.MarketFeatures,
    policy: ServePolicy,
    exclude: Set[int],
    rate_correction: Optional[RateCorrection] = None,
) -> List[Allocation]:
    """Suitable allocations whose MTTR dominates the rolling SLO horizon,
    cheapest-per-delivered-token first.

    Suitability reuses the training split search (a serving replica whose
    params fit no single shape splits over DCN like a training job); the
    admission test deliberately replaces the job-wall-time comparison with
    the wall-clock horizon — serving exposure does not shrink on faster
    shapes."""
    job = Job(length_hours=policy.slo_horizon_hours, memory_gb=workload.state_gb)
    cands = alg.find_suitable_allocations(
        job, feats, policy.as_siwoft(), exclude=exclude
    )
    floor = policy.lifetime_factor * policy.slo_horizon_hours
    admitted = [a for a in cands if alg.allocation_mttr(a, feats) >= floor]
    pool = admitted if admitted else cands  # Alg.-1 fallback discipline
    corr = rate_correction if rate_correction is not None else (lambda a: 1.0)
    return sorted(
        pool,
        key=lambda a: (
            alg.allocation_price(a, feats)
            / max(replica_rate(workload, feats, a, corr(a)), 1e-9),
            a.markets,
        ),
    )


def _diverse(
    alloc: Allocation,
    placed: Sequence[int],
    feats: alg.MarketFeatures,
    policy: ServePolicy,
) -> bool:
    """Every leg of ``alloc`` co-revokes below the threshold with every
    market the fleet already holds — find_low_correlation semantics, so
    one spike cannot take two replicas."""
    if not placed:
        return True
    W = alg.find_low_correlation(
        feats, placed[0], policy, surviving=tuple(placed[1:])
    )
    return all(m in W for m in alloc.markets)


def provision_fleet(
    workload: ServingWorkload,
    feats: alg.MarketFeatures,
    policy: ServePolicy,
    *,
    exclude: Set[int] = frozenset(),
    existing: Sequence[Replica] = (),
    rate_correction: Optional[RateCorrection] = None,
) -> FleetPlan:
    """Size and place the fleet: admitted allocations, cheapest per
    delivered token first, each low-correlated with everything already
    placed, until the aggregate capacity covers target × headroom.

    With ``survive_one_loss`` (default) sizing continues past the target
    until the fleet minus its largest replica still covers the RAW target
    — the N-1 bar a single revocation must not break while its repair
    migrates in. If the diversity filter starves the pool before the
    target is met, it is relaxed (same refill discipline as Alg. 1 step
    13) and the plan is flagged ``relaxed_correlation`` — capacity beats
    purity, but the operator can see the compromise.

    ``existing`` is the autoscaler's incremental form: replicas the fleet
    already holds count toward both sizing bars, the diversity filter,
    and ``max_replicas``, and the returned plan contains only the NEW
    replicas (empty when the existing fleet already satisfies the bars).
    ``rate_correction`` (allocation → factor) applies a measured
    ``ThroughputTracker`` correction to every candidate's rate, so
    ranking and sizing consume real decode speed instead of the analytic
    ``n^α`` when a tracker is wired in."""
    target = workload.target_tokens_per_sec * policy.capacity_headroom
    corr = rate_correction if rate_correction is not None else (lambda a: 1.0)

    def satisfied(reps: Sequence[Replica]) -> bool:
        rates = [r.tokens_per_sec for r in existing] + [
            r.tokens_per_sec for r in reps
        ]
        cap = sum(rates)
        if cap < target:
            return False
        if policy.survive_one_loss and rates:
            worst = max(rates)
            if cap - worst < workload.target_tokens_per_sec:
                return False
        return True

    replicas: List[Replica] = []
    used: Set[int] = set(exclude) | {
        m for r in existing for m in r.allocation.markets
    }
    relaxed = False
    for strict in (True, False):
        cands = _admitted(workload, feats, policy, used, rate_correction)
        for a in cands:
            if len(existing) + len(replicas) >= policy.max_replicas:
                break
            if satisfied(replicas):
                break
            if any(m in used for m in a.markets):
                continue
            placed = [
                m for r in existing for m in r.allocation.markets
            ] + [m for r in replicas for m in r.allocation.markets]
            if strict and not _diverse(a, placed, feats, policy):
                continue
            if not strict:
                relaxed = True
            replicas.append(
                Replica(
                    len(replicas), a, replica_rate(workload, feats, a, corr(a))
                )
            )
            used.update(a.markets)
        if satisfied(replicas):
            break
    if not replicas and not existing:
        raise ValueError(
            f"no admitted allocation fits a {workload.state_gb} GB replica"
        )
    return FleetPlan(replicas=replicas, relaxed_correlation=relaxed)


def repair_fleet(
    workload: ServingWorkload,
    feats: alg.MarketFeatures,
    policy: ServePolicy,
    *,
    revoked_market: int,
    survivors: Sequence[int],
    exclude: Set[int],
    lost: Replica,
    rate_correction: Optional[RateCorrection] = None,
) -> Optional[Replica]:
    """Replacement for one revoked replica: low-correlated with the
    revoked market AND every surviving replica (step-13 semantics),
    admitted against the rolling horizon, preferring the lost replica's
    device shape (a same-shape replacement reuses the compiled serving
    step — the params-only reshard is the whole migration)."""
    used = set(exclude) | set(survivors) | {revoked_market}
    cands = _admitted(workload, feats, policy, used, rate_correction)
    W = alg.find_low_correlation(
        feats, revoked_market, policy, surviving=tuple(survivors)
    )
    diverse = [a for a in cands if all(m in W for m in a.markets)]
    pool = diverse if diverse else cands
    if not pool:
        return None
    corr = rate_correction if rate_correction is not None else (lambda a: 1.0)
    lost_shape = lost.allocation.device_counts
    best = min(
        pool,
        key=lambda a: (
            0 if a.device_counts == lost_shape else 1,
            alg.allocation_price(a, feats)
            / max(replica_rate(workload, feats, a, corr(a)), 1e-9),
            a.markets,
        ),
    )
    return Replica(
        lost.replica_id, best, replica_rate(workload, feats, best, corr(best))
    )


# ---------------------------------------------------------------------------
# Fleet simulation on replayable price traces
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FleetReport:
    breakdown: Breakdown
    router: RouterStats
    revocations: int
    repairs: int
    migrated_bytes: int            # params(+cache) over DCN, fleet policy
    restored_bytes: int            # full serving state through storage
    replicas_provisioned: int
    markets_used: List[int]
    capacity_tokens_per_sec: float
    relaxed_correlation: bool = False
    # demand-driven sizing counters (0 for every static-sized policy)
    scale_ups: int = 0
    scale_downs: int = 0
    #: tokens of capacity the offered trace never used — what a
    #: peak-sized fleet burns at night and the autoscaler exists to shed
    idle_headroom_tokens: float = 0.0

    @property
    def cost_dollars(self) -> float:
        return self.breakdown.total_cost

    @property
    def slo_violation_seconds(self) -> float:
        return self.breakdown.time["slo_violation"] * SECONDS_PER_HOUR

    @property
    def p50_delay_seconds(self) -> float:
        return self.router.p50_delay_seconds

    @property
    def p99_delay_seconds(self) -> float:
        return self.router.p99_delay_seconds


class FleetSimulator:
    """Drive a fleet through a future price trace, deterministically.

    ``mode="fleet"`` — the tentpole policy: SLO-horizon admission,
    correlation spread, params-only migration repair.
    ``mode="static"`` — the over-replication baseline: capacity ×
    ``policy.capacity_headroom`` on the cheapest suitable spot markets
    with NO market intelligence (no MTTR admission, no correlation
    spread); a revoked replica is replaced after a FULL serving-state
    restore through remote storage (what running today's serve.py behind
    an autoscaler amounts to).

    ``throughput_mode`` selects where the reference replica rate comes
    from: ``"analytic"`` (default) uses the workload's closed-form
    ``replica_tokens_per_sec``; ``"engine"`` replaces it with
    ``measured_tokens_per_sec`` — the tokens/sec a real
    :class:`repro.serve.engine.DecodeEngine` measured on the reference
    shape — so provisioning, N-1 sizing, and the router all consume the
    engine's observed rate. With a measured rate equal to the analytic
    reference the two modes produce identical reports (pinned in
    tests/test_serve_fleet.py), so the analytic baseline stays bit-exact.

    ``sizing`` selects WHEN capacity is sized: ``"static"`` (default, the
    byte-exact pinned baseline) sizes once to the workload's fixed target
    and only repairs revocations; ``"auto"`` walks the demand trace with
    an :class:`repro.serve.autoscale.AutoScaler` — scale-up ahead of
    forecast ramps, scale-down (cheapest-kept-first retirement) under the
    low-water mark after a cooldown, and demand-driven repair: a revoked
    replica is replaced only if the remaining fleet no longer clears the
    CURRENT interval's bars, not unconditionally. ``sizing="auto"``
    requires ``mode="fleet"``.
    """

    def __init__(
        self,
        history: MarketSet,
        future: MarketSet,
        workload: ServingWorkload,
        policy: ServePolicy,
        overheads: OverheadModel = OverheadModel(),
        *,
        mode: str = "fleet",
        sizing: str = "static",
        autoscale: Optional[AutoscalePolicy] = None,
        tracker=None,  # Optional[dist.meshplan.ThroughputTracker]
        throughput_mode: str = "analytic",
        measured_tokens_per_sec: Optional[float] = None,
    ):
        assert mode in ("fleet", "static")
        assert sizing in ("static", "auto")
        assert throughput_mode in ("analytic", "engine")
        if sizing == "auto" and mode != "fleet":
            raise ValueError("sizing='auto' requires mode='fleet'")
        if throughput_mode == "engine":
            if not measured_tokens_per_sec or measured_tokens_per_sec <= 0:
                raise ValueError(
                    "throughput_mode='engine' needs a positive "
                    "measured_tokens_per_sec from a DecodeEngine"
                )
            workload = dataclasses.replace(
                workload,
                replica_tokens_per_sec=float(measured_tokens_per_sec),
            )
        self.throughput_mode = throughput_mode
        self.feats = alg.MarketFeatures.from_history(history)
        self.future = future
        self.workload = workload
        self.policy = policy
        self.ov = overheads
        self.mode = mode
        self.sizing = sizing
        self.autoscale = autoscale if autoscale is not None else AutoscalePolicy()
        self.tracker = tracker
        self._rev = future.revocation_matrix()
        # vectorized trace indexes (one O(markets × hours) pass each):
        # next-revocation suffix table for O(1) "when does this leg die?"
        # queries, and an hour -> revoking-market-set map so the hourly
        # loops touch Python only on event hours
        self._next_rev = next_revocation_table(self._rev)
        self._rev_hours: dict = {}
        for m, h in zip(*np.nonzero(self._rev)):
            self._rev_hours.setdefault(int(h), set()).add(int(m))
        # with a tracker wired in, provisioning itself consumes measured
        # rates (ranking, sizing bars, Replica.tokens_per_sec); without
        # one the analytic model stands and the hook stays None so the
        # pinned baselines keep their exact float expressions
        self._corr: Optional[RateCorrection] = (
            self._rate_correction if tracker is not None else None
        )

    # -- static-baseline provisioning (no market intelligence) ----------
    def _provision_static(self, exclude: Set[int]) -> FleetPlan:
        job = Job(
            length_hours=self.policy.slo_horizon_hours,
            memory_gb=self.workload.state_gb,
        )
        cands = [
            Allocation.single(i, int(self.feats.device_count[i]))
            for i in alg.find_suitable_servers(job, self.feats)
            if i not in exclude
        ]
        cands.sort(key=lambda a: (float(self.feats.avg_price[a.legs[0].market]),
                                  a.markets))
        target = (
            self.workload.target_tokens_per_sec * self.policy.capacity_headroom
        )
        corr = self._corr if self._corr is not None else (lambda a: 1.0)
        replicas: List[Replica] = []
        used = set(exclude)
        for a in cands:
            if sum(r.tokens_per_sec for r in replicas) >= target:
                break
            if len(replicas) >= self.policy.max_replicas:
                break
            if any(m in used for m in a.markets):
                continue
            replicas.append(
                Replica(
                    len(replicas),
                    a,
                    replica_rate(self.workload, self.feats, a, corr(a)),
                )
            )
            used.update(a.markets)
        if not replicas:
            raise ValueError("static baseline: no suitable market")
        return FleetPlan(replicas=replicas)

    def _rate_correction(self, alloc: Allocation) -> float:
        """Measured-vs-analytic correction for the allocation's mesh-plan
        key, when a ThroughputTracker from a real serving loop is wired
        in; 1.0 (analytic model stands) otherwise.

        Same anchoring convention as the training orchestrator: the
        analytic table covers EVERY observed plan key at the reference
        bandwidth (the tracker's ratio corrects deviation from the
        scaling LAW; the bandwidth-aware base value lives in the replica
        rate itself), and the corrected rate is capped at the model's
        sublinear ceiling so no calibration can claim superlinear
        scaling."""
        if self.tracker is None:
            return 1.0
        from repro.core.market import THROUGHPUT_EFFICIENCY_CEIL
        from repro.dist.meshplan import mesh_shape_for

        n = alloc.total_devices
        key = (n, mesh_shape_for(n))
        analytic = {k: shape_throughput(k[0]) for k in self.tracker.measured}
        analytic[key] = shape_throughput(n)
        corr = self.tracker.correction(key, analytic)
        base = alg.allocation_throughput(alloc, self.feats)
        cap = float(n) ** THROUGHPUT_EFFICIENCY_CEIL
        return min(corr, cap / max(base, 1e-9))

    def _next_revocation_hour(self, alloc: Allocation, wall: float) -> Optional[int]:
        h0 = int(math.ceil(wall))
        if h0 < 0:
            h0 = 0
        if h0 >= self._next_rev.shape[1]:
            return None
        best = None
        for m in alloc.markets:
            h = int(self._next_rev[m, h0])
            if h >= 0:
                best = h if best is None else min(best, h)
        return best

    def run(
        self,
        hours: float,
        rate_tokens_per_sec: Sequence[float],
    ) -> FleetReport:
        """Serve ``rate_tokens_per_sec`` (offered tokens/sec per trace
        hour) for ``hours`` trace hours under revocations. With
        ``sizing="auto"`` the demand-driven loop runs instead."""
        if self.sizing == "auto":
            return self._run_auto(hours, rate_tokens_per_sec)
        wl, policy, ov = self.workload, self.policy, self.ov
        rec = obs_current()
        if rec.enabled:
            rec.emit(
                obs_ev.RunStart(
                    t=0.0,
                    subsystem="fleet",
                    label=f"{self.mode}/static",
                    horizon_hours=float(hours),
                )
            )
            rec.emit(obs_ev.price_trace(0.0, self.future.prices))
        bd = Breakdown()
        price = PriceTable(self.future.prices)
        if self.mode == "fleet":
            plan = provision_fleet(
                wl, self.feats, policy, rate_correction=self._corr
            )
        else:
            plan = self._provision_static(set())
        revocations = repairs = 0
        migrated = restored = 0
        markets_used: List[int] = list(plan.markets)
        n_provisioned = len(plan.replicas)
        revoked: Set[int] = set()

        # live set: (replica, provisioned_at, live_from, session). Sessions
        # stay open until the replica dies or the simulation ends;
        # billing-cycle anchors stagger at each replica's own provisioning
        # instant. The capacity timeline is built from (time, delta) pairs
        # and prefix-summed after sorting — a replica revoked before its
        # startup completes cancels its own pending capacity exactly.
        live: List[Tuple[Replica, float, float, Session]] = []
        cap_deltas: List[Tuple[float, float]] = []

        def start_replica(
            rep: Replica,
            at: float,
            mig: Optional[MigrationCost] = None,
            restore_hours: float = 0.0,
        ):
            # one session per replica tenure, anchored (whole-hour cycles
            # and all) at its own provisioning instant — replicas bill on
            # naturally staggered clocks. ``mig`` is the fleet policy's
            # live migration (reshard wire time + re-prefill recompute);
            # ``restore_hours`` is the static baseline's full-state pull
            # through remote storage, billed to ``recovery`` like every
            # other storage restore in the repo.
            s = Session(
                rep.allocation.legs[0].market, at, legs=rep.allocation.markets
            )
            s.add("startup", ov.startup_hours)
            delay = ov.startup_hours
            if mig is not None:
                s.add("reshard", mig.wire_hours)
                s.add("re_execution", mig.recompute_hours)
                delay += mig.hours
            if restore_hours > 0:
                s.add("recovery", restore_hours)
                delay += restore_hours
            # a tracker-backed correction is already in the provisioned
            # rate (self._corr); re-derive it here only on the legacy
            # tracker-less path, where it is exactly 1.0
            corr = (
                1.0
                if self._corr is not None
                else self._rate_correction(rep.allocation)
            )
            rate = rep.tokens_per_sec * corr
            if rec.enabled:
                rec.emit(
                    obs_ev.Provision(
                        t=at,
                        market_id=int(rep.allocation.legs[0].market),
                        legs=tuple(int(m) for m in rep.allocation.markets),
                        replica_id=int(rep.replica_id),
                        rate_tokens_per_sec=rate,
                    )
                )
                if mig is not None:
                    rec.emit(obs_ev.ReshardStart(t=at, bytes_moved=int(mig.moved_bytes)))
                    rec.emit(obs_ev.ReshardDone(t=at + mig.wire_hours, hours=mig.wire_hours))
            live.append(
                (dataclasses.replace(rep, tokens_per_sec=rate), at, at + delay, s)
            )
            cap_deltas.append((at + delay, rate))

        for rep in plan.replicas:
            start_replica(rep, 0.0, None)

        # -- event loop: earliest next revocation among live replicas ----
        for _ in range(10_000):
            nxt: Optional[Tuple[int, int, int]] = None  # (hour, idx, market)
            for i, (rep, t0, _, _) in enumerate(live):
                h = self._next_revocation_hour(rep.allocation, t0)
                if h is not None and h < hours and (nxt is None or h < nxt[0]):
                    m = next(
                        m for m in rep.allocation.markets if self._rev[m, h]
                    )
                    nxt = (h, i, m)
            if nxt is None:
                break
            h, i, rev_market = nxt
            rep, t0, t_live, session = live.pop(i)
            revocations += 1
            revoked.add(rev_market)
            if rec.enabled:
                rec.emit(
                    obs_ev.Revoke(
                        t=float(h),
                        market_id=int(rev_market),
                        replica_id=int(rep.replica_id),
                    )
                )
            # the dead replica served until the revocation hour; its
            # tenure ends there and its own cycles settle (whole-hour
            # billing per spot request — same proxy as the batch paper)
            session.add("execution", max(h - t0 - session.used_hours, 0.0))
            if rec.enabled:
                rec.emit(obs_ev.session_billed(float(h), session))
            bill_session(session, price, bd)
            # capacity leaves when the replica dies — or never arrives, if
            # it died mid-startup (the -delta lands on the +delta's time)
            cap_deltas.append((max(float(h), t_live), -rep.tokens_per_sec))
            # survivors absorb the load (the router sees the capacity
            # dip); a replacement migrates in params-only
            survivors = [m for r, _, _, _ in live for m in r.allocation.markets]
            if self.mode == "fleet":
                newrep = repair_fleet(
                    wl, self.feats, policy,
                    revoked_market=rev_market,
                    survivors=survivors,
                    exclude=revoked,
                    lost=rep,
                    rate_correction=self._corr,
                )
                if newrep is not None:
                    mig = migration_cost(
                        param_bytes=wl.param_bytes,
                        cache_bytes=wl.cache_bytes,
                        cache_policy=policy.cache_policy,
                        dcn_gbps=newrep.allocation.dcn_gbps,
                        inflight_context_tokens=wl.inflight_context_tokens,
                        prefill_tokens_per_sec=wl.prefill_rate
                        * alg.allocation_throughput(newrep.allocation, self.feats),
                    )
                    migrated += mig.moved_bytes
                    repairs += 1
                    n_provisioned += 1
                    markets_used.extend(newrep.allocation.markets)
                    start_replica(newrep, float(h), mig)
            else:
                # static baseline: full serving state back through storage
                newplan = None
                try:
                    newplan = self._provision_static(
                        revoked | {m for m in survivors}
                    )
                except ValueError:
                    pass
                if newplan is not None and newplan.replicas:
                    newrep = dataclasses.replace(
                        newplan.replicas[0], replica_id=rep.replica_id
                    )
                    restored += wl.param_bytes + wl.cache_bytes
                    repairs += 1
                    n_provisioned += 1
                    markets_used.extend(newrep.allocation.markets)
                    start_replica(
                        newrep, float(h),
                        restore_hours=ov.restore_hours(wl.state_gb),
                    )

        # -- drain to the end of the window, settle every open session ---
        for _rep, t0, _, session in live:
            session.add("execution", max(hours - t0 - session.used_hours, 0.0))
            if rec.enabled:
                rec.emit(obs_ev.session_billed(float(hours), session))
            bill_session(session, price, bd)

        # prefix-sum the sorted deltas into the absolute-capacity timeline
        cap_events: List[CapacityEvent] = [CapacityEvent(0.0, 0.0)]
        level = 0.0
        for at, delta in sorted(cap_deltas):
            level += delta
            cap_events.append(CapacityEvent(at, max(level, 0.0)))

        stats = route_trace(
            rate_tokens_per_sec,
            cap_events,
            max_delay_seconds=policy.max_delay_seconds,
            shed_delay_seconds=policy.shed_delay_seconds,
            hours=hours,
        )
        stats.merge_into(bd)
        bd.revocations = revocations
        bd.wall_time = float(hours)
        if rec.enabled:
            rec.emit(obs_ev.breakdown_pin(float(hours), bd))
            rec.emit(obs_ev.RunEnd(t=float(hours), wall_hours=float(hours)))
        return FleetReport(
            breakdown=bd,
            router=stats,
            revocations=revocations,
            repairs=repairs,
            migrated_bytes=migrated,
            restored_bytes=restored,
            replicas_provisioned=n_provisioned,
            markets_used=markets_used,
            capacity_tokens_per_sec=plan.capacity_tokens_per_sec,
            relaxed_correlation=plan.relaxed_correlation,
            idle_headroom_tokens=idle_headroom_tokens(
                rate_tokens_per_sec, cap_events, hours=hours
            ),
        )

    # -- demand-driven sizing (the autoscaler loop) ----------------------
    def _run_auto(
        self,
        hours: float,
        rate_tokens_per_sec: Sequence[float],
    ) -> FleetReport:
        """Hour-driven demand loop: every trace hour the scaler forecasts
        the offered load, and the fleet is resized against the SAME bars
        ``provision_fleet`` enforces — scale-up ahead of ramps (never
        cooldown-gated), scale-down of the worst $/token replicas under
        the low-water mark (cooldown-gated, floored at the live offered
        rate and ``min_replicas``), and demand-driven repair: a revoked
        replica is replaced only when the survivors no longer clear the
        current target. Billing, migration pricing, and routing reuse the
        static loop's primitives unchanged — a scale-up replica is a
        params-only wire migration from the survivors (no in-flight
        contexts to re-prefill: it joins empty), a scale-down settles the
        retiree's session at the decision instant, and its in-flight
        streams drain to the survivors (``autoscale.drain_replica`` is
        the engine-level form, token-identical by the shed→resume pin).
        """
        wl, policy, ov = self.workload, self.policy, self.ov
        rec = obs_current()
        if rec.enabled:
            rec.emit(
                obs_ev.RunStart(
                    t=0.0,
                    subsystem="fleet",
                    label=f"{self.mode}/auto",
                    horizon_hours=float(hours),
                )
            )
            rec.emit(obs_ev.price_trace(0.0, self.future.prices))
        bd = Breakdown()
        price = PriceTable(self.future.prices)
        scaler = AutoScaler(
            self.autoscale,
            capacity_headroom=policy.capacity_headroom,
            survive_one_loss=policy.survive_one_loss,
        )
        revocations = repairs = 0
        migrated = restored = 0
        markets_used: List[int] = []
        n_provisioned = 0
        relaxed = False
        peak_capacity = 0.0
        revoked: Set[int] = set()
        next_id = 0

        live: List[Tuple[Replica, float, float, Session]] = []
        cap_deltas: List[Tuple[float, float]] = []

        def start_replica(rep: Replica, at: float, mig: Optional[MigrationCost]):
            nonlocal next_id, n_provisioned
            s = Session(
                rep.allocation.legs[0].market, at, legs=rep.allocation.markets
            )
            s.add("startup", ov.startup_hours)
            delay = ov.startup_hours
            if mig is not None:
                s.add("reshard", mig.wire_hours)
                s.add("re_execution", mig.recompute_hours)
                delay += mig.hours
            rep = dataclasses.replace(rep, replica_id=next_id)
            next_id += 1
            n_provisioned += 1
            markets_used.extend(rep.allocation.markets)
            if rec.enabled:
                rec.emit(
                    obs_ev.Provision(
                        t=at,
                        market_id=int(rep.allocation.legs[0].market),
                        legs=tuple(int(m) for m in rep.allocation.markets),
                        replica_id=int(rep.replica_id),
                        rate_tokens_per_sec=rep.tokens_per_sec,
                    )
                )
                if mig is not None:
                    rec.emit(obs_ev.ReshardStart(t=at, bytes_moved=int(mig.moved_bytes)))
                    rec.emit(obs_ev.ReshardDone(t=at + mig.wire_hours, hours=mig.wire_hours))
            live.append((rep, at, at + delay, s))
            cap_deltas.append((at + delay, rep.tokens_per_sec))

        def settle_replica(idx: int, at: float) -> Replica:
            rep, t0, t_live, session = live.pop(idx)
            session.add("execution", max(at - t0 - session.used_hours, 0.0))
            if rec.enabled:
                rec.emit(obs_ev.session_billed(at, session))
            bill_session(session, price, bd)
            # capacity leaves at the decision instant — or never arrives,
            # if the replica dies mid-startup
            cap_deltas.append((max(at, t_live), -rep.tokens_per_sec))
            return rep

        def scale_up(at: float, target: float, extra_exclude: Set[int]) -> bool:
            nonlocal migrated, relaxed
            wl_t = dataclasses.replace(wl, target_tokens_per_sec=target)
            holding = [r for r, _, _, _ in live]
            try:
                plan = provision_fleet(
                    wl_t, self.feats, policy,
                    exclude=revoked | extra_exclude,
                    existing=holding,
                    rate_correction=self._corr,
                )
            except ValueError:
                return False  # pool starved: best effort, router bills it
            for newrep in plan.replicas:
                mig = None
                if live:
                    # survivors hold the params: a new replica is a
                    # params-only wire migration; it joins with no
                    # in-flight contexts, so nothing is re-prefilled
                    mig = migration_cost(
                        param_bytes=wl.param_bytes,
                        cache_bytes=0,
                        cache_policy="drop",
                        dcn_gbps=newrep.allocation.dcn_gbps,
                    )
                    migrated += mig.moved_bytes
                start_replica(newrep, at, mig)
            relaxed = relaxed or plan.relaxed_correlation
            return bool(plan.replicas)

        def scale_down(at: float, target: float) -> bool:
            def dollars_per_token(rep: Replica) -> float:
                return alg.allocation_price(rep.allocation, self.feats) / max(
                    rep.tokens_per_sec, 1e-9
                )

            retired = False
            while len(live) > self.autoscale.min_replicas:
                idx = max(
                    range(len(live)),
                    key=lambda i: (
                        dollars_per_token(live[i][0]),
                        live[i][0].allocation.markets,
                    ),
                )
                trial = [
                    r.tokens_per_sec
                    for j, (r, _, _, _) in enumerate(live)
                    if j != idx
                ]
                if not scaler.satisfied(trial, target):
                    break
                settle_replica(idx, at)
                retired = True
            return retired

        # initial fleet, sized to hour 0's forecast (a cold start has no
        # survivors to migrate params from)
        fc0 = scaler.forecast(rate_tokens_per_sec, 0)
        offered0 = (
            float(rate_tokens_per_sec[0]) if len(rate_tokens_per_sec) else 0.0
        )
        target0 = max(fc0, offered0)
        scale_up(0.0, target0, self._revoking_at(0))
        scaler.record(0.0, "init")  # arms the cooldown, not a scale event

        # offered-rate lookups batched once: the hourly loop reads a plain
        # float array instead of converting a sequence element per hour
        offered = np.asarray(rate_tokens_per_sec, dtype=float)
        n_hours = int(hours)
        # sanctioned hourly DECISION loop: the scaler's verdict is
        # genuinely sequential (cooldowns, in-flight floor); the per-hour
        # trace lookups it consumes are precomputed arrays/maps
        for h in range(n_hours):  # repro-lint: disable=V001
            now = float(h)
            # 1) revocations landing this hour (same trace semantics as
            # the static loop: market m revokes at hour h)
            revoking = self._revoking_at(h)
            for i in reversed(range(len(live))):
                rep = live[i][0]
                hit = [m for m in rep.allocation.markets if m in revoking]
                if hit:
                    if rec.enabled:
                        rec.emit(
                            obs_ev.Revoke(
                                t=now,
                                market_id=int(hit[0]),
                                replica_id=int(rep.replica_id),
                            )
                        )
                    settle_replica(i, now)
                    revocations += 1
                    revoked.update(hit)
            # 2) the scaler's verdict for this interval
            offered_now = (
                float(offered[min(h, offered.size - 1)]) if offered.size else 0.0
            )
            fc = scaler.forecast(rate_tokens_per_sec, h)
            live_rates = [r.tokens_per_sec for r, _, _, _ in live]
            decision = scaler.decide(
                now,
                live_rates,
                forecast=fc,
                offered_now=offered_now,
            )
            if rec.enabled:
                # the scaler's full input vector, so a trace answers "what
                # did it see when it scaled" without rerunning the fleet
                rec.emit(
                    obs_ev.ScaleDecision(
                        t=now,
                        kind=decision.kind,
                        offered_tokens_per_sec=offered_now,
                        forecast_tokens_per_sec=fc,
                        capacity_tokens_per_sec=sum(live_rates),
                        target_tokens_per_sec=decision.target_tokens_per_sec,
                    )
                )
            if decision.kind == "up":
                # demand-driven repair and ramp scale-up are the same
                # move: add capacity until the bars clear again
                n_before = len(live)
                grew = scale_up(
                    now, decision.target_tokens_per_sec, revoking
                )
                if grew:
                    if rec.enabled:
                        rec.emit(
                            obs_ev.ScaleUp(
                                t=now,
                                added=len(live) - n_before,
                                target_tokens_per_sec=decision.target_tokens_per_sec,
                            )
                        )
                    if revoking:
                        repairs += 1
                    scaler.record(now, "up")
            elif decision.kind == "down":
                n_before = len(live)
                if scale_down(now, decision.target_tokens_per_sec):
                    if rec.enabled:
                        rec.emit(
                            obs_ev.ScaleDown(
                                t=now,
                                retired=n_before - len(live),
                                target_tokens_per_sec=decision.target_tokens_per_sec,
                            )
                        )
                    scaler.record(now, "down")
            peak_capacity = max(
                peak_capacity, sum(r.tokens_per_sec for r, _, _, _ in live)
            )

        # drain to the end of the window, settle every open session
        for _rep, t0, _, session in live:
            session.add("execution", max(hours - t0 - session.used_hours, 0.0))
            if rec.enabled:
                rec.emit(obs_ev.session_billed(float(hours), session))
            bill_session(session, price, bd)

        cap_events: List[CapacityEvent] = [CapacityEvent(0.0, 0.0)]
        level = 0.0
        for at, delta in sorted(cap_deltas):
            level += delta
            cap_events.append(CapacityEvent(at, max(level, 0.0)))

        stats = route_trace(
            rate_tokens_per_sec,
            cap_events,
            max_delay_seconds=policy.max_delay_seconds,
            shed_delay_seconds=policy.shed_delay_seconds,
            hours=hours,
        )
        stats.merge_into(bd)
        bd.revocations = revocations
        bd.wall_time = float(hours)
        if rec.enabled:
            rec.emit(obs_ev.breakdown_pin(float(hours), bd))
            rec.emit(obs_ev.RunEnd(t=float(hours), wall_hours=float(hours)))
        return FleetReport(
            breakdown=bd,
            router=stats,
            revocations=revocations,
            repairs=repairs,
            migrated_bytes=migrated,
            restored_bytes=restored,
            replicas_provisioned=n_provisioned,
            markets_used=markets_used,
            capacity_tokens_per_sec=peak_capacity,
            relaxed_correlation=relaxed,
            scale_ups=scaler.scale_ups,
            scale_downs=scaler.scale_downs,
            idle_headroom_tokens=idle_headroom_tokens(
                rate_tokens_per_sec, cap_events, hours=hours
            ),
        )

    def _revoking_at(self, hour: int) -> Set[int]:
        """Markets whose spot request is revoked at trace hour ``hour`` —
        excluded from same-hour provisioning (a replica placed on one
        would die before it finished starting). O(1) map lookup; quiet
        hours (the vast majority) return the empty set without touching
        the revocation matrix."""
        return self._rev_hours.get(int(hour), set())


def on_demand_reference(
    workload: ServingWorkload,
    feats: alg.MarketFeatures,
    future: MarketSet,
    hours: float,
    rate_tokens_per_sec: Sequence[float],
    policy: ServePolicy,
    overheads: OverheadModel = OverheadModel(),
) -> FleetReport:
    """The on-demand baseline: replicas on the fitting shape with the best
    on-demand $ per delivered token, no revocations ever, billed at the
    sticker price for the whole window. The availability bar the fleet
    policy must match at lower cost."""
    job = Job(length_hours=policy.slo_horizon_hours, memory_gb=workload.state_gb)
    fit = alg.find_suitable_servers(job, feats)
    if not fit:
        raise ValueError("on-demand: no shape fits the replica")
    best = min(
        fit,
        key=lambda i: (
            float(feats.on_demand[i])
            / max(
                replica_rate(workload, feats, Allocation.single(i, 1)), 1e-9
            ),
            i,
        ),
    )
    alloc = Allocation.single(best, int(feats.device_count[best]))
    rate = replica_rate(workload, feats, alloc)
    target = workload.target_tokens_per_sec * policy.capacity_headroom
    k = max(int(math.ceil(target / max(rate, 1e-9))), 1)
    rec = obs_current()
    if rec.enabled:
        rec.emit(
            obs_ev.RunStart(
                t=0.0,
                subsystem="fleet",
                label="on_demand",
                horizon_hours=float(hours),
            )
        )
    bd = Breakdown()
    od_price = float(feats.on_demand[best])
    od_table = PriceTable.constant(od_price)
    for i in range(k):
        s = Session(best, 0.0)
        s.add("startup", overheads.startup_hours)
        s.add("execution", max(hours - overheads.startup_hours, 0.0))
        if rec.enabled:
            rec.emit(
                obs_ev.Provision(
                    t=0.0,
                    market_id=int(best),
                    legs=(int(best),),
                    replica_id=i,
                    rate_tokens_per_sec=rate,
                )
            )
            rec.emit(obs_ev.session_billed(0.0, s, price_const=od_price))
        bill_session(s, od_table, bd)
    cap_events = [
        CapacityEvent(0.0, 0.0),
        CapacityEvent(overheads.startup_hours, k * rate),
    ]
    stats = route_trace(
        rate_tokens_per_sec,
        cap_events,
        max_delay_seconds=policy.max_delay_seconds,
        shed_delay_seconds=policy.shed_delay_seconds,
        hours=hours,
    )
    stats.merge_into(bd)
    bd.wall_time = float(hours)
    if rec.enabled:
        rec.emit(obs_ev.breakdown_pin(float(hours), bd))
        rec.emit(obs_ev.RunEnd(t=float(hours), wall_hours=float(hours)))
    return FleetReport(
        breakdown=bd,
        router=stats,
        revocations=0,
        repairs=0,
        migrated_bytes=0,
        restored_bytes=0,
        replicas_provisioned=k,
        markets_used=[best] * k,
        capacity_tokens_per_sec=k * rate,
        idle_headroom_tokens=idle_headroom_tokens(
            rate_tokens_per_sec, cap_events, hours=hours
        ),
    )
