"""Continuous-batching decode engine over the paged KV block pool.

The serving hot path: a fixed set of decode *lanes* (the batch dimension of
the compiled decode step) advances every active sequence one token per
step, while a host-side free-page list admits pending requests into lanes
as pool pages free up — insertion at prefill completion, eviction at
EOS / length / shed. Unlike the legacy lock-step path (``launch/serve.py``
without ``--engine``), lanes hold sequences of DIFFERENT lengths: each
lane's write position and attention extent come from its own ``seq_lens``
entry, and its pages from its row of the block table.

Admission rule (documented in docs/serving.md): requests are admitted
FIFO, and a request is admitted only when a free lane exists AND the pool
has enough free pages for its whole lifetime — ``ceil((prompt + max_new)
/ page_size)`` pages are reserved up front. Reserving up front means an
admitted request can never stall mid-stream on pool exhaustion, so the
engine needs no preemption machinery; the cost is earlier admission
back-pressure, which the fleet layer sees as queue depth.

Page accounting: the pool's LAST page is the trash page — dead lanes
(no active sequence) redirect their decode writes there and it is never
allocated, so a fully static-shape decode step serves a ragged, changing
set of sequences.

Prefill runs dense (the existing blockwise/flash path, one request at a
time at its exact prompt length), then a donating jit scatters the dense
cache pages into the request's reserved pool pages. Per-batch decode wall
times feed a ``ThroughputTracker`` so the fleet simulator can consume
MEASURED tokens/sec (``FleetSimulator`` ``throughput_mode="engine"``)
instead of the closed-form analytic table.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import PAGE_SIZE
from repro.obs import events as obs_ev
from repro.obs.recorder import current as obs_current


@dataclasses.dataclass
class Request:
    """One generation request. ``resume_tokens`` carries tokens already
    generated (and committed) before a migration; the engine re-prefills
    prompt + resume_tokens[:-1] and continues from resume_tokens[-1]."""

    rid: int
    prompt: np.ndarray                      # (S,) int32
    max_new_tokens: int
    resume_tokens: Optional[np.ndarray] = None


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]                       # all generated tokens, in order
    reason: str                             # "eos" | "length" | "shed"


@dataclasses.dataclass
class _Lane:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    pages: List[int]                        # reserved pool pages, in order
    seq_len: int                            # tokens written to the pool
    current: int                            # last generated, not yet fed
    generated: List[int]


class DecodeEngine:
    """Continuous-batching greedy decode over a paged KV pool.

    One engine per (model, mesh, lane count): the decode step compiles
    once for the static (lanes, max_blocks) shape and every step serves
    whatever mix of sequences currently occupies the lanes.
    """

    def __init__(
        self,
        model,
        layout,
        mesh,
        *,
        lanes: int,
        num_pages: int,
        max_context: int,
        page_size: int = PAGE_SIZE,
        eos_id: Optional[int] = None,
        tracker=None,                       # Optional[ThroughputTracker]
        tracker_key: Any = None,
        use_kernel: bool = False,
        interpret: bool = False,
    ):
        from repro.dist import (
            cache_shardings,
            make_activation_constrainer,
            param_shardings,
        )
        from repro.train.steps import (
            build_paged_decode_step,
            build_prefill_step,
        )

        assert num_pages >= 2, "pool needs at least one real page + trash"
        self.model = model
        self.layout = layout
        self.mesh = mesh
        self.lanes = lanes
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_blocks = -(-max_context // page_size)
        self.eos_id = eos_id
        self.tracker = tracker
        self.tracker_key = tracker_key
        self.decoded_tokens = 0
        self.decode_seconds = 0.0
        self.prefilled_tokens = 0
        self.steps = 0                      # lane-event trace clock

        self._int8 = layout.int8_kv_cache
        self._free_pages = deque(range(num_pages - 1))  # last page = trash
        self._pending: deque = deque()
        self._lanes: List[Optional[_Lane]] = [None] * lanes
        self._done: List[Completion] = []

        constrain = make_activation_constrainer(mesh, layout, model.cfg)
        self.param_sh = param_shardings(model.specs, mesh, layout)
        pc_specs = model.paged_cache_specs(num_pages, page_size, int8=self._int8)
        self._c_sh = cache_shardings(pc_specs, mesh, layout)
        repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
        self._repl = repl
        self._decode = jax.jit(
            build_paged_decode_step(
                model, layout, constrain,
                use_kernel=use_kernel, interpret=interpret,
            ),
            in_shardings=(self.param_sh, self._c_sh, repl, repl, repl),
            out_shardings=(None, self._c_sh),
            donate_argnums=(1,),
        )
        self._build_prefill = functools.partial(
            build_prefill_step, model, layout, constrain=constrain
        )
        self._prefills: Dict[int, Any] = {}   # prompt len -> jitted prefill
        self._packs: Dict[int, Any] = {}      # n dense pages -> jitted pack
        with mesh:
            self.cache = jax.device_put(
                model.init_paged_cache(num_pages, page_size, int8=self._int8),
                self._c_sh,
            )

    # -- submission ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        self._pending.append(req)

    @property
    def in_flight(self) -> int:
        return len(self._pending) + sum(l is not None for l in self._lanes)

    @property
    def free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def completions(self) -> List[Completion]:
        return list(self._done)

    @property
    def occupancy(self) -> float:
        """Fraction of decode lanes currently holding a live stream — the
        utilization signal the demand-driven autoscaler's low-water mark
        reads (pending-but-unadmitted requests do not count: they hold no
        lane, so they are demand pressure, not occupancy)."""
        if not self._lanes:
            return 0.0
        return sum(l is not None for l in self._lanes) / len(self._lanes)

    @property
    def page_pool_used_frac(self) -> float:
        """Fraction of *allocatable* pool pages currently reserved by live
        lanes. The trash page is excluded from the denominator: it is never
        allocated, so a fully drained engine reads exactly 0.0."""
        allocatable = self.num_pages - 1
        return 1.0 - len(self._free_pages) / allocatable

    def _sample_gauges(self, rec) -> None:
        t = float(self.steps)
        rec.gauge("engine.occupancy", t, self.occupancy)
        rec.gauge("engine.page_pool_used_frac", t, self.page_pool_used_frac)

    @property
    def measured_tokens_per_sec(self) -> float:
        if self.decode_seconds <= 0:
            return 0.0
        return self.decoded_tokens / self.decode_seconds

    # -- admission ----------------------------------------------------------

    def _pages_needed(self, req: Request) -> int:
        n_resume = len(req.resume_tokens) if req.resume_tokens is not None else 0
        total = len(req.prompt) + n_resume + req.max_new_tokens
        return -(-total // self.page_size)

    def _prefill_for(self, length: int):
        if length not in self._prefills:
            self._prefills[length] = jax.jit(self._build_prefill(length))
        return self._prefills[length]

    def _pack_for(self, n_dense_pages: int):
        if n_dense_pages not in self._packs:
            ps = self.page_size
            key_map = {"k": "k_pages", "v": "v_pages",
                       "k_scale": "k_scale", "v_scale": "v_scale"}

            def pack(pool, dense_blocks, pages):
                out = dict(pool["blocks"])
                for dk, pk in key_map.items():
                    if dk not in dense_blocks:
                        continue
                    src = dense_blocks[dk][:, 0]      # (L, T, ...)
                    L, T = src.shape[:2]
                    src = src.reshape(L, T // ps, ps, *src.shape[2:])
                    out[pk] = out[pk].at[:, pages].set(src.astype(out[pk].dtype))
                return {"blocks": out}

            self._packs[n_dense_pages] = jax.jit(
                pack, donate_argnums=(0,), out_shardings=self._c_sh
            )
        return self._packs[n_dense_pages]

    def _admit(self) -> None:
        while self._pending and None in self._lanes:
            req = self._pending[0]
            needed = self._pages_needed(req)
            assert needed <= self.max_blocks, (
                f"request {req.rid} needs {needed} pages > "
                f"max_blocks {self.max_blocks}"
            )
            if needed > len(self._free_pages):
                return  # FIFO back-pressure: head-of-line waits for pages
            self._pending.popleft()
            self._insert(req, [self._free_pages.popleft() for _ in range(needed)])

    def _insert(self, req: Request, pages: List[int]) -> None:
        resume = (np.asarray(req.resume_tokens, np.int32)
                  if req.resume_tokens is not None else np.zeros(0, np.int32))
        # cache must hold prompt + all resumed tokens except the newest,
        # which rides the next decode step
        cached = np.concatenate([req.prompt.astype(np.int32), resume[:-1]])
        length = len(cached)
        prefill = self._prefill_for(length)
        with self.mesh:
            tokens = jax.device_put(jnp.asarray(cached[None, :]), self._repl)
            logits, dense = prefill(self._params, {"tokens": tokens})
            n_dense = dense["blocks"]["k"].shape[2] // self.page_size
            pack = self._pack_for(n_dense)
            self.cache = pack(
                self.cache, dense["blocks"],
                jnp.asarray(pages[:n_dense], jnp.int32),
            )
            if len(resume):
                current = int(resume[-1])
            else:
                current = int(jnp.argmax(logits[0, -1]))
        self.prefilled_tokens += length
        lane = self._lanes.index(None)
        generated = [int(t) for t in resume] if len(resume) else [current]
        self._lanes[lane] = _Lane(
            rid=req.rid, prompt=req.prompt, max_new_tokens=req.max_new_tokens,
            pages=pages, seq_len=length, current=current, generated=generated,
        )
        rec = obs_current()
        if rec.enabled:
            rec.emit(obs_ev.Admit(
                t=float(self.steps), request_id=int(req.rid),
                lane=lane, pages_reserved=len(pages),
            ))
            self._sample_gauges(rec)
        self._maybe_finish(lane)

    # -- stepping -----------------------------------------------------------

    def _maybe_finish(self, lane_idx: int) -> None:
        lane = self._lanes[lane_idx]
        reason = None
        if len(lane.generated) >= lane.max_new_tokens:
            reason = "length"
        elif self.eos_id is not None and lane.generated[-1] == self.eos_id:
            reason = "eos"
        if reason is not None:
            self._evict(lane_idx, reason)

    def _evict(self, lane_idx: int, reason: str) -> None:
        lane = self._lanes[lane_idx]
        self._free_pages.extend(lane.pages)
        self._done.append(Completion(lane.rid, lane.generated, reason))
        self._lanes[lane_idx] = None
        rec = obs_current()
        if rec.enabled:
            rec.emit(obs_ev.Evict(
                t=float(self.steps), request_id=int(lane.rid),
                lane=lane_idx, reason=reason,
            ))
            self._sample_gauges(rec)

    def shed(self) -> List[Request]:
        """Evict every active lane and drain the queue (spot revocation):
        returns the resumable requests, committed tokens included."""
        rec = obs_current()
        out: List[Request] = []
        for i, lane in enumerate(self._lanes):
            if lane is None:
                continue
            if rec.enabled:
                rec.emit(obs_ev.Shed(
                    t=float(self.steps), request_id=int(lane.rid), lane=i,
                    prompt_tokens=len(lane.prompt),
                    resume_tokens=len(lane.generated),
                ))
            out.append(Request(
                rid=lane.rid, prompt=lane.prompt,
                max_new_tokens=lane.max_new_tokens,
                resume_tokens=np.asarray(lane.generated, np.int32),
            ))
            self._evict(i, "shed")
            self._done.pop()  # shed lanes resume elsewhere, not completions
        while self._pending:
            out.append(self._pending.popleft())
        return out

    def step(self, params) -> List[Completion]:
        """Admit what fits, advance every active lane one token. Returns
        completions finished by this call."""
        self._params = params
        self.steps += 1
        done_before = len(self._done)
        self._admit()
        active = [i for i, l in enumerate(self._lanes) if l is not None]
        if not active:
            return self._done[done_before:]

        tokens = np.zeros((self.lanes, 1), np.int32)
        seq_lens = np.zeros(self.lanes, np.int32)
        table = np.full((self.lanes, self.max_blocks), -1, np.int32)
        for i in active:
            lane = self._lanes[i]
            tokens[i, 0] = lane.current
            seq_lens[i] = lane.seq_len
            table[i, : len(lane.pages)] = lane.pages

        with self.mesh:
            tok_d = jax.device_put(jnp.asarray(tokens), self._repl)
            sl_d = jax.device_put(jnp.asarray(seq_lens), self._repl)
            bt_d = jax.device_put(jnp.asarray(table), self._repl)
            t0 = time.perf_counter()  # repro-lint: disable=D001
            logits, self.cache = self._decode(
                params, self.cache, tok_d, sl_d, bt_d
            )
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            jax.block_until_ready(nxt)
            dt = time.perf_counter() - t0  # repro-lint: disable=D001
        self.decode_seconds += dt
        self.decoded_tokens += len(active)
        if self.tracker is not None:
            self.tracker.observe(self.tracker_key, 1, dt)

        nxt = np.asarray(nxt)
        for i in active:
            lane = self._lanes[i]
            lane.seq_len += 1
            lane.current = int(nxt[i])
            lane.generated.append(lane.current)
            self._maybe_finish(i)
        return self._done[done_before:]

    def run(self, params, max_steps: int = 100_000) -> List[Completion]:
        """Drive until every submitted request completes."""
        for _ in range(max_steps):
            if self.in_flight == 0:
                break
            self.step(params)
        assert self.in_flight == 0, "engine did not drain (pool too small?)"
        return list(self._done)
