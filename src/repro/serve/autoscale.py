"""Demand-driven autoscaling for the serving fleet.

The static fleet sizes once to the trace PEAK and burns the night-time
headroom — BENCH_serve's diurnal and steady scenarios cost exactly the
same, which contradicts the paper's thesis that market structure (not
over-provisioning) buys availability cheaply. The scaler closes that gap
by walking the demand trace and resizing the fleet every interval (Qu et
al.'s heterogeneous-spot auto-scaler gives the rule shape):

* **scale-up** — whenever the *forecast* offered load (the max over a
  short look-ahead window, so capacity is live before the ramp arrives)
  breaks the fleet's sizing bars: aggregate capacity below
  ``target × capacity_headroom``, or the N−1 bar (capacity minus the
  largest replica below the raw target). Scale-ups are never gated by
  the cooldown — the SLO outranks thrash avoidance. The demand target is
  floored at the *currently offered* rate, so a bad forecast can never
  size the fleet below live traffic (the in-flight floor).
* **scale-down** — when fleet utilization (required capacity over held
  capacity) falls below ``low_water`` AND the cooldown since the last
  scale event has elapsed. The retiring replica's in-flight streams are
  shed and resumed on a survivor (:func:`drain_replica` — the engine's
  shed→resume round trip is token-identical, so a scale-down is
  invisible in the streams, exactly like a revocation).
* **cooldown** — scale-downs within ``cooldown_hours`` of ANY scale
  event (up, down, or the initial provisioning) are suppressed; this is
  the thrash guard: a demand dip right after a ramp never flaps the
  fleet.

The scaler is deliberately pure arithmetic over rates — it owns no
markets and no sessions. :class:`repro.serve.fleet.FleetSimulator` with
``sizing="auto"`` consumes its decisions and does the provisioning,
billing, and routing; the engine-level drain is driven by
``launch/serve.py`` and pinned by tests/test_serve_engine.py.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, List, Sequence, Tuple

from repro.obs import events as obs_ev
from repro.obs.recorder import current as obs_current

if TYPE_CHECKING:  # pragma: no cover
    from repro.serve.engine import DecodeEngine

SCALE_KINDS = ("hold", "up", "down")


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """Knobs for the demand-driven scaler."""

    #: hours of demand trace the scale-up rule looks ahead over (max of
    #: the window) — capacity must be live BEFORE the ramp arrives, since
    #: a replica takes startup + migration time to come up
    forecast_window_hours: int = 3
    #: scale-down low-water mark: retire capacity only when
    #: required/held utilization drops below this fraction
    low_water: float = 0.5
    #: minimum hours between a scale event and a subsequent scale-DOWN
    cooldown_hours: float = 3.0
    #: never scale below this many replicas (N−1 needs a survivor to
    #: absorb load, and the params have to live somewhere)
    min_replicas: int = 1

    def __post_init__(self):
        assert self.forecast_window_hours >= 1
        assert 0.0 < self.low_water < 1.0
        assert self.cooldown_hours >= 0.0
        assert self.min_replicas >= 1


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    """One interval's verdict: ``kind`` ∈ ``SCALE_KINDS`` and the demand
    target (tokens/sec, already floored at the offered rate) the fleet
    must satisfy this interval."""

    kind: str
    target_tokens_per_sec: float


class AutoScaler:
    """The rule engine: forecast → sizing bars → up/down/hold.

    Stateful only in its event log (``events``) and the cooldown clock;
    every decision is a pure function of (now, replica rates, forecast,
    offered) so random-trace property tests can drive it directly.
    """

    def __init__(
        self,
        policy: AutoscalePolicy = AutoscalePolicy(),
        *,
        capacity_headroom: float,
        survive_one_loss: bool = True,
    ):
        self.policy = policy
        self.capacity_headroom = float(capacity_headroom)
        self.survive_one_loss = survive_one_loss
        #: (at_hours, kind) for every non-hold event, in time order
        self.events: List[Tuple[float, str]] = []
        self._last_event: float | None = None

    # -- the rules -------------------------------------------------------

    def forecast(self, rate: Sequence[float], hour: int) -> float:
        """Max offered rate over ``[hour, hour + window)`` of the trace
        (clamped to the trace; past the end the last hour persists)."""
        if not len(rate):
            return 0.0
        lo = min(max(int(hour), 0), len(rate) - 1)
        hi = min(lo + self.policy.forecast_window_hours, len(rate))
        return max(float(rate[h]) for h in range(lo, hi))

    def satisfied(self, rates: Sequence[float], target: float) -> bool:
        """The fleet sizing bars, identical to ``provision_fleet``:
        capacity ≥ target × headroom AND (N−1) capacity − max ≥ target."""
        cap = sum(rates)
        if cap < target * self.capacity_headroom:
            return False
        if self.survive_one_loss and rates and cap - max(rates) < target:
            return False
        return True

    def cooldown_ok(self, now: float) -> bool:
        if self._last_event is None:
            return True
        return now - self._last_event >= self.policy.cooldown_hours

    def decide(
        self,
        now: float,
        replica_rates: Sequence[float],
        *,
        forecast: float,
        offered_now: float,
    ) -> ScaleDecision:
        """One interval's verdict. The target is the forecast floored at
        the live offered rate — the scaler may be wrong about the future
        but never sizes below the present."""
        target = max(float(forecast), float(offered_now), 0.0)
        if not self.satisfied(replica_rates, target):
            return ScaleDecision("up", target)
        cap = sum(replica_rates)
        required = target * self.capacity_headroom
        if (
            cap > 0.0
            and required / cap < self.policy.low_water
            and len(replica_rates) > self.policy.min_replicas
            and self.cooldown_ok(now)
        ):
            return ScaleDecision("down", target)
        return ScaleDecision("hold", target)

    def record(self, now: float, kind: str) -> None:
        """Log a realized scale event (the simulator calls this only when
        a decision actually changed the fleet) and reset the cooldown
        clock. ``kind="init"`` marks the initial provisioning: it is not
        a scale event but it arms the cooldown, so the fleet cannot
        scale down in the first ``cooldown_hours``."""
        assert kind in SCALE_KINDS + ("init",), kind
        if kind == "hold":
            return
        self.events.append((float(now), kind))
        self._last_event = float(now)

    @property
    def scale_ups(self) -> int:
        return sum(1 for _, k in self.events if k == "up")

    @property
    def scale_downs(self) -> int:
        return sum(1 for _, k in self.events if k == "down")


def drain_replica(src: "DecodeEngine", dst: "DecodeEngine") -> int:
    """Scale-down an engine replica: shed every in-flight stream from the
    retiring engine and resubmit it on a survivor. The engine's
    shed→resume round trip re-prefills ``prompt + generated[:-1]``, so
    the drained streams complete token-identically to uninterrupted
    serving (pinned in tests/test_serve_engine.py) — a scale-down is as
    invisible as a revocation. Returns the number of streams moved."""
    resumed = src.shed()
    for req in resumed:
        dst.submit(req)
    rec = obs_current()
    if rec.enabled:
        rec.emit(obs_ev.Drain(t=float(src.steps), moved_requests=len(resumed)))
    return len(resumed)
