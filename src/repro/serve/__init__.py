"""``repro.serve`` — SLO-aware spot provisioning for inference fleets.

The serving face of the paper's thesis: instead of admitting a batch job
whose wall time an MTTR must dominate, the fleet provisioner admits
replica markets whose MTTR dominates a rolling SLO horizon, spreads
replicas across low-correlation markets, and treats a revocation as a
params-only live migration — availability from market diversity, not
from redundancy mechanisms.

* :mod:`repro.serve.fleet`   — fleet sizing, admission, diversity, the
  trace-driven fleet simulator and its baselines;
* :mod:`repro.serve.router`  — the deterministic open-loop request queue
  (served/shed tokens, SLO-violation clock, exact token conservation);
* :mod:`repro.serve.migrate` — the params-only migration cost model and
  the live reshard helpers ``launch/serve.py --plan`` drives for real;
* :mod:`repro.serve.engine`  — the continuous-batching decode engine over
  the paged KV pool (the replica hot path whose measured tokens/sec the
  fleet simulator consumes in ``throughput_mode="engine"``);
* :mod:`repro.serve.autoscale` — the demand-driven scaler
  (forecast-ahead scale-up, low-water scale-down with cooldown) behind
  ``FleetSimulator(sizing="auto")`` and the engine drain helper.
"""
from repro.serve.autoscale import (
    AutoscalePolicy,
    AutoScaler,
    ScaleDecision,
    drain_replica,
)
from repro.serve.engine import Completion, DecodeEngine, Request
from repro.serve.fleet import (
    FleetPlan,
    FleetReport,
    FleetSimulator,
    Replica,
    ServePolicy,
    ServingWorkload,
    on_demand_reference,
    provision_fleet,
    repair_fleet,
    replica_rate,
)
from repro.serve.migrate import MigrationCost, migration_cost
from repro.serve.router import (
    CapacityEvent,
    RouterStats,
    drain_interval,
    idle_headroom_tokens,
    route_trace,
)

__all__ = [
    "AutoScaler",
    "AutoscalePolicy",
    "CapacityEvent",
    "Completion",
    "DecodeEngine",
    "FleetPlan",
    "FleetReport",
    "FleetSimulator",
    "MigrationCost",
    "Replica",
    "Request",
    "RouterStats",
    "ScaleDecision",
    "ServePolicy",
    "ServingWorkload",
    "drain_interval",
    "drain_replica",
    "idle_headroom_tokens",
    "migration_cost",
    "on_demand_reference",
    "provision_fleet",
    "repair_fleet",
    "replica_rate",
    "route_trace",
]
