"""Params-only migration of a revoked serving replica.

A revoked TRAINING leg moves params + both Adam moments (the
``TrainState``); a revoked SERVING replica moves **params only** — there
is no optimizer state to carry, and the KV cache is a policy decision:

* ``cache_policy="drop"`` — the cache dies with the instance; in-flight
  requests re-prefill on the replacement, billed as **recompute time**
  (``re_execution``: it is re-execution of prefill work the fleet already
  did once);
* ``cache_policy="migrate"`` — the cache crosses the DCN next to the
  params, billed at DCN bandwidth like any other reshard bytes.

Either way the serving migration moves STRICTLY fewer bytes than the
training path would for the same revocation (opt state never moves) —
:func:`migration_cost` asserts it rather than assuming it, mirroring the
reshard-vs-restore byte discipline of the training orchestrator.

Two layers:

* the **analytic** model (:func:`migration_cost`) prices a migration from
  the model's spec trees alone — what the fleet simulator and
  ``benchmarks/serve_bench.py`` bill;
* the **live** helpers (:func:`replica_param_bytes_moved`,
  :func:`assert_params_only`) measure the bytes an actual cross-mesh
  reshard moves, for the real revocation→migration→serve round trip in
  ``repro.launch.serve --plan``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core.units import BYTES_PER_GB, SECONDS_PER_HOUR

CACHE_POLICIES = ("drop", "migrate")


@dataclasses.dataclass(frozen=True)
class MigrationCost:
    """Priced migration of one serving replica onto a replacement shape."""

    params_bytes: int        # params crossing the DCN (always move)
    cache_bytes: int         # cache bytes moved (0 under "drop")
    recompute_hours: float   # re-prefill wall hours (0 under "migrate")
    wire_hours: float        # (params + cache) / DCN bandwidth
    train_path_bytes: int    # what the training path moves: params + opt
    restore_bytes: int       # full serving state through remote storage

    @property
    def moved_bytes(self) -> int:
        return self.params_bytes + self.cache_bytes

    @property
    def hours(self) -> float:
        return self.wire_hours + self.recompute_hours


def migration_cost(
    *,
    param_bytes: int,
    cache_bytes: int,
    cache_policy: str = "drop",
    dcn_gbps: float,
    inflight_context_tokens: float = 0.0,
    prefill_tokens_per_sec: float = 1.0,
) -> MigrationCost:
    """Price one replica migration analytically.

    ``param_bytes`` / ``cache_bytes`` come from the model's spec trees
    (``dist.meshplan.serve_state_bytes`` decomposition); the replacement
    replica starts empty, so the params cross the DCN once in full — from
    the surviving replicas, not from storage. Under ``drop`` the cache is
    rebuilt by re-prefilling ``inflight_context_tokens`` at the
    replacement's prefill rate. Asserts the params-only invariant:
    strictly fewer bytes than the training path (params + 2 Adam moments)
    for the same revocation.
    """
    assert cache_policy in CACHE_POLICIES, cache_policy
    assert param_bytes > 0
    train_path = 3 * param_bytes  # fp32 master + Adam m, v — never moves here
    moved_cache = int(cache_bytes) if cache_policy == "migrate" else 0
    moved = param_bytes + moved_cache
    # the params-only invariant: the STATE the training path would restore
    # (params + both Adam moments) strictly dominates the serving params
    # leg. The cache is a separate, policy-priced quantity — a huge-batch
    # cache under "migrate" may legitimately exceed it and is billed for
    # what it is, not asserted away.
    assert param_bytes < train_path, (param_bytes, train_path)
    wire_hours = moved / (max(dcn_gbps, 1e-9) * BYTES_PER_GB) / SECONDS_PER_HOUR
    recompute_hours = 0.0
    if cache_policy == "drop" and inflight_context_tokens > 0:
        recompute_hours = (
            inflight_context_tokens / max(prefill_tokens_per_sec, 1e-9) / SECONDS_PER_HOUR
        )
    return MigrationCost(
        params_bytes=int(param_bytes),
        cache_bytes=moved_cache,
        recompute_hours=recompute_hours,
        wire_hours=wire_hours,
        train_path_bytes=train_path,
        restore_bytes=int(param_bytes) + int(cache_bytes),
    )


# ---------------------------------------------------------------------------
# Live helpers (real arrays, real meshes) — used by launch/serve.py --plan
# ---------------------------------------------------------------------------

def replica_param_bytes_moved(params: Any, new_shardings: Any) -> int:
    """Bytes a live params-only migration moves onto ``new_shardings`` —
    the exact slice-overlap arithmetic the training orchestrator uses,
    applied to the param tree alone."""
    from repro.dist.meshplan import live_shardings, reshard_bytes

    return reshard_bytes(params, live_shardings(params), new_shardings)


def assert_params_only(params_moved: int, model) -> int:
    """The params-only invariant on LIVE bytes: a serving migration moved
    fewer bytes than the same model's TrainState restore would. Returns
    the training-path byte count for reporting."""
    from repro.dist.meshplan import train_state_bytes

    train_path = train_state_bytes(model)
    assert params_moved < train_path, (params_moved, train_path)
    return train_path


def migrate_cache(
    cache: Any,
    new_shardings: Any,
    cache_policy: str,
) -> Optional[Any]:
    """Apply the cache policy to a live cache: reshard it onto the new
    mesh (``migrate``) or drop it (``drop`` — caller re-prefills)."""
    assert cache_policy in CACHE_POLICIES, cache_policy
    if cache_policy == "drop":
        return None
    from repro.dist.elastic import reshard_tree

    return reshard_tree(cache, new_shardings)
