"""spotax: provisioning spot instances without fault-tolerance mechanisms.

A JAX reproduction of the paper's market-selection provisioner driving real
elastic training: ``repro.core`` implements Algorithm 1 over market traces,
``repro.dist`` reshards live state across device meshes on revocation, and
``repro.models``/``repro.train`` provide the sharded execution substrate.
"""

__version__ = "0.1.0"
