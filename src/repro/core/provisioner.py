"""P-SIWOFT — Algorithm 1, implemented faithfully step by step.

Function names mirror the paper's pseudocode:

    Step 2   FindSuitableServers(J, R)      -> find_suitable_servers
    Step 3   ComputeLifeTime(M, U)          -> compute_lifetime
    Step 5   ServerBasedLifeTime(j, M, L)   -> server_based_lifetime
    Step 7   Highest(S_j)                   -> highest
    Step 8   length(s_j) >> length(j)       -> lifetime_admits (MTTR ≥ 2L)
    Step 9   RevocationProbability(j, s_j)  -> market.revocation_probability
    Step 13  FindLowCorrelation(j, s_j)     -> find_low_correlation
    Step 14  S_j ← (S_j \\ {s_j}) ∩ W_{s_j} -> restrict_after_revocation

The paper leaves two situations unspecified; our choices (documented in
DESIGN.md §Deviations):

* no market passes the MTTR ≥ 2L filter → we keep the MTTR-descending order
  over all suitable markets (best effort) instead of failing the job;
* the correlation filter empties S_j → we refill with the remaining
  suitable markets (minus already-revoked ones), again MTTR-descending.

Instance-menu deviation (beyond the paper): the paper matches a job to the
single smallest memory size that fits; our markets are *mesh shapes*
(``device_count`` accelerators × ``memory_gb`` each, see
``repro.core.market.InstanceShape``), so :func:`find_suitable_servers`
matches the job's sharded state footprint against the instance's TOTAL
memory (``memory_gb × device_count``) and keeps every shape within a
bounded overshoot (default 4×) of the tightest fit. The suitable set
therefore spans heterogeneous mesh shapes (Voorsluys & Buyya; Qu et al.)
and Algorithm 1's MTTR ordering chooses among them; a revocation can
re-provision onto a *different* shape, which the orchestrator handles as
a live cross-mesh reshard.

Throughput deviation (beyond the paper): every shape carries a relative
throughput (``market.shape_throughput`` — sublinear in device count,
mildly increasing in interconnect, ``1.0`` for the 1-device reference),
so a job's wall time is shape-dependent. Ranking within an MTTR tier is
by *expected cost-to-complete* — historical price integrated over the
shape's wall time, inflated by the restart-expectation ``1/(1 - wall/MTTR)``
— instead of raw $/h (:func:`expected_cost_to_complete`). The MTTR
admission filter compares the market's lifetime against the job's wall
time ON THAT SHAPE. Heterogeneous-spot cost-efficiency requires
normalizing price by delivered throughput (Qu et al., arXiv:1509.05197;
Voorsluys & Buyya, arXiv:1110.5969); with a single-device menu every
throughput is 1.0 and all of this degenerates to the paper's exact
price-vs-MTTR behavior.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set

import numpy as np

from repro.core.market import MarketSet, revocation_probability
from repro.core.policies import Job, SiwoftPolicy, work_to_wall_hours


@dataclasses.dataclass
class MarketFeatures:
    """The three §III-A features, computed ONCE from the history window,
    plus the per-shape throughput (beyond the paper) that turns raw $/h
    into $/unit-of-work."""

    mttr: np.ndarray          # (n_markets,) hours
    corr: np.ndarray          # (n_markets, n_markets) co-revocation in [0,1]
    memory_gb: np.ndarray     # (n_markets,) GiB per device
    on_demand: np.ndarray     # (n_markets,)
    avg_price: np.ndarray     # (n_markets,) mean historical spot price
    device_count: np.ndarray = None      # (n_markets,) devices per instance
    interconnect_gbps: np.ndarray = None  # (n_markets,) GB/s reshard bandwidth
    throughput: np.ndarray = None         # (n_markets,) rel. steps/hour (1-dev ≡ 1)

    def __post_init__(self):
        if self.device_count is None:
            self.device_count = np.ones_like(self.memory_gb)
        if self.interconnect_gbps is None:
            self.interconnect_gbps = np.full_like(self.memory_gb, 10.0)
        if self.throughput is None:
            self.throughput = np.ones_like(self.memory_gb)

    @property
    def total_memory_gb(self) -> np.ndarray:
        """The instance shape's aggregate memory: what the job's *sharded*
        state footprint must fit into."""
        return self.memory_gb * self.device_count

    @classmethod
    def from_history(cls, history: MarketSet) -> "MarketFeatures":
        return cls(
            mttr=history.mttr_hours(),
            corr=history.correlation_matrix(),
            memory_gb=np.array([m.memory_gb for m in history.markets], dtype=float),
            on_demand=np.array([m.on_demand_price for m in history.markets]),
            avg_price=history.prices.mean(axis=1),
            device_count=np.array(
                [m.device_count for m in history.markets], dtype=float
            ),
            interconnect_gbps=np.array(
                [m.interconnect_gbps for m in history.markets], dtype=float
            ),
            throughput=np.array(
                [m.throughput for m in history.markets], dtype=float
            ),
        )


# --- throughput-aware cost-to-complete (beyond the paper) -------------------

# Expected-cost revocation-risk cap: a market whose estimated revocation
# probability reaches 1 would have infinite expected cost; clip so the
# fallback ordering over hopeless markets stays finite and price-sensitive.
MAX_REVOCATION_RISK = 0.95


def wall_hours(work_hours: float, feats: MarketFeatures, market: int) -> float:
    """Wall-clock hours market ``market`` needs for ``work_hours`` of work
    (work is measured in hours on the 1-device reference shape)."""
    return work_to_wall_hours(work_hours, float(feats.throughput[market]))


def cost_to_complete(work_hours: float, feats: MarketFeatures, market: int) -> float:
    """$ to run ``work_hours`` of reference work on ``market``, ignoring
    revocations: historical price integrated over the shape-dependent wall
    time — i.e. price/throughput, not raw price."""
    return float(feats.avg_price[market]) * wall_hours(work_hours, feats, market)


def expected_cost_to_complete(
    work_hours: float, feats: MarketFeatures, market: int
) -> float:
    """Revocation-risk-adjusted cost-to-complete.

    A restart-from-scratch policy that gets revoked must repurchase the
    whole run; with per-attempt revocation probability v (the paper's
    ``wall / MTTR`` estimate) the expected number of purchases is ~1/(1-v),
    so the expected bill inflates by that factor. Longer wall occupancy —
    i.e. slower shapes — inflates more, which is exactly how a pricier
    8-device shape can undercut a cheap 1-device shape on a long job."""
    wall = wall_hours(work_hours, feats, market)
    v = min(wall / max(float(feats.mttr[market]), 1e-9), MAX_REVOCATION_RISK)
    return cost_to_complete(work_hours, feats, market) / (1.0 - v)


# --- Alg. 1 steps -----------------------------------------------------------

def find_suitable_servers(
    job: Job, feats: MarketFeatures, *, max_overshoot: float = 4.0
) -> List[int]:
    """Step 2, menu-aware: a market is suitable when the job's sharded state
    footprint fits the instance shape's TOTAL memory
    (``memory_gb × device_count``) and the shape is not wastefully large
    (total ≤ ``max_overshoot`` × the tightest fitting total). The returned
    candidates are ordered by expected cost-to-complete ascending (price
    integrated over the shape-dependent wall time, risk-adjusted) — NOT by
    raw $/h: a pricier shape that finishes the work faster ranks ahead of
    a cheap slow one.

    Deviation from the paper (which keeps only the single smallest memory
    size): the bounded-overshoot band deliberately keeps *several mesh
    shapes* in play so Algorithm 1 provisions across heterogeneous instance
    types — the degree of freedom the related heterogeneous-spot work
    exploits — while still excluding shapes that only waste money."""
    total = feats.total_memory_gb
    fits = total[total >= job.memory_gb]
    if fits.size == 0:
        return []
    best = fits.min()
    suitable = [
        i
        for i in range(len(total))
        if total[i] >= job.memory_gb and total[i] <= max_overshoot * best
    ]
    return sorted(
        suitable,
        key=lambda i: (expected_cost_to_complete(job.length_hours, feats, i), i),
    )


def compute_lifetime(feats: MarketFeatures, suitable: Sequence[int]) -> Dict[int, float]:
    """Step 3: lifetime (MTTR) per suitable market."""
    return {i: float(feats.mttr[i]) for i in suitable}


def server_based_lifetime(
    job: Job,
    lifetimes: Dict[int, float],
    policy: SiwoftPolicy,
    feats: Optional[MarketFeatures] = None,
) -> List[int]:
    """Step 5: keep markets whose lifetime admits the job (MTTR ≥ 2 × the
    job's *wall time on that shape*), sorted by lifetime descending. Ties
    (e.g. several never-revoking markets, or markets sharing a revocation
    count) break toward the lowest expected cost-to-complete — price
    integrated over the shape-dependent wall time, risk-adjusted — instead
    of raw $/h, so among equally-safe markets Algorithm 1 deliberately
    provisions the shape that finishes the work cheapest, which may be a
    pricier-per-hour but faster mesh. The paper does not specify
    tie-breaking; see module docstring. Falls back to all candidates
    (same ordering) when the filter is empty."""
    admitted = [
        i for i, lt in lifetimes.items()
        if lt >= policy.lifetime_factor * _wall(job, feats, i)
    ]
    pool = admitted if admitted else list(lifetimes)
    return sorted(pool, key=lambda i: (-lifetimes[i], _ecc(job, feats, i), i))


def _wall(job: Job, feats: Optional[MarketFeatures], i: int) -> float:
    """Job wall time on market ``i`` (== length when features are absent)."""
    return wall_hours(job.length_hours, feats, i) if feats is not None else job.length_hours


def _ecc(job: Job, feats: Optional[MarketFeatures], i: int) -> float:
    """Tie-break key: expected cost-to-complete (0 when features absent)."""
    return expected_cost_to_complete(job.length_hours, feats, i) if feats is not None else 0.0


def highest(S: Sequence[int]) -> int:
    """Step 7: S is kept lifetime-descending; the head is the highest."""
    return S[0]


def lifetime_admits(
    job: Job, lifetime: float, policy: SiwoftPolicy, throughput: float = 1.0
) -> bool:
    """Step 8 guard, throughput-aware: the market must outlive the job's
    wall occupancy on ITS shape, not the reference-length — a fast shape
    shrinks its own exposure window."""
    return lifetime >= policy.lifetime_factor * job.wall_hours_on(throughput)


def find_low_correlation(
    feats: MarketFeatures, revoked_market: int, policy: SiwoftPolicy
) -> Set[int]:
    """Step 13: markets whose co-revocation with the revoked market over the
    3-month history is below the threshold."""
    corr = feats.corr[revoked_market]
    return {i for i in range(corr.shape[0]) if corr[i] < policy.correlation_threshold}


def restrict_after_revocation(
    S: List[int],
    revoked: int,
    W: Set[int],
    lifetimes: Dict[int, float],
    already_revoked: Set[int],
    feats: Optional[MarketFeatures] = None,
    job: Optional[Job] = None,
) -> List[int]:
    """Step 14 (+ fallback): S ← (S \\ {s}) ∩ W, lifetime-descending with
    the expected-cost-to-complete tie-break (pass ``job`` + ``feats`` to
    enable it; ``job`` carries the remaining work the cost is integrated
    over)."""
    rest = [i for i in S if i != revoked and i in W]
    if not rest:
        rest = [i for i in lifetimes if i not in already_revoked and i != revoked]
    if job is not None:
        tiebreak = lambda i: _ecc(job, feats, i)
    elif feats is not None:
        tiebreak = lambda i: float(feats.avg_price[i])
    else:
        tiebreak = lambda i: 0.0
    return sorted(rest, key=lambda i: (-lifetimes[i], tiebreak(i), i))


def remaining_job(job: Job, remaining_work_hours: float) -> Job:
    """The job with only its unfinished work — what re-provisioning after a
    revocation should integrate price/throughput over."""
    return dataclasses.replace(
        job, length_hours=max(float(remaining_work_hours), 1e-9)
    )


def plan_first_choice(job: Job, feats: MarketFeatures, policy: SiwoftPolicy) -> int:
    """Convenience: the market Alg. 1 provisions first for this job."""
    suitable = find_suitable_servers(job, feats)
    lifetimes = compute_lifetime(feats, suitable)
    return highest(server_based_lifetime(job, lifetimes, policy, feats))
