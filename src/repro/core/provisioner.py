"""P-SIWOFT — Algorithm 1, implemented faithfully step by step.

Function names mirror the paper's pseudocode:

    Step 2   FindSuitableServers(J, R)      -> find_suitable_servers
    Step 3   ComputeLifeTime(M, U)          -> compute_lifetime
    Step 5   ServerBasedLifeTime(j, M, L)   -> server_based_lifetime
    Step 7   Highest(S_j)                   -> highest
    Step 8   length(s_j) >> length(j)       -> lifetime_admits (MTTR ≥ 2L)
    Step 9   RevocationProbability(j, s_j)  -> market.revocation_probability
    Step 13  FindLowCorrelation(j, s_j)     -> find_low_correlation
    Step 14  S_j ← (S_j \\ {s_j}) ∩ W_{s_j} -> restrict_after_revocation

The paper leaves two situations unspecified; our choices (documented in
DESIGN.md §Deviations):

* no market passes the MTTR ≥ 2L filter → we keep the MTTR-descending order
  over all suitable markets (best effort) instead of failing the job;
* the correlation filter empties S_j → we refill with the remaining
  suitable markets (minus already-revoked ones), again MTTR-descending.

Instance-menu deviation (beyond the paper): the paper matches a job to the
single smallest memory size that fits; our markets are *mesh shapes*
(``device_count`` accelerators × ``memory_gb`` each, see
``repro.core.market.InstanceShape``), so :func:`find_suitable_servers`
matches the job's sharded state footprint against the instance's TOTAL
memory (``memory_gb × device_count``) and keeps every shape within a
bounded overshoot (default 4×) of the tightest fit. The suitable set
therefore spans heterogeneous mesh shapes (Voorsluys & Buyya; Qu et al.)
and Algorithm 1's MTTR ordering — with the historical-price tie-break —
chooses among them; a revocation can re-provision onto a *different*
shape, which the orchestrator handles as a live cross-mesh reshard.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set

import numpy as np

from repro.core.market import MarketSet, revocation_probability
from repro.core.policies import Job, SiwoftPolicy


@dataclasses.dataclass
class MarketFeatures:
    """The three §III-A features, computed ONCE from the history window."""

    mttr: np.ndarray          # (n_markets,) hours
    corr: np.ndarray          # (n_markets, n_markets) co-revocation in [0,1]
    memory_gb: np.ndarray     # (n_markets,) GiB per device
    on_demand: np.ndarray     # (n_markets,)
    avg_price: np.ndarray     # (n_markets,) mean historical spot price
    device_count: np.ndarray = None      # (n_markets,) devices per instance
    interconnect_gbps: np.ndarray = None  # (n_markets,) GB/s reshard bandwidth

    def __post_init__(self):
        if self.device_count is None:
            self.device_count = np.ones_like(self.memory_gb)
        if self.interconnect_gbps is None:
            self.interconnect_gbps = np.full_like(self.memory_gb, 10.0)

    @property
    def total_memory_gb(self) -> np.ndarray:
        """The instance shape's aggregate memory: what the job's *sharded*
        state footprint must fit into."""
        return self.memory_gb * self.device_count

    @classmethod
    def from_history(cls, history: MarketSet) -> "MarketFeatures":
        return cls(
            mttr=history.mttr_hours(),
            corr=history.correlation_matrix(),
            memory_gb=np.array([m.memory_gb for m in history.markets], dtype=float),
            on_demand=np.array([m.on_demand_price for m in history.markets]),
            avg_price=history.prices.mean(axis=1),
            device_count=np.array(
                [m.device_count for m in history.markets], dtype=float
            ),
            interconnect_gbps=np.array(
                [m.interconnect_gbps for m in history.markets], dtype=float
            ),
        )


# --- Alg. 1 steps -----------------------------------------------------------

def find_suitable_servers(
    job: Job, feats: MarketFeatures, *, max_overshoot: float = 4.0
) -> List[int]:
    """Step 2, menu-aware: a market is suitable when the job's sharded state
    footprint fits the instance shape's TOTAL memory
    (``memory_gb × device_count``) and the shape is not wastefully large
    (total ≤ ``max_overshoot`` × the tightest fitting total).

    Deviation from the paper (which keeps only the single smallest memory
    size): the bounded-overshoot band deliberately keeps *several mesh
    shapes* in play so Algorithm 1 provisions across heterogeneous instance
    types — the degree of freedom the related heterogeneous-spot work
    exploits — while still excluding shapes that only waste money."""
    total = feats.total_memory_gb
    fits = total[total >= job.memory_gb]
    if fits.size == 0:
        return []
    best = fits.min()
    return [
        i
        for i in range(len(total))
        if total[i] >= job.memory_gb and total[i] <= max_overshoot * best
    ]


def compute_lifetime(feats: MarketFeatures, suitable: Sequence[int]) -> Dict[int, float]:
    """Step 3: lifetime (MTTR) per suitable market."""
    return {i: float(feats.mttr[i]) for i in suitable}


def server_based_lifetime(
    job: Job,
    lifetimes: Dict[int, float],
    policy: SiwoftPolicy,
    feats: Optional[MarketFeatures] = None,
) -> List[int]:
    """Step 5: keep markets whose lifetime admits the job (MTTR ≥ 2 × len),
    sorted by lifetime descending. Ties (e.g. several never-revoking
    markets) break toward the historically cheaper market — the paper does
    not specify tie-breaking; see module docstring. Falls back to all
    candidates (still MTTR-descending) when the filter is empty."""
    admitted = [
        i for i, lt in lifetimes.items()
        if lt >= policy.lifetime_factor * job.length_hours
    ]
    pool = admitted if admitted else list(lifetimes)
    price = (lambda i: float(feats.avg_price[i])) if feats is not None else (lambda i: 0.0)
    return sorted(pool, key=lambda i: (-lifetimes[i], price(i), i))


def highest(S: Sequence[int]) -> int:
    """Step 7: S is kept lifetime-descending; the head is the highest."""
    return S[0]


def lifetime_admits(job: Job, lifetime: float, policy: SiwoftPolicy) -> bool:
    """Step 8 guard."""
    return lifetime >= policy.lifetime_factor * job.length_hours


def find_low_correlation(
    feats: MarketFeatures, revoked_market: int, policy: SiwoftPolicy
) -> Set[int]:
    """Step 13: markets whose co-revocation with the revoked market over the
    3-month history is below the threshold."""
    corr = feats.corr[revoked_market]
    return {i for i in range(corr.shape[0]) if corr[i] < policy.correlation_threshold}


def restrict_after_revocation(
    S: List[int],
    revoked: int,
    W: Set[int],
    lifetimes: Dict[int, float],
    already_revoked: Set[int],
    feats: Optional[MarketFeatures] = None,
) -> List[int]:
    """Step 14 (+ fallback): S ← (S \\ {s}) ∩ W, lifetime-descending."""
    rest = [i for i in S if i != revoked and i in W]
    if not rest:
        rest = [i for i in lifetimes if i not in already_revoked and i != revoked]
    price = (lambda i: float(feats.avg_price[i])) if feats is not None else (lambda i: 0.0)
    return sorted(rest, key=lambda i: (-lifetimes[i], price(i), i))


def plan_first_choice(job: Job, feats: MarketFeatures, policy: SiwoftPolicy) -> int:
    """Convenience: the market Alg. 1 provisions first for this job."""
    suitable = find_suitable_servers(job, feats)
    lifetimes = compute_lifetime(feats, suitable)
    return highest(server_based_lifetime(job, lifetimes, policy))
