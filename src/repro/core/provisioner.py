"""P-SIWOFT — Algorithm 1, implemented faithfully step by step.

Function names mirror the paper's pseudocode:

    Step 2   FindSuitableServers(J, R)      -> find_suitable_servers
    Step 3   ComputeLifeTime(M, U)          -> compute_lifetime
    Step 5   ServerBasedLifeTime(j, M, L)   -> server_based_lifetime
    Step 7   Highest(S_j)                   -> highest
    Step 8   length(s_j) >> length(j)       -> lifetime_admits (MTTR ≥ 2L)
    Step 9   RevocationProbability(j, s_j)  -> market.revocation_probability
    Step 13  FindLowCorrelation(j, s_j)     -> find_low_correlation
    Step 14  S_j ← (S_j \\ {s_j}) ∩ W_{s_j} -> restrict_after_revocation

The paper leaves two situations unspecified; our choices (documented in
DESIGN.md §Deviations):

* no market passes the MTTR ≥ 2L filter → we keep the MTTR-descending order
  over all suitable markets (best effort) instead of failing the job;
* the correlation filter empties S_j → we refill with the remaining
  suitable markets (minus already-revoked ones), again MTTR-descending.

Instance-menu deviation (beyond the paper): the paper matches a job to the
single smallest memory size that fits; our markets are *mesh shapes*
(``device_count`` accelerators × ``memory_gb`` each, see
``repro.core.market.InstanceShape``), so :func:`find_suitable_servers`
matches the job's sharded state footprint against the instance's TOTAL
memory (``memory_gb × device_count``) and keeps every shape within a
bounded overshoot (default 4×) of the tightest fit. The suitable set
therefore spans heterogeneous mesh shapes (Voorsluys & Buyya; Qu et al.)
and Algorithm 1's MTTR ordering chooses among them; a revocation can
re-provision onto a *different* shape, which the orchestrator handles as
a live cross-mesh reshard.

Allocation deviation (beyond the paper, ISSUE 4): the unit Algorithm 1
ranks and provisions is a multi-leg :class:`repro.core.allocation.
Allocation`, not a bare market index. When some single shape fits the job
the candidate set is exactly the paper's (single-leg allocations, same
order — bit-identical to the pre-allocation provisioner); when NONE fits,
:func:`find_suitable_allocations` searches splits of the job across up to
``policy.max_legs`` low-correlation markets, priced with the combined
DCN-discounted throughput and the min-over-legs MTTR (admission is
strictly harder for wider splits). After a revocation of one leg,
:func:`find_low_correlation` / :func:`restrict_after_revocation` filter
against the revoked market AND every surviving leg, keeping one-leg
repairs eligible.

Throughput deviation (beyond the paper): every shape carries a relative
throughput (``market.shape_throughput`` — sublinear in device count,
mildly increasing in interconnect, ``1.0`` for the 1-device reference),
so a job's wall time is shape-dependent. Ranking within an MTTR tier is
by *expected cost-to-complete* — historical price integrated over the
shape's wall time, inflated by the restart-expectation ``1/(1 - wall/MTTR)``
— instead of raw $/h (:func:`expected_cost_to_complete`). The MTTR
admission filter compares the market's lifetime against the job's wall
time ON THAT SHAPE. Heterogeneous-spot cost-efficiency requires
normalizing price by delivered throughput (Qu et al., arXiv:1509.05197;
Voorsluys & Buyya, arXiv:1110.5969); with a single-device menu every
throughput is 1.0 and all of this degenerates to the paper's exact
price-vs-MTTR behavior.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple, Union

import numpy as np

from repro.core.allocation import Allocation, combined_throughput
from repro.core.market import MarketSet
from repro.core.policies import Job, SiwoftPolicy, work_to_wall_hours

# Algorithm-1 candidates are Allocations since the multi-leg refactor; the
# int form survives for the per-market primitives the FT baselines and the
# feature layer still speak.
Candidate = Union[int, Allocation]


@dataclasses.dataclass
class MarketFeatures:
    """The three §III-A features, computed ONCE from the history window,
    plus the per-shape throughput (beyond the paper) that turns raw $/h
    into $/unit-of-work."""

    mttr: np.ndarray          # (n_markets,) hours
    corr: np.ndarray          # (n_markets, n_markets) co-revocation in [0,1]
    memory_gb: np.ndarray     # (n_markets,) GiB per device
    on_demand: np.ndarray     # (n_markets,)
    avg_price: np.ndarray     # (n_markets,) mean historical spot price
    device_count: np.ndarray = None      # (n_markets,) devices per instance
    interconnect_gbps: np.ndarray = None  # (n_markets,) GB/s reshard bandwidth
    throughput: np.ndarray = None         # (n_markets,) rel. steps/hour (1-dev ≡ 1)

    def __post_init__(self):
        if self.device_count is None:
            self.device_count = np.ones_like(self.memory_gb)
        if self.interconnect_gbps is None:
            self.interconnect_gbps = np.full_like(self.memory_gb, 10.0)
        if self.throughput is None:
            self.throughput = np.ones_like(self.memory_gb)

    @property
    def total_memory_gb(self) -> np.ndarray:
        """The instance shape's aggregate memory: what the job's *sharded*
        state footprint must fit into."""
        return self.memory_gb * self.device_count

    @classmethod
    def from_history(cls, history: MarketSet) -> "MarketFeatures":
        # One pass over the market objects instead of five comprehensions.
        # m.throughput stays a per-market scalar call on purpose: it routes
        # through libm's pow (float ** alpha), and swapping it for np.power
        # could drift the last ulp of the ranking keys.
        n = len(history.markets)
        memory_gb = np.empty(n)
        on_demand = np.empty(n)
        device_count = np.empty(n)
        interconnect_gbps = np.empty(n)
        throughput = np.empty(n)
        for i, m in enumerate(history.markets):
            memory_gb[i] = m.memory_gb
            on_demand[i] = m.on_demand_price
            device_count[i] = m.device_count
            interconnect_gbps[i] = m.interconnect_gbps
            throughput[i] = m.throughput
        return cls(
            mttr=history.mttr_hours(),
            corr=history.correlation_matrix(),
            memory_gb=memory_gb,
            on_demand=on_demand,
            avg_price=history.prices.mean(axis=1),
            device_count=device_count,
            interconnect_gbps=interconnect_gbps,
            throughput=throughput,
        )


# --- throughput-aware cost-to-complete (beyond the paper) -------------------

# Expected-cost revocation-risk cap: a market whose estimated revocation
# probability reaches 1 would have infinite expected cost; clip so the
# fallback ordering over hopeless markets stays finite and price-sensitive.
MAX_REVOCATION_RISK = 0.95


def wall_hours(work_hours: float, feats: MarketFeatures, market: int) -> float:
    """Wall-clock hours market ``market`` needs for ``work_hours`` of work
    (work is measured in hours on the 1-device reference shape)."""
    return work_to_wall_hours(work_hours, float(feats.throughput[market]))


def cost_to_complete(work_hours: float, feats: MarketFeatures, market: int) -> float:
    """$ to run ``work_hours`` of reference work on ``market``, ignoring
    revocations: historical price integrated over the shape-dependent wall
    time — i.e. price/throughput, not raw price."""
    return float(feats.avg_price[market]) * wall_hours(work_hours, feats, market)


def expected_cost_to_complete(
    work_hours: float, feats: MarketFeatures, market: int
) -> float:
    """Revocation-risk-adjusted cost-to-complete.

    A restart-from-scratch policy that gets revoked must repurchase the
    whole run; with per-attempt revocation probability v (the paper's
    ``wall / MTTR`` estimate) the expected number of purchases is ~1/(1-v),
    so the expected bill inflates by that factor. Longer wall occupancy —
    i.e. slower shapes — inflates more, which is exactly how a pricier
    8-device shape can undercut a cheap 1-device shape on a long job."""
    wall = wall_hours(work_hours, feats, market)
    v = min(wall / max(float(feats.mttr[market]), 1e-9), MAX_REVOCATION_RISK)
    return cost_to_complete(work_hours, feats, market) / (1.0 - v)


def expected_cost_to_complete_batch(
    work_hours: float, feats: MarketFeatures, markets: Sequence[int]
) -> np.ndarray:
    """:func:`expected_cost_to_complete` over a whole candidate set at once.

    Elementwise mirror of the scalar chain (same IEEE-double ops in the
    same order: divide by clamped throughput, price × wall, clip risk,
    inflate), so every entry equals the scalar value BIT-FOR-BIT and sort
    keys built from either are interchangeable — the property tests pin
    this. Turns candidate scoring from O(set size) Python calls into one
    fused numpy expression.
    """
    idx = np.asarray(markets, dtype=np.intp)
    w = float(work_hours)
    wall = w / np.maximum(feats.throughput[idx], 1e-9)
    ctc = feats.avg_price[idx] * wall
    v = np.minimum(wall / np.maximum(feats.mttr[idx], 1e-9), MAX_REVOCATION_RISK)
    return ctc / (1.0 - v)


# --- allocation-level composition (multi-leg meshes over DCN) ---------------
#
# Single-leg allocations DELEGATE to the per-market functions above, so a
# one-market allocation prices, admits, and ranks bit-identically to the
# bare market index it replaced (the PR 3 legacy-equivalence guarantee).

def allocation_throughput(alloc: Allocation, feats: MarketFeatures) -> float:
    """Relative steps/hour of an allocation. One leg: the market's own
    (possibly measured/calibrated) throughput. Multi-leg: the analytic
    sublinear law over the union device count at the DCN-capped effective
    bandwidth — never better than the same devices on one interconnect."""
    if len(alloc) == 1:
        return float(feats.throughput[alloc.legs[0].market])
    return combined_throughput(
        alloc.device_counts,
        [float(feats.interconnect_gbps[m]) for m in alloc.markets],
        alloc.dcn_gbps,
    )


def allocation_mttr(alloc: Allocation, feats: MarketFeatures) -> float:
    """Any leg revocation interrupts the job: MTTR composes as the MIN over
    legs — the honest survival model, which makes the Alg. 1 admission rule
    strictly harder for wider splits."""
    return min(float(feats.mttr[m]) for m in alloc.markets)


def allocation_price(alloc: Allocation, feats: MarketFeatures) -> float:
    """Hourly price of the whole allocation: legs bill independently."""
    return float(sum(float(feats.avg_price[m]) for m in alloc.markets))


def allocation_memory_gb(alloc: Allocation, feats: MarketFeatures) -> float:
    """Aggregate memory across legs — what the job's sharded state (now
    spread over the union mesh) must fit into."""
    return float(sum(float(feats.total_memory_gb[m]) for m in alloc.markets))


def allocation_wall_hours(
    work_hours: float, feats: MarketFeatures, alloc: Allocation
) -> float:
    if len(alloc) == 1:
        return wall_hours(work_hours, feats, alloc.legs[0].market)
    return work_to_wall_hours(work_hours, allocation_throughput(alloc, feats))


def allocation_cost_to_complete(
    work_hours: float, feats: MarketFeatures, alloc: Allocation
) -> float:
    if len(alloc) == 1:
        return cost_to_complete(work_hours, feats, alloc.legs[0].market)
    return allocation_price(alloc, feats) * allocation_wall_hours(
        work_hours, feats, alloc
    )


def allocation_expected_cost_to_complete(
    work_hours: float, feats: MarketFeatures, alloc: Allocation
) -> float:
    """Risk-adjusted cost-to-complete of an allocation: same restart
    expectation as the per-market rule, with wall time at the combined
    throughput and revocation risk against the min-over-legs MTTR."""
    if len(alloc) == 1:
        return expected_cost_to_complete(work_hours, feats, alloc.legs[0].market)
    wall = allocation_wall_hours(work_hours, feats, alloc)
    v = min(wall / max(allocation_mttr(alloc, feats), 1e-9), MAX_REVOCATION_RISK)
    return allocation_cost_to_complete(work_hours, feats, alloc) / (1.0 - v)


# --- Alg. 1 steps -----------------------------------------------------------

def find_suitable_servers(
    job: Job, feats: MarketFeatures, *, max_overshoot: float = 4.0
) -> List[int]:
    """Step 2, menu-aware: a market is suitable when the job's sharded state
    footprint fits the instance shape's TOTAL memory
    (``memory_gb × device_count``) and the shape is not wastefully large
    (total ≤ ``max_overshoot`` × the tightest fitting total). The returned
    candidates are ordered by expected cost-to-complete ascending (price
    integrated over the shape-dependent wall time, risk-adjusted) — NOT by
    raw $/h: a pricier shape that finishes the work faster ranks ahead of
    a cheap slow one.

    Deviation from the paper (which keeps only the single smallest memory
    size): the bounded-overshoot band deliberately keeps *several mesh
    shapes* in play so Algorithm 1 provisions across heterogeneous instance
    types — the degree of freedom the related heterogeneous-spot work
    exploits — while still excluding shapes that only waste money."""
    total = feats.total_memory_gb
    fits_mask = total >= job.memory_gb
    if not fits_mask.any():
        return []
    best = total[fits_mask].min()
    suitable = np.flatnonzero(fits_mask & (total <= max_overshoot * best))
    # one vectorized scoring pass over the whole suitable set, then an
    # argsort on (score, index) — same keys, same order as the per-market
    # sorted(..., key=expected_cost_to_complete) it replaces
    ecc = expected_cost_to_complete_batch(job.length_hours, feats, suitable)
    order = np.lexsort((suitable, ecc))
    return [int(i) for i in suitable[order]]


def find_suitable_allocations(
    job: Job,
    feats: MarketFeatures,
    policy: Optional[SiwoftPolicy] = None,
    *,
    max_overshoot: float = 4.0,
    max_legs: Optional[int] = None,
    split_margin: Optional[float] = None,
    exclude: Set[int] = frozenset(),
) -> List[Allocation]:
    """Step 2, allocation-first: the candidate set Algorithm 1 ranks.

    Single-leg allocations wrap :func:`find_suitable_servers` one-for-one
    (same markets, same expected-cost order), so when any single shape fits
    and splits are not opportunistically enabled the candidate set is the
    paper's — bit-identical ordering to the pre-allocation provisioner.

    The SPLIT-SEARCH path activates when
    * no single shape fits the job (the case the paper cannot provision
      without fault tolerance), or
    * ``split_margin`` is set (policy knob ``SiwoftPolicy.split_margin``)
      and some split's expected cost-to-complete beats the best single
      shape by at least that fraction.

    Splits are pairs-to-``max_legs``-tuples of distinct markets whose
    combined memory fits the job, gated by a PAIRWISE correlation budget
    when a policy is given (``SiwoftPolicy.split_corr_cut``): every pair
    of legs must co-revoke below the budget — a split correlated with
    itself revokes as one market but pays DCN prices, strictly dominated.
    The gate is enforced incrementally (each new leg against every chosen
    leg), so a 3-leg candidate under ``max_legs=3`` is admitted only when
    all three pairs qualify; its MTTR still composes as min over legs, so
    wider splits face a strictly harder admission test. Ranking is by
    allocation expected cost-to-complete; the honest min-MTTR survival
    model and the DCN-discounted throughput are both priced in, so the
    search only surfaces splits that genuinely earn their coupling cost.
    """
    if policy is not None:
        max_legs = policy.max_legs if max_legs is None else max_legs
        split_margin = (
            policy.split_margin if split_margin is None else split_margin
        )
    max_legs = 2 if max_legs is None else max(int(max_legs), 1)

    singles = [
        Allocation.single(i, int(feats.device_count[i]))
        for i in find_suitable_servers(job, feats, max_overshoot=max_overshoot)
        if i not in exclude
    ]
    if singles and split_margin is None:
        return singles
    if max_legs < 2:
        return singles

    corr_cut = policy.split_corr_cut if policy is not None else 1.0
    totals = feats.total_memory_gb
    n = len(totals)
    pool = [i for i in range(n) if i not in exclude]
    # widest shapes first: a split wants the fewest, biggest legs
    pool.sort(key=lambda i: (-float(totals[i]), i))

    splits: List[Allocation] = []

    def grow(legs: List[int], mem: float, start: int) -> None:
        if len(legs) >= 2 and mem >= job.memory_gb:
            splits.append(
                Allocation.of(legs, [int(feats.device_count[m]) for m in legs])
            )
            return  # a fitting split never benefits from MORE legs (min-MTTR)
        if len(legs) >= max_legs:
            return
        for k in range(start, len(pool)):
            j = pool[k]
            if any(float(feats.corr[j, m]) >= corr_cut for m in legs):
                continue
            grow(legs + [j], mem + float(totals[j]), k + 1)

    grow([], 0.0, 0)
    if not splits and corr_cut < 1.0:
        # correlation filter emptied the split set: refill without it (same
        # fallback discipline as Alg. 1's step-13 refill)
        corr_cut = 1.0
        grow([], 0.0, 0)

    splits.sort(
        key=lambda a: (
            allocation_expected_cost_to_complete(job.length_hours, feats, a),
            a.markets,
        )
    )
    if not singles:
        return splits
    best_single = allocation_expected_cost_to_complete(
        job.length_hours, feats, singles[0]
    )
    margin = float(split_margin or 0.0)
    good_splits = [
        a
        for a in splits
        if allocation_expected_cost_to_complete(job.length_hours, feats, a)
        < best_single * (1.0 - margin)
    ]
    merged = singles + good_splits
    merged.sort(
        key=lambda a: (
            allocation_expected_cost_to_complete(job.length_hours, feats, a),
            a.markets,
        )
    )
    return merged


def compute_lifetime(feats: MarketFeatures, suitable: Sequence[int]) -> Dict[int, float]:
    """Step 3: lifetime (MTTR) per suitable market."""
    return {i: float(feats.mttr[i]) for i in suitable}


def compute_allocation_lifetimes(
    feats: MarketFeatures, suitable: Sequence[Allocation]
) -> Dict[Allocation, float]:
    """Step 3 over allocations: lifetime = min over legs (any leg revocation
    interrupts the job)."""
    return {a: allocation_mttr(a, feats) for a in suitable}


def server_based_lifetime(
    job: Job,
    lifetimes: Dict[Candidate, float],
    policy: SiwoftPolicy,
    feats: Optional[MarketFeatures] = None,
) -> List[Candidate]:
    """Step 5: keep markets whose lifetime admits the job (MTTR ≥ 2 × the
    job's *wall time on that shape*), sorted by lifetime descending. Ties
    (e.g. several never-revoking markets, or markets sharing a revocation
    count) break toward the lowest expected cost-to-complete — price
    integrated over the shape-dependent wall time, risk-adjusted — instead
    of raw $/h, so among equally-safe markets Algorithm 1 deliberately
    provisions the shape that finishes the work cheapest, which may be a
    pricier-per-hour but faster mesh. The paper does not specify
    tie-breaking; see module docstring. Falls back to all candidates
    (same ordering) when the filter is empty."""
    admitted = [
        i for i, lt in lifetimes.items()
        if lt >= policy.lifetime_factor * _wall(job, feats, i)
    ]
    pool = admitted if admitted else list(lifetimes)
    return sorted(
        pool, key=lambda i: (-lifetimes[i], _ecc(job, feats, i), _stable(i))
    )


def _stable(c: Candidate):
    """Deterministic final sort key: the market index, or the allocation's
    market tuple (for single-leg allocations that orders exactly like the
    bare index did)."""
    return c.markets if isinstance(c, Allocation) else c


def _markets(c: Candidate) -> Tuple[int, ...]:
    return c.markets if isinstance(c, Allocation) else (c,)


def _wall(job: Job, feats: Optional[MarketFeatures], c: Candidate) -> float:
    """Job wall time on candidate ``c`` (== length when features are absent)."""
    if feats is None:
        return job.length_hours
    if isinstance(c, Allocation):
        return allocation_wall_hours(job.length_hours, feats, c)
    return wall_hours(job.length_hours, feats, c)


def _ecc(job: Job, feats: Optional[MarketFeatures], c: Candidate) -> float:
    """Tie-break key: expected cost-to-complete (0 when features absent)."""
    if feats is None:
        return 0.0
    if isinstance(c, Allocation):
        return allocation_expected_cost_to_complete(job.length_hours, feats, c)
    return expected_cost_to_complete(job.length_hours, feats, c)


def highest(S: Sequence[Candidate]) -> Candidate:
    """Step 7: S is kept lifetime-descending; the head is the highest."""
    return S[0]


def lifetime_admits(
    job: Job, lifetime: float, policy: SiwoftPolicy, throughput: float = 1.0
) -> bool:
    """Step 8 guard, throughput-aware: the market must outlive the job's
    wall occupancy on ITS shape, not the reference-length — a fast shape
    shrinks its own exposure window."""
    return lifetime >= policy.lifetime_factor * job.wall_hours_on(throughput)


def find_low_correlation(
    feats: MarketFeatures,
    revoked_market: int,
    policy: SiwoftPolicy,
    surviving: Sequence[int] = (),
) -> Set[int]:
    """Step 13, allocation-aware: markets whose co-revocation with the
    revoked market — AND with every surviving leg of the interrupted
    allocation — over the 3-month history is below the threshold. A
    replacement leg correlated with a leg the job still holds would turn
    the next zone shock into a double revocation, which is exactly what the
    filter exists to prevent; with no surviving legs (the single-market
    case) this is the paper's step 13 unchanged."""
    corr = feats.corr[revoked_market]
    out = {i for i in range(corr.shape[0]) if corr[i] < policy.correlation_threshold}
    for s in surviving:
        out &= {
            i
            for i in range(corr.shape[0])
            if feats.corr[s, i] < policy.correlation_threshold
        }
    return out


def restrict_after_revocation(
    S: List[Candidate],
    revoked: Candidate,
    W: Set[int],
    lifetimes: Dict[Candidate, float],
    already_revoked: Set[int],
    feats: Optional[MarketFeatures] = None,
    job: Optional[Job] = None,
    surviving: Sequence[int] = (),
) -> List[Candidate]:
    """Step 14 (+ fallback): S ← (S \\ {s}) ∩ W, lifetime-descending with
    the expected-cost-to-complete tie-break (pass ``job`` + ``feats`` to
    enable it; ``job`` carries the remaining work the cost is integrated
    over).

    Allocation-aware: a candidate survives the restriction only when EVERY
    leg market is in W or among the interrupted allocation's surviving legs
    (a repair that keeps live legs must stay eligible even though a leg is
    trivially correlated with itself). The revoked market itself is never
    in W (self-correlation is 1), so any candidate touching it drops out.
    For single-leg candidates this reduces to the pre-allocation rule
    ``i != revoked and i in W`` exactly."""
    keep = W | set(surviving)
    rest = [
        c for c in S if c != revoked and all(m in keep for m in _markets(c))
    ]
    if not rest:
        rest = [
            c
            for c in lifetimes
            if c != revoked
            and not any(m in already_revoked for m in _markets(c))
        ]
    if job is not None:
        tiebreak = lambda c: _ecc(job, feats, c)
    elif feats is not None:
        tiebreak = lambda c: (
            allocation_price(c, feats)
            if isinstance(c, Allocation)
            else float(feats.avg_price[c])
        )
    else:
        tiebreak = lambda c: 0.0
    return sorted(rest, key=lambda c: (-lifetimes[c], tiebreak(c), _stable(c)))


def remaining_job(job: Job, remaining_work_hours: float) -> Job:
    """The job with only its unfinished work — what re-provisioning after a
    revocation should integrate price/throughput over."""
    return dataclasses.replace(
        job, length_hours=max(float(remaining_work_hours), 1e-9)
    )


def plan_first_choice(
    job: Job, feats: MarketFeatures, policy: SiwoftPolicy
) -> Allocation:
    """Convenience: the allocation Alg. 1 provisions first for this job —
    a single-leg allocation whenever one shape fits (the paper's case), a
    multi-leg split when none does (or when ``policy.split_margin`` lets a
    sufficiently cheaper split win)."""
    suitable = find_suitable_allocations(job, feats, policy)
    if not suitable:
        raise ValueError(
            f"no allocation (≤{policy.max_legs} legs) fits {job.memory_gb} GB"
        )
    lifetimes = compute_allocation_lifetimes(feats, suitable)
    return highest(server_based_lifetime(job, lifetimes, policy, feats))
