"""Provisioning policies and the fault-tolerance overhead model.

``OverheadModel`` holds the physical constants every policy shares
(checkpoint/restore bandwidth to remote storage, instance startup time, the
2-minute revocation notice, the 4 GB live-migration memory bound the paper
cites from SpotOn [4]).

Policies:

* ``SiwoftPolicy``      — the paper's contribution (Algorithm 1): highest-
                          MTTR market with MTTR ≥ 2×job length, restart from
                          scratch on revocation, re-provision only from the
                          low-correlation set. NO fault-tolerance mechanism.
* ``CheckpointPolicy``  — FT baseline: periodic checkpoints to remote
                          storage; revocation → new instance + restore +
                          re-execute from last checkpoint.
* ``MigrationPolicy``   — FT baseline: on the 2-minute notice, live-migrate
                          if the footprint fits the notice window, else the
                          revocation behaves like an unplanned kill.
* ``ReplicationPolicy`` — FT baseline: k replicas on distinct markets; the
                          job restarts from scratch only if ALL replicas die.
* ``OnDemandPolicy``    — reference: on-demand instance, no revocations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.units import BYTES_PER_GB, MINUTES_PER_HOUR, SECONDS_PER_HOUR


@dataclasses.dataclass(frozen=True)
class OverheadModel:
    startup_hours: float = 150.0 / SECONDS_PER_HOUR  # boot + docker pull ≈ 2.5 min
    ckpt_bandwidth_gb_per_s: float = 0.05        # single-stream S3 ≈ 50 MB/s
    restore_bandwidth_gb_per_s: float = 0.05
    migration_bandwidth_gb_per_s: float = 1.0    # instance-to-instance
    live_migration_max_gb: float = 4.0           # paper cites SpotOn's bound
    revocation_notice_hours: float = 2.0 / MINUTES_PER_HOUR  # EC2's 2-minute warning
    storage_cost_per_gb_hour: float = 0.0        # S3 cost negligible vs compute

    def ckpt_hours(self, mem_gb: float) -> float:
        return mem_gb / self.ckpt_bandwidth_gb_per_s / SECONDS_PER_HOUR

    def restore_hours(self, mem_gb: float) -> float:
        return mem_gb / self.restore_bandwidth_gb_per_s / SECONDS_PER_HOUR

    def migration_hours(self, mem_gb: float) -> float:
        return mem_gb / self.migration_bandwidth_gb_per_s / SECONDS_PER_HOUR

    def reshard_hours(self, bytes_moved: float, interconnect_gbps: float) -> float:
        """Live cross-mesh reshard: bytes actually moved (leaf-by-leaf, see
        ``repro.dist.meshplan.reshard_bytes``) over the destination
        market's device interconnect — orders of magnitude faster than the
        remote-storage path ``restore_hours`` models."""
        if bytes_moved <= 0:
            return 0.0
        return bytes_moved / (max(interconnect_gbps, 1e-9) * BYTES_PER_GB) / SECONDS_PER_HOUR


def work_to_wall_hours(work_hours: float, throughput: float) -> float:
    """Wall-clock hours to complete ``work_hours`` of reference work at
    relative throughput θ — THE work↔wall conversion rule; every layer
    (provisioner admission, simulator progress, orchestrator billing)
    delegates here."""
    return float(work_hours) / max(float(throughput), 1e-9)


@dataclasses.dataclass(frozen=True)
class Job:
    """A batch job: pure-compute length (hours) and memory footprint (GB).

    ``length_hours`` is WORK, not wall time: hours of compute on the
    1-device reference shape (relative throughput 1.0). A market whose
    shape delivers throughput θ finishes the job in ``length_hours / θ``
    wall hours — see :meth:`wall_hours_on`. On a single-device menu
    (θ ≡ 1 everywhere) work and wall time coincide, which is the paper's
    setting."""

    length_hours: float
    memory_gb: float
    job_id: int = 0

    def wall_hours_on(self, throughput: float) -> float:
        """Wall-clock hours on a shape with relative throughput θ."""
        return work_to_wall_hours(self.length_hours, throughput)


@dataclasses.dataclass(frozen=True)
class SiwoftPolicy:
    name: str = "siwoft"
    lifetime_factor: float = 2.0        # Alg.1 step 8: MTTR ≥ 2 × job length
    correlation_threshold: float = 0.2  # "low revocation correlation" cut
    # beyond-paper hybrid: also checkpoint every `ckpt_interval_hours` (0=off)
    ckpt_interval_hours: float = 0.0
    # beyond-paper multi-leg allocations (core/allocation.py): a job whose
    # footprint fits no single menu shape splits across up to `max_legs`
    # spot markets (multi-leg mesh over DCN). `split_margin=None` keeps the
    # split search strictly as a fallback — single-market behavior is then
    # bit-identical to the pre-allocation provisioner; a float in (0, 1)
    # also admits opportunistic splits whose expected cost-to-complete
    # beats the best single shape by at least that fraction.
    max_legs: int = 2
    split_margin: Optional[float] = None
    # pairwise co-revocation budget for split legs: EVERY pair of legs in a
    # candidate split must co-revoke below this cut (a split correlated
    # with itself revokes as one market but pays DCN prices). None -> the
    # step-13 `correlation_threshold` doubles as the budget. Three-leg
    # splits (`max_legs=3`) face the test over all three pairs, and their
    # MTTR still composes as min over legs — admission only gets harder.
    split_correlation_budget: Optional[float] = None

    @property
    def split_corr_cut(self) -> float:
        """The pairwise co-revocation cut the split search applies."""
        return (
            self.split_correlation_budget
            if self.split_correlation_budget is not None
            else self.correlation_threshold
        )

    @property
    def uses_checkpoints(self) -> bool:
        return self.ckpt_interval_hours > 0


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    name: str = "checkpoint"
    ckpt_interval_hours: float = 1.0    # "number of checkpoints" knob
    # the paper's FT baseline provisions "a spot instance" with no market
    # intelligence -> random suitable market; "cheapest" is a smarter variant
    market_selection: str = "random"


@dataclasses.dataclass(frozen=True)
class MigrationPolicy:
    name: str = "migration"
    market_selection: str = "random"


@dataclasses.dataclass(frozen=True)
class ReplicationPolicy:
    name: str = "replication"
    degree: int = 2
    market_selection: str = "random"


@dataclasses.dataclass(frozen=True)
class OnDemandPolicy:
    name: str = "on_demand"
