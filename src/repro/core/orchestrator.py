"""SpotTrainingOrchestrator — the paper's provisioner driving a REAL JAX
training run.

The execution substrate (models, pjit train step, checkpoint manager) is
the framework's own; the provisioning layer decides WHERE each work segment
runs and what happens on a spot revocation:

* ``mode="siwoft"``      — Algorithm 1 picks the market (highest MTTR ≥ 2×
  the segment's expected duration); NO checkpoints are written. On a
  revocation the current segment's steps are lost and re-executed on a new
  low-correlation market. Completed segments survive: their state lives on
  the (new) instance via device_put handoff — job-queue semantics, not a
  fault-tolerance mechanism.
* ``mode="checkpoint"``  — FT baseline: random suitable market, periodic
  checkpoints through :class:`CheckpointManager`; revocation → restore the
  last checkpoint (recovery time) and re-execute the delta.
* ``mode="hybrid"``      — beyond-paper: Algorithm-1 market selection AND
  coarse checkpoints (what you actually want for week-long pretraining).

Instance-menu deviation (beyond the paper): every market is a *mesh shape*
(``device_count`` × ``memory_gb``, ``interconnect_gbps`` — see
``repro.core.market.InstanceShape``), and the job's memory requirement is
the model's real param+optimizer footprint (``dist.meshplan.
train_state_bytes``), not a hard-coded class. When provisioning lands on a
market whose shape differs from the one the live state sits on, siwoft/
hybrid migrate by a LIVE CROSS-MESH RESHARD: the ``TrainState`` moves
leaf-by-leaf onto the new market's mesh (``dist.elastic.reshard_tree``),
the train step re-jits for the new mesh, and training continues — no
checkpoint touched. The reshard cost model: ``reshard_bytes`` (slice-
overlap bytes actually moved, ``dist.meshplan.reshard_bytes``) over the
destination market's interconnect, billed to the ``reshard`` time/cost
component so Fig-1-style breakdowns show reshard vs recovery vs
re-execution head-to-head. The checkpoint baseline instead pays
``recovery`` (full state through remote storage) and its moved bytes are
reported as ``restore_bytes`` — the byte-level comparison the paper's
thesis needs.

Throughput deviation (beyond the paper): each market's shape carries a
relative throughput (``repro.core.market.shape_throughput`` — sublinear in
device count), so ``steps_per_trace_hour`` is the 1-device REFERENCE rate
and a provisioned market delivers ``steps_per_trace_hour × θ`` steps per
trace hour. Provisioning ranks by expected cost-to-complete (price
integrated over the shape-dependent wall time) rather than raw $/h, so
siwoft deliberately migrates to a bigger, pricier shape when it finishes
the remaining work cheaper. The orchestrator also MEASURES real steps/sec
per mesh shape from ``run_segment`` wall timings (``ThroughputTracker``)
and corrects the analytic model with the observed ratios on every
subsequent pick; the report carries the measured per-shape rates
(``shape_steps_per_hour``) and the first pick's expected
``cost_to_complete``.

Allocation deviation (beyond the paper, ISSUE 4): the unit of
provisioning is a multi-leg ``repro.core.allocation.Allocation``. A job
whose footprint fits no single menu shape splits across up to
``policy.max_legs`` markets: the legs form ONE mesh
(``ElasticMeshManager.plan_for_allocation`` — contiguous per-leg device
spans on the local pool), billed per leg at each market's own price
(``Breakdown.leg_cost`` sums exactly to the total), running at the
DCN-discounted combined throughput. A revocation of ONE leg is a PARTIAL
reshard: the surviving legs keep their shards, the provisioner swaps only
the lost leg for a same-shape low-correlation market
(``_pick_allocation_siwoft(repair_of=...)``), and the bill is the lost
leg's distinct state slices over DCN (``dist.meshplan.leg_state_bytes``)
— strictly fewer bytes than the full restore a checkpoint baseline pays.
Single-leg allocations reproduce the pre-allocation orchestrator
bit-exactly.

Revocations: siwoft/hybrid markets revoke when their future price trace
crosses on-demand (mapped trace-hour → step index at the shape's step
rate); the FT baseline gets the paper's fixed injected revocation count.
Costs accrue per billing cycle against the market's trace price with an
explicit monotone wall clock that advances at the shape-dependent rate.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.config.base import ShardingLayout, TrainConfig
from repro.core import provisioner as alg
from repro.core.accounting import (
    Breakdown,
    PriceTable,
    Session,
    bill_session,
    settle_leg,
)
from repro.core.allocation import Allocation, Leg
from repro.core.market import (
    THROUGHPUT_EFFICIENCY_CEIL,
    MarketSet,
    shape_throughput,
)
from repro.core.policies import Job, OverheadModel, SiwoftPolicy
from repro.core.units import BYTES_PER_GIB, SECONDS_PER_HOUR
from repro.data import SyntheticLM
from repro.dist.elastic import reshard_tree
from repro.obs import events as obs_ev
from repro.obs.recorder import current as obs_current
from repro.dist.meshplan import (
    ElasticMeshManager,
    MeshPlan,
    ThroughputTracker,
    leg_state_bytes,
    live_shardings,
    reshard_bytes,
    train_state_bytes,
    tree_bytes,
)
from repro.models import zoo
from repro.train.loop import Revoked, make_jitted_step, run_segment
from repro.train.steps import init_train_state


@dataclasses.dataclass
class OrchestratorReport:
    total_steps: int
    useful_steps: int
    wasted_steps: int
    revocations: int
    markets_used: List[int]
    cost_dollars: float
    wall_seconds: float
    losses: List[float]
    # byte-level migration accounting (beyond the paper)
    reshard_bytes: int = 0          # bytes moved by live cross-mesh reshards
    restore_bytes: int = 0          # bytes pulled through checkpoint restores
    reshard_events: int = 0         # migrations that moved live state
    mesh_shapes: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    breakdown: Optional[Breakdown] = None
    # throughput accounting (beyond the paper): measured steps/hour per mesh
    # shape ("DxM" -> steps/hour, from run_segment wall timings) and the
    # expected $ cost-to-complete of the first provisioned market — the
    # quantity the provisioner ranked by (price/throughput over the work,
    # risk-adjusted), as opposed to that market's raw $/h
    shape_steps_per_hour: Dict[str, float] = dataclasses.field(default_factory=dict)
    cost_to_complete: float = 0.0
    # multi-leg allocation accounting (beyond the paper): the leg tuple of
    # every provisioned allocation (singletons for one-market picks), the
    # per-market dollar split of cost_dollars (must sum to it — pinned by
    # tests/test_allocation.py), and how many revocations were repaired by
    # rebuilding ONE leg over DCN instead of a full re-provision
    allocations_used: List[Tuple[int, ...]] = dataclasses.field(default_factory=list)
    leg_costs: Dict[int, float] = dataclasses.field(default_factory=dict)
    leg_repairs: int = 0

    @property
    def goodput(self) -> float:
        return self.useful_steps / max(self.total_steps, 1)


class SpotTrainingOrchestrator:
    def __init__(
        self,
        model: zoo.Model,
        dataset: SyntheticLM,
        mesh,
        history: MarketSet,
        future: MarketSet,
        *,
        mode: str = "siwoft",
        tc: TrainConfig = TrainConfig(),
        layout: ShardingLayout = ShardingLayout(),
        segment_steps: int = 20,
        steps_per_trace_hour: int = 50,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 10,
        ft_revocations: int = 2,
        seed: int = 0,
        overheads: OverheadModel = OverheadModel(),
        mesh_manager: Optional[ElasticMeshManager] = None,
        policy: Optional[SiwoftPolicy] = None,
        job_memory_gb: Optional[float] = None,
    ):
        assert mode in ("siwoft", "checkpoint", "hybrid")
        self.model = model
        self.dataset = dataset
        # ``mesh`` seeds the local device pool the menu shapes are built
        # from; the actual execution mesh per segment comes from the
        # provisioned market's device_count.
        self.mesh = mesh
        self.meshman = mesh_manager or ElasticMeshManager.from_mesh(mesh)
        self.mode = mode
        self.tc = tc
        self.layout = layout
        self.segment_steps = segment_steps
        self.steps_per_hour = steps_per_trace_hour
        self.ft_revocations = ft_revocations
        self.seed = seed
        self.ov = overheads
        self.feats = alg.MarketFeatures.from_history(history)
        self.future = future
        self._rev = future.revocation_matrix()
        self.ckpt = (
            CheckpointManager(ckpt_dir, keep=3)
            if ckpt_dir and mode in ("checkpoint", "hybrid")
            else None
        )
        self.ckpt_every = ckpt_every
        self.policy = policy or SiwoftPolicy()
        # planner-level footprint override (GB): lets a run exercise the
        # multi-leg split path (a footprint larger than every menu shape)
        # while the local device pool keeps simulating the execution — the
        # reduced model's real bytes still drive the reshard accounting
        self.job_memory_gb = job_memory_gb
        # one jitted step + state-sharding tree per distinct mesh plan
        self._steps: Dict[Tuple, Tuple[Any, Any]] = {}
        # measured steps/sec per mesh-plan key (EMA) + the analytic
        # prediction for each honored shape — the correction of the menu's
        # throughput model by what run_segment actually delivered
        self.thr_tracker = ThroughputTracker()
        self._analytic_honored: Dict[Tuple, float] = {}

    # ------------------------------------------------------------------
    def _segment_job(self, total_steps: int) -> Job:
        # length in WORK hours: steps at the 1-device reference rate; a
        # provisioned shape with throughput θ delivers θ × steps_per_hour
        hours = total_steps / self.steps_per_hour
        # real footprint: fp32 params + both Adam moments, from the model's
        # ParamSpec tree via the dist layer (was: hard-coded 16 GB) — unless
        # the planner-level override stands in for a bigger production model
        mem_gb = (
            self.job_memory_gb
            if self.job_memory_gb is not None
            else train_state_bytes(self.model) / BYTES_PER_GIB
        )
        return Job(length_hours=hours, memory_gb=mem_gb, job_id=0)

    def _jitted_for(self, plan: MeshPlan):
        entry = self._steps.get(plan.key)
        if entry is None:
            jitted, state_sh = make_jitted_step(
                self.model, self.tc, self.layout, plan.mesh
            )
            entry = (jitted, state_sh)
            self._steps[plan.key] = entry
        return entry

    def _plan_key_for(self, market: int) -> Tuple:
        plan = self.meshman.plan_for(self.future.markets[market].device_count)
        if plan.key not in self._analytic_honored:
            self._analytic_honored[plan.key] = shape_throughput(plan.device_count)
        return plan.key

    def _effective_feats(self) -> alg.MarketFeatures:
        """Menu features with the throughput column calibrated by measured
        per-shape step rates: analytic model × measured-vs-analytic
        correction for the market's (honored) mesh shape. Until two
        distinct shapes have been timed the correction is 1.0 and the
        analytic model stands."""
        thr = np.array(self.feats.throughput, dtype=float, copy=True)
        for i, m in enumerate(self.future.markets):
            if m.steps_per_hour is not None:
                # an explicit measured rate in the trace is ground truth:
                # neither the local-pool correction nor the analytic
                # ceiling applies to it
                continue
            key = self._plan_key_for(i)
            thr[i] *= self.thr_tracker.correction(key, self._analytic_honored)
            # the correction is anchored on the local pool's honored shapes
            # (default-bandwidth exponent), while the analytic value it
            # scales is bandwidth-aware — cap the product at the model's
            # sublinear ceiling so no calibration can claim superlinear
            # scaling
            cap = float(self.feats.device_count[i]) ** THROUGHPUT_EFFICIENCY_CEIL
            thr[i] = min(thr[i], cap)
        return dataclasses.replace(self.feats, throughput=thr)

    def _throughput_of(self, feats: alg.MarketFeatures, market: int) -> float:
        return max(float(feats.throughput[market]), 1e-9)

    def _pick_allocation_siwoft(
        self,
        job: Job,
        feats,
        revoked: Set[int],
        repair_of: Optional[Tuple[Allocation, int]] = None,
    ) -> Tuple[Allocation, bool]:
        """Algorithm 1 over allocations; returns (allocation, is_repair).

        ``repair_of = (interrupted_allocation, revoked_market)`` activates
        the partial-reshard path: before a full re-provision, try to swap
        ONLY the lost leg for a same-shape market that is low-correlated
        with the revoked market AND with every surviving leg. A repair
        keeps the mesh plan (and the live state's layout) intact, so the
        only migration bytes are the lost leg's distinct slices over DCN —
        strictly fewer than a full restore. When no repair admits, fall
        back to the ordinary allocation pick."""
        policy = self.policy
        if repair_of is not None and repair_of[0].is_split:
            prev, rev_market = repair_of
            lost = next(l for l in prev.legs if l.market == rev_market)
            surviving = tuple(m for m in prev.markets if m != rev_market)
            W = alg.find_low_correlation(
                feats, rev_market, policy, surviving=surviving
            )
            repairs = []
            for w in sorted(W):
                if w in revoked or w in prev.markets:
                    continue
                if int(feats.device_count[w]) != lost.device_count:
                    continue  # same shape class: the mesh plan survives
                cand = prev.replace_leg(rev_market, Leg(w, lost.device_count))
                if alg.allocation_memory_gb(cand, feats) < job.memory_gb:
                    continue
                if alg.allocation_mttr(cand, feats) >= (
                    policy.lifetime_factor
                    * alg.allocation_wall_hours(job.length_hours, feats, cand)
                ):
                    repairs.append(cand)
            if repairs:
                repairs.sort(
                    key=lambda a: (
                        alg.allocation_expected_cost_to_complete(
                            job.length_hours, feats, a
                        ),
                        a.markets,
                    )
                )
                return repairs[0], True
        suitable = [
            a
            for a in alg.find_suitable_allocations(job, feats, policy)
            if not any(m in revoked for m in a.markets)
        ]
        if not suitable:
            suitable = alg.find_suitable_allocations(job, feats, policy)
        if not suitable:
            raise ValueError(
                f"{job.memory_gb} GB fits no allocation of ≤{policy.max_legs} legs"
            )
        lifetimes = alg.compute_allocation_lifetimes(feats, suitable)
        S = alg.server_based_lifetime(job, lifetimes, policy, feats)
        return alg.highest(S), False

    def _pick_market_random(self, job: Job, feats, revoked: Set[int], salt: int) -> int:
        cands = [
            i for i in alg.find_suitable_servers(job, feats) if i not in revoked
        ]
        if not cands:
            cands = alg.find_suitable_servers(job, feats)
        if not cands:
            raise ValueError(
                f"FT baseline cannot provision {job.memory_gb} GB: no single "
                "menu shape fits (splitting is a no-FT allocation mechanism)"
            )
        rng = np.random.default_rng((self.seed, salt))
        return int(cands[rng.integers(len(cands))])

    def _revocation_step(
        self, market: int, from_step: int, wall: float, rate: float
    ) -> Optional[int]:
        """Map the market's next trace revocation (first trace hour ≥
        ``wall`` whose price crosses on-demand) to a global step index,
        at this market's shape-dependent step rate (steps per trace hour)."""
        h = int(math.ceil(wall))
        tail = self._rev[market, h:]
        if not tail.any():
            return None
        rev_hour = h + int(np.argmax(tail))
        return from_step + max(int((rev_hour - wall) * rate), 0)

    def _revocation_step_alloc(
        self, alloc: Allocation, from_step: int, wall: float, rate: float
    ) -> Tuple[Optional[int], Optional[int]]:
        """Earliest trace revocation across the allocation's legs, mapped to
        a global step index at the allocation's combined step rate; returns
        (step, revoked leg's market). Any leg revocation interrupts the
        whole allocation — the min-MTTR semantics the admission rule
        priced. Leg order breaks exact hour ties deterministically."""
        best_step: Optional[int] = None
        best_market: Optional[int] = None
        for m in alloc.markets:
            s = self._revocation_step(m, from_step, wall, rate)
            if s is not None and (best_step is None or s < best_step):
                best_step, best_market = s, m
        return best_step, best_market

    # ------------------------------------------------------------------
    def run(self, total_steps: int) -> OrchestratorReport:
        state = init_train_state(self.model, jax.random.key(self.tc.seed))
        job = self._segment_job(total_steps)
        revoked: Set[int] = set()
        markets: List[int] = []
        allocations: List[Tuple[int, ...]] = []
        mesh_shapes: List[Tuple[int, int]] = []
        losses: List[float] = []
        bd = Breakdown()
        useful = wasted = revs = 0
        moved_total = 0
        restore_total = 0
        reshard_events = 0
        leg_repairs = 0
        first_ecc = 0.0
        active_key = None  # plan.key the live state is laid out for
        # a pending one-leg rebuild: (interrupted allocation, revoked
        # market) + the lost leg's distinct-slice bytes, measured at
        # revocation time and billed over DCN on the repaired session
        pending_repair: Optional[Tuple[Allocation, int]] = None
        pending_repair_bytes = 0
        # staggered billing cycles across a split revocation: surviving
        # legs defer their billing buffer (their occupancy continues into
        # the repaired session) — market -> (cycle anchor, deferred end
        # wall), settled when the leg is finally dropped or at run end
        carry_anchors: Dict[int, Tuple[float, float]] = {}
        # PriceTable routes bill_session through the vectorized biller;
        # identical to the spot_price closure call-for-call (same clamp)
        price_of = PriceTable(self.future.prices)
        step = 0
        wall = 0.0  # trace wall-clock hours; advances at the shape's rate
        rec = obs_current()
        if rec.enabled:
            rec.emit(
                obs_ev.RunStart(
                    t=wall,
                    subsystem="orchestrator",
                    label=self.mode,
                    horizon_hours=float(self.future.n_hours),
                )
            )
            rec.emit(obs_ev.price_trace(wall, self.future.prices))
        # real (not simulated) wall clock: measures actual segment speed for
        # the ThroughputTracker; never enters the deterministic trace ledger
        t0 = time.perf_counter()  # repro-lint: disable=D001

        # FT baseline: fixed injected revocation schedule (paper methodology)
        rng = np.random.default_rng((self.seed, 77))
        ft_rev_steps = (
            sorted(rng.integers(1, max(total_steps, 2), size=self.ft_revocations).tolist())
            if self.mode == "checkpoint"
            else []
        )

        while step < total_steps:
            # provisioning consults the measured-throughput-corrected menu:
            # after a segment on a shape, its real steps/sec feeds back into
            # the cost-to-complete ranking for every later pick
            feats = self._effective_feats()
            remaining = alg.remaining_job(job, (total_steps - step) / self.steps_per_hour)
            if self.mode in ("siwoft", "hybrid"):
                alloc, is_repair = self._pick_allocation_siwoft(
                    remaining, feats, revoked, repair_of=pending_repair
                )
            else:
                market = self._pick_market_random(
                    remaining, feats, revoked, salt=len(allocations)
                )
                alloc = Allocation.single(
                    market, self.future.markets[market].device_count
                )
                is_repair = False
            if not allocations:
                first_ecc = alg.allocation_expected_cost_to_complete(
                    job.length_hours, feats, alloc
                )
            allocations.append(alloc.markets)
            markets.extend(alloc.markets)
            m = self.future.markets[alloc.legs[0].market]
            plan = self.meshman.plan_for_allocation(alloc.device_counts)
            mesh_shapes.append(plan.mesh_shape)
            jitted, state_sh = self._jitted_for(plan)
            # steps this allocation delivers per trace hour: reference rate ×
            # the (calibrated) relative throughput — for splits, the
            # DCN-discounted combined throughput over the union mesh
            rate = self.steps_per_hour * max(
                alg.allocation_throughput(alloc, feats), 1e-9
            )

            if rec.enabled:
                rec.emit(
                    obs_ev.Provision(
                        t=wall,
                        market_id=int(alloc.legs[0].market),
                        legs=tuple(int(m) for m in alloc.markets),
                    )
                )
            session = Session(alloc.legs[0].market, wall, legs=alloc.markets)
            if carry_anchors:
                # legs surviving the last split revocation carry their own
                # billing-cycle anchors into this session; carried legs
                # this allocation no longer holds settle their final
                # partial cycle now (leg-level billing-cycle staggering)
                session.leg_anchors = tuple(
                    carry_anchors.get(m, (wall,))[0] for m in alloc.markets
                )
                for m in list(carry_anchors):
                    if m in alloc.markets:
                        del carry_anchors[m]
                    else:
                        a, end = carry_anchors.pop(m)
                        if rec.enabled:
                            rec.emit(
                                obs_ev.LegSettled(
                                    t=wall, market_id=int(m), anchor=a, end_wall=end
                                )
                            )
                        settle_leg(bd, m, a, end, price_of)
            session.add("startup", self.ov.startup_hours)

            if pending_repair is not None and active_key == plan.key:
                prev_alloc, _ = pending_repair
                if is_repair:
                    # partial reshard: only the lost leg is rebuilt — its
                    # distinct state slices cross the DCN once; surviving
                    # legs keep their shards, the jitted step is reused
                    moved = pending_repair_bytes
                    leg_repairs += 1
                else:
                    # the ordinary pick replaced more than the lost leg
                    # (no same-shape repair admitted): every leg span whose
                    # market changed must be refilled over DCN — which is
                    # why this always costs at least as much as a repair
                    changed = [
                        i
                        for i in range(
                            min(len(alloc.legs), len(prev_alloc.legs))
                        )
                        if alloc.markets[i] != prev_alloc.markets[i]
                    ] + list(range(len(prev_alloc.legs), len(alloc.legs)))
                    moved = sum(
                        leg_state_bytes(state, state_sh, plan, i)
                        for i in changed
                        if i < len(plan.leg_spans)
                    )
                if moved:
                    moved_total += moved
                    reshard_events += 1
                    reshard_h = self.ov.reshard_hours(moved, alloc.dcn_gbps)
                    if rec.enabled:
                        rec.emit(
                            obs_ev.ReshardStart(
                                t=wall, bytes_moved=int(moved), gbps=alloc.dcn_gbps
                            )
                        )
                        rec.emit(obs_ev.ReshardDone(t=wall + reshard_h, hours=reshard_h))
                    session.add("reshard", reshard_h)
            pending_repair, pending_repair_bytes = None, 0

            # live cross-mesh migration: the state's current layout differs
            # from the provisioned market's mesh -> move it, price it
            if active_key != plan.key:
                if active_key is not None:
                    if self.mode in ("siwoft", "hybrid"):
                        moved = reshard_bytes(state, live_shardings(state), state_sh)
                        moved_total += moved
                        reshard_events += 1
                        reshard_h = self.ov.reshard_hours(moved, m.interconnect_gbps)
                        if rec.enabled:
                            rec.emit(
                                obs_ev.ReshardStart(
                                    t=wall,
                                    bytes_moved=int(moved),
                                    gbps=m.interconnect_gbps,
                                )
                            )
                            rec.emit(
                                obs_ev.ReshardDone(t=wall + reshard_h, hours=reshard_h)
                            )
                        session.add("reshard", reshard_h)
                    else:
                        # the checkpoint baseline has no live-handoff
                        # mechanism: crossing instances means a checkpoint
                        # write + restore through remote storage, full
                        # state size (post-revocation restores skip this
                        # branch via active_key = None — already billed)
                        restore_total += tree_bytes(state)
                        session.add("recovery", self.ov.restore_hours(job.memory_gb))
                state = reshard_tree(state, state_sh)
                active_key = plan.key

            if self.mode == "checkpoint":
                rev_at = ft_rev_steps[revs] if revs < len(ft_rev_steps) else None
                rev_market = alloc.legs[0].market if rev_at is not None else None
            else:
                rev_at, rev_market = self._revocation_step_alloc(
                    alloc, step, wall + session.used_hours, rate
                )

            seg_start = step
            seg_state = state
            n = min(self.segment_steps, total_steps - step)

            try:
                res = run_segment(
                    self.model, seg_state, self.dataset, plan.mesh, self.tc,
                    self.layout,
                    num_steps=n,
                    start_step=step,
                    ckpt=self.ckpt,
                    ckpt_every=self.ckpt_every if self.mode in ("checkpoint", "hybrid") else 0,
                    revoke_at_step=(lambda s: rev_at is not None and s >= rev_at),
                    jitted=jitted,
                )
                state = res.state
                losses.extend(res.losses)
                useful += res.steps_done
                session.add("execution", res.steps_done / rate)
                step += res.steps_done
                # feed the measured step rate back into the throughput model
                self.thr_tracker.observe(
                    plan.key, res.steps_done, sum(res.step_seconds)
                )
            except Revoked as r:
                done = max(r.last_step - seg_start + 1, 0)
                revs += 1
                if rec.enabled:
                    rec.emit(obs_ev.Revoke(t=wall, market_id=int(rev_market)))
                revoked.add(rev_market)
                session.add("re_execution", done / rate)
                handoff = False  # true when live state survives in memory
                if self.mode == "checkpoint" and self.ckpt is not None:
                    self.ckpt.wait()
                    latest = self.ckpt.latest_step()
                    if latest is not None:
                        _, state = self.ckpt.restore(latest, like=seg_state)
                        state = jax.tree_util.tree_map(jax.numpy.asarray, state)
                        restore_total += tree_bytes(state)
                        step = latest
                    else:
                        state = init_train_state(self.model, jax.random.key(self.tc.seed))
                        step = 0
                    # the restored state is host-materialized: it must be
                    # re-laid-out for whatever mesh the next market brings
                    active_key = None
                    # steps retained via a mid-segment checkpoint stay useful
                    retained = max(0, step - seg_start)
                    useful += retained
                    wasted += max(done - retained, 0)
                    session.add("recovery", self.ov.restore_hours(job.memory_gb))
                elif self.mode == "hybrid" and self.ckpt is not None:
                    self.ckpt.wait()
                    latest = self.ckpt.latest_step()
                    if latest is not None and latest > seg_start:
                        _, state = self.ckpt.restore(latest, like=seg_state)
                        state = jax.tree_util.tree_map(jax.numpy.asarray, state)
                        restore_total += tree_bytes(state)
                        step = latest
                        active_key = None
                        retained = max(0, step - seg_start)
                        useful += retained
                        wasted += max(done - retained, 0)
                        session.add("recovery", self.ov.restore_hours(job.memory_gb))
                    else:
                        # no checkpoint inside the segment: live-state
                        # handoff, same as siwoft (reshard on next pick)
                        state = seg_state
                        step = seg_start
                        wasted += done
                        handoff = True
                else:
                    # P-SIWOFT: segment state survives via in-memory handoff
                    # (a live reshard onto the next market's mesh); steps
                    # inside the segment are lost
                    state = seg_state
                    step = seg_start
                    wasted += done
                    handoff = True
                if handoff and alloc.is_split:
                    # one leg died, the rest of the mesh is alive: measure
                    # the lost leg's distinct-slice bytes NOW (the layout
                    # the survivors still hold) so the next pick can price
                    # a partial rebuild over DCN — same in siwoft & hybrid
                    leg_idx = alloc.markets.index(rev_market)
                    pending_repair = (alloc, rev_market)
                    pending_repair_bytes = leg_state_bytes(
                        seg_state, state_sh, plan, leg_idx
                    )
            # leg-level billing-cycle staggering: when a split lost ONE leg
            # and the live state survives (a repair is pending), only the
            # revoked leg's cycle closes here — the survivors' occupancy
            # continues into the repaired session, so their buffers defer
            # with their original anchors
            defer = pending_repair is not None and pending_repair[0] is alloc
            if defer or session.leg_anchors is not None:
                anchors = session.leg_anchors or (
                    (session.start_wall,) * len(alloc.markets)
                )
                releases = (
                    tuple(m == pending_repair[1] for m in alloc.markets)
                    if defer
                    else (True,) * len(alloc.markets)
                )
                session.leg_anchors = anchors
                session.leg_releases = releases
            if rec.enabled:
                rec.emit(obs_ev.session_billed(wall, session))
            wall += bill_session(session, price_of, bd)
            if defer:
                end = session.start_wall + session.used_hours
                for m, a, rel in zip(alloc.markets, anchors, releases):
                    if not rel:
                        carry_anchors[m] = (a, end)

        for m, (a, end) in sorted(carry_anchors.items()):
            if rec.enabled:
                rec.emit(
                    obs_ev.LegSettled(t=wall, market_id=int(m), anchor=a, end_wall=end)
                )
            settle_leg(bd, m, a, end, price_of)
        if self.ckpt is not None:
            self.ckpt.wait()
        # the breakdown carries the run's own revocation count and simulated
        # wall clock (report.wall_seconds stays the real perf-counter time),
        # which is also what makes the replay oracle uniform across loops
        bd.revocations = revs
        bd.wall_time = wall
        if rec.enabled:
            rec.emit(obs_ev.breakdown_pin(wall, bd))
            rec.emit(obs_ev.RunEnd(t=wall, wall_hours=wall))
        return OrchestratorReport(
            total_steps=useful + wasted,
            useful_steps=useful,
            wasted_steps=wasted,
            revocations=revs,
            markets_used=markets,
            cost_dollars=bd.total_cost,
            wall_seconds=time.perf_counter() - t0,  # repro-lint: disable=D001
            losses=losses,
            reshard_bytes=moved_total,
            restore_bytes=restore_total,
            reshard_events=reshard_events,
            mesh_shapes=mesh_shapes,
            breakdown=bd,
            shape_steps_per_hour={
                f"{k[1][0]}x{k[1][1]}": sps * SECONDS_PER_HOUR
                for k, sps in self.thr_tracker.measured.items()
            },
            cost_to_complete=first_ecc,
            allocations_used=allocations,
            leg_costs=dict(bd.leg_cost),
            leg_repairs=leg_repairs,
        )
