"""SpotTrainingOrchestrator — the paper's provisioner driving a REAL JAX
training run.

The execution substrate (models, pjit train step, checkpoint manager) is
the framework's own; the provisioning layer decides WHERE each work segment
runs and what happens on a spot revocation:

* ``mode="siwoft"``      — Algorithm 1 picks the market (highest MTTR ≥ 2×
  the segment's expected duration); NO checkpoints are written. On a
  revocation the current segment's steps are lost and re-executed on a new
  low-correlation market. Completed segments survive: their state lives on
  the (new) instance via device_put handoff — job-queue semantics, not a
  fault-tolerance mechanism.
* ``mode="checkpoint"``  — FT baseline: random suitable market, periodic
  checkpoints through :class:`CheckpointManager`; revocation → restore the
  last checkpoint (recovery time) and re-execute the delta.
* ``mode="hybrid"``      — beyond-paper: Algorithm-1 market selection AND
  coarse checkpoints (what you actually want for week-long pretraining).

Revocations: siwoft/hybrid markets revoke when their future price trace
crosses on-demand (mapped trace-hour → step index); the FT baseline gets
the paper's fixed injected revocation count. Costs accrue per billing cycle
against the market's trace price with measured wall time.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, List, Optional, Set

import jax
import numpy as np

from repro.ckpt import CheckpointManager
from repro.config.base import ShardingLayout, TrainConfig
from repro.core import provisioner as alg
from repro.core.accounting import Breakdown, Session, bill_session
from repro.core.market import MarketSet
from repro.core.policies import Job, OverheadModel, SiwoftPolicy
from repro.data import SyntheticLM
from repro.models import zoo
from repro.train.loop import Revoked, SegmentResult, make_jitted_step, run_segment
from repro.train.steps import TrainState, init_train_state


@dataclasses.dataclass
class OrchestratorReport:
    total_steps: int
    useful_steps: int
    wasted_steps: int
    revocations: int
    markets_used: List[int]
    cost_dollars: float
    wall_seconds: float
    losses: List[float]

    @property
    def goodput(self) -> float:
        return self.useful_steps / max(self.total_steps, 1)


class SpotTrainingOrchestrator:
    def __init__(
        self,
        model: zoo.Model,
        dataset: SyntheticLM,
        mesh,
        history: MarketSet,
        future: MarketSet,
        *,
        mode: str = "siwoft",
        tc: TrainConfig = TrainConfig(),
        layout: ShardingLayout = ShardingLayout(),
        segment_steps: int = 20,
        steps_per_trace_hour: int = 50,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 10,
        ft_revocations: int = 2,
        seed: int = 0,
        overheads: OverheadModel = OverheadModel(),
    ):
        assert mode in ("siwoft", "checkpoint", "hybrid")
        self.model = model
        self.dataset = dataset
        self.mesh = mesh
        self.mode = mode
        self.tc = tc
        self.layout = layout
        self.segment_steps = segment_steps
        self.steps_per_hour = steps_per_trace_hour
        self.ft_revocations = ft_revocations
        self.seed = seed
        self.ov = overheads
        self.feats = alg.MarketFeatures.from_history(history)
        self.future = future
        self._rev = future.revocation_matrix()
        self.ckpt = (
            CheckpointManager(ckpt_dir, keep=3)
            if ckpt_dir and mode in ("checkpoint", "hybrid")
            else None
        )
        self.ckpt_every = ckpt_every
        self._jitted, _ = make_jitted_step(model, tc, layout, mesh)

    # ------------------------------------------------------------------
    def _segment_job(self, total_steps: int) -> Job:
        hours = total_steps / self.steps_per_hour
        mem_gb = 16.0  # class of instance the training host needs
        return Job(length_hours=hours, memory_gb=mem_gb, job_id=0)

    def _pick_market_siwoft(self, job: Job, revoked: Set[int]) -> int:
        suitable = [
            i for i in alg.find_suitable_servers(job, self.feats) if i not in revoked
        ]
        if not suitable:
            suitable = alg.find_suitable_servers(job, self.feats)
        lifetimes = alg.compute_lifetime(self.feats, suitable)
        policy = SiwoftPolicy()
        S = alg.server_based_lifetime(job, lifetimes, policy, self.feats)
        return alg.highest(S)

    def _pick_market_random(self, job: Job, revoked: Set[int], salt: int) -> int:
        cands = [
            i for i in alg.find_suitable_servers(job, self.feats) if i not in revoked
        ]
        if not cands:
            cands = alg.find_suitable_servers(job, self.feats)
        rng = np.random.default_rng((self.seed, salt))
        return int(cands[rng.integers(len(cands))])

    def _revocation_step(self, market: int, from_step: int) -> Optional[int]:
        """Map the market's next trace revocation to a global step index."""
        hour0 = from_step / self.steps_per_hour
        h = int(math.ceil(hour0))
        tail = self._rev[market, h:]
        if not tail.any():
            return None
        rev_hour = h + int(np.argmax(tail))
        return int(rev_hour * self.steps_per_hour)

    # ------------------------------------------------------------------
    def run(self, total_steps: int) -> OrchestratorReport:
        state = init_train_state(self.model, jax.random.key(self.tc.seed))
        job = self._segment_job(total_steps)
        revoked: Set[int] = set()
        markets: List[int] = []
        losses: List[float] = []
        bd = Breakdown()
        useful = wasted = revs = 0
        step = 0
        t0 = time.perf_counter()

        # FT baseline: fixed injected revocation schedule (paper methodology)
        rng = np.random.default_rng((self.seed, 77))
        ft_rev_steps = (
            sorted(rng.integers(1, max(total_steps, 2), size=self.ft_revocations).tolist())
            if self.mode == "checkpoint"
            else []
        )

        while step < total_steps:
            if self.mode in ("siwoft", "hybrid"):
                market = self._pick_market_siwoft(job, revoked)
            else:
                market = self._pick_market_random(job, revoked, salt=len(markets))
            markets.append(market)

            if self.mode == "checkpoint":
                rev_at = ft_rev_steps[revs] if revs < len(ft_rev_steps) else None
            else:
                rev_at = self._revocation_step(market, step)

            seg_start = step
            seg_state = state
            n = min(self.segment_steps, total_steps - step)
            session = Session(market, step / self.steps_per_hour)
            session.add("startup", self.ov.startup_hours)

            try:
                res = run_segment(
                    self.model, seg_state, self.dataset, self.mesh, self.tc,
                    self.layout,
                    num_steps=n,
                    start_step=step,
                    ckpt=self.ckpt,
                    ckpt_every=self.ckpt_every if self.mode in ("checkpoint", "hybrid") else 0,
                    revoke_at_step=(lambda s: rev_at is not None and s >= rev_at),
                    jitted=self._jitted,
                )
                state = res.state
                losses.extend(res.losses)
                useful += res.steps_done
                session.add("execution", res.steps_done / self.steps_per_hour)
                step += res.steps_done
            except Revoked as r:
                done = max(r.last_step - seg_start + 1, 0)
                revs += 1
                revoked.add(market)
                session.add("re_execution", done / self.steps_per_hour)
                if self.mode == "checkpoint" and self.ckpt is not None:
                    self.ckpt.wait()
                    latest = self.ckpt.latest_step()
                    if latest is not None:
                        _, state = self.ckpt.restore(latest, like=seg_state)
                        state = jax.tree_util.tree_map(jax.numpy.asarray, state)
                        step = latest
                    else:
                        state = init_train_state(self.model, jax.random.key(self.tc.seed))
                        step = 0
                    # steps retained via a mid-segment checkpoint stay useful
                    retained = max(0, step - seg_start)
                    useful += retained
                    wasted += max(done - retained, 0)
                    session.add("recovery", self.ov.restore_hours(job.memory_gb))
                elif self.mode == "hybrid" and self.ckpt is not None:
                    self.ckpt.wait()
                    latest = self.ckpt.latest_step()
                    if latest is not None and latest > seg_start:
                        _, state = self.ckpt.restore(latest, like=seg_state)
                        state = jax.tree_util.tree_map(jax.numpy.asarray, state)
                        step = latest
                    else:
                        state = seg_state
                        step = seg_start
                    retained = max(0, step - seg_start)
                    useful += retained
                    wasted += max(done - retained, 0)
                    session.add("recovery", self.ov.restore_hours(job.memory_gb))
                else:
                    # P-SIWOFT: segment state survives via in-memory handoff;
                    # steps inside the segment are lost
                    state = seg_state
                    step = seg_start
                    wasted += done
            bill_session(session, lambda m, h: self.future.spot_price(m, h), bd)

        if self.ckpt is not None:
            self.ckpt.wait()
        return OrchestratorReport(
            total_steps=useful + wasted,
            useful_steps=useful,
            wasted_steps=wasted,
            revocations=revs,
            markets_used=markets,
            cost_dollars=bd.total_cost,
            wall_seconds=time.perf_counter() - t0,
            losses=losses,
        )
