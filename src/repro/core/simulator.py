"""Discrete-event simulator for jobs on spot markets (paper §IV–§V).

Methodology mirrors the paper exactly:

* fault-tolerance baselines receive a FIXED, seeded number of revocations
  placed uniformly over the job's compute progress ("we randomly send a
  fixed number of revocations per day of the job's execution length"),
* P-SIWOFT's revocations are TRACE-DRIVEN: the provisioned market revokes
  at the first future hour whose spot price exceeds on-demand (the same
  proxy its MTTR feature is built on) — markets chosen by Algorithm 1
  rarely hit one,
* costs accrue per hourly billing cycle at the hour's spot price, and the
  unused tail of each started cycle is charged to ``billing_buffer``,
* time/cost decompose into the paper's stacked components (execution,
  re-execution, checkpointing, recovery, startup, buffer).

Progress-based classification: ``max_progress`` tracks the furthest point
ever computed; any compute below it re-done after a revocation counts as
``re_execution``, first-time compute counts as ``execution`` (so execution
always totals the job length, and overhead is visible separately).
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import provisioner as alg
from repro.core.accounting import Breakdown, PriceTable, Session, bill_session
from repro.obs import events as obs_ev
from repro.obs.recorder import current as obs_current
from repro.core.allocation import Allocation
from repro.core.market import MarketSet, next_revocation_scalar, next_revocation_table
from repro.core.policies import (
    CheckpointPolicy,
    Job,
    MigrationPolicy,
    OnDemandPolicy,
    OverheadModel,
    ReplicationPolicy,
    SiwoftPolicy,
)

MAX_ATTEMPTS = 1000  # hard stop for pathological market sets


class Simulator:
    def __init__(
        self,
        history: MarketSet,
        future: MarketSet,
        overheads: OverheadModel = OverheadModel(),
        seed: int = 0,
        engine: str = "vectorized",
        feats: Optional[alg.MarketFeatures] = None,
    ):
        """``engine="vectorized"`` (default) routes billing through a
        :class:`PriceTable`, answers next-revocation queries from a
        precomputed suffix-scan table, and memoizes suitable sets per job
        footprint. ``engine="reference"`` keeps the original scalar code
        paths end-to-end — the oracle ``benchmarks/sim_bench.py`` asserts
        bit-exact breakdown equality against. ``feats`` optionally injects
        precomputed :class:`MarketFeatures` (so benchmark harnesses can
        share the O(markets²) correlation matrix across engines)."""
        assert engine in ("vectorized", "reference"), engine
        self.history = history
        self.future = future
        self.ov = overheads
        self.seed = seed
        self.engine = engine
        self.feats = (
            alg.MarketFeatures.from_history(history) if feats is None else feats
        )
        self._rev_matrix = future.revocation_matrix()
        self._next_rev_table: Optional[np.ndarray] = None
        # suitable-set memos: the FT baselines recompute the identical
        # candidate list on every one of up to MAX_ATTEMPTS attempts; the
        # returned lists are never mutated by callers, so sharing is safe
        self._servers_cache: dict = {}
        self._allocs_cache: dict = {}
        if engine == "vectorized":
            self._price = PriceTable(future.prices)
        else:
            prices, n_last = future.prices, future.n_hours - 1
            self._price = lambda market_id, hour: float(
                prices[market_id, min(int(hour), n_last)]
            )

    # ------------------------------------------------------------------
    # helpers
    # ------------------------------------------------------------------
    def _const_price(self, price: float):
        """Flat $/h price source (on-demand): a PriceTable on the vectorized
        engine so ``bill_session`` takes its batched path, the equivalent
        legacy closure on the reference engine."""
        if self.engine == "vectorized":
            return PriceTable.constant(price)
        return lambda m, h: price

    def _suitable_servers(self, job: Job) -> List[int]:
        if self.engine == "reference":
            return alg.find_suitable_servers(job, self.feats)
        key = (job.memory_gb, job.length_hours)
        out = self._servers_cache.get(key)
        if out is None:
            out = alg.find_suitable_servers(job, self.feats)
            self._servers_cache[key] = out
        return out

    def _suitable_allocations(self, job: Job, policy: SiwoftPolicy):
        if self.engine == "reference":
            return alg.find_suitable_allocations(job, self.feats, policy)
        # frozen-dataclass policies hash by value, so the key is stable
        key = (job.memory_gb, job.length_hours, policy)
        out = self._allocs_cache.get(key)
        if out is None:
            out = alg.find_suitable_allocations(job, self.feats, policy)
            self._allocs_cache[key] = out
        return out

    def _throughput(self, market_id: int) -> float:
        """Relative work rate of the market's shape (1-device ≡ 1.0)."""
        return max(float(self.feats.throughput[market_id]), 1e-9)

    def _od_choice(self, job: Job) -> Tuple[float, float]:
        """On-demand reference, throughput-aware: (price $/h, throughput) of
        the fitting shape with the lowest cost-to-complete — od price
        integrated over the shape's wall time, not the lowest raw $/h. On a
        single-device menu this degenerates to the cheapest fitting
        instance (the paper's reference)."""
        fit = [m for m in self.future.markets if m.total_memory_gb >= job.memory_gb]
        best = min(fit, key=lambda m: m.on_demand_price / m.throughput)
        return best.on_demand_price, best.throughput

    def _select_ft_market(
        self,
        job: Job,
        wall: float,
        exclude: Set[int],
        mode: str,
        salt: int,
        within: Optional[Set[int]] = None,
    ) -> int:
        """FT-baseline market choice: "random" (paper: no market
        intelligence) or "cheapest" (price-aware variant). ``within``
        restricts candidates to one instance-shape class (replication:
        replicas must be interchangeable)."""
        hour = min(int(wall), self.future.n_hours - 1)
        suitable = self._suitable_servers(job)
        if within is not None:
            suitable = [i for i in suitable if i in within] or suitable
        cands = [i for i in suitable if i not in exclude]
        if not cands:
            cands = suitable
        if mode == "cheapest":
            return min(cands, key=lambda i: self.future.prices[i, hour])
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed, spawn_key=(job.job_id, salt, len(exclude)))
        )
        return int(cands[rng.integers(len(cands))])

    def _next_trace_revocation(self, market_id: int, wall: float) -> Optional[float]:
        """First revocation hour ≥ wall in the future window (None if none).

        Vectorized engine: O(1) lookup in the lazily-built suffix-scan
        table. Reference engine: the scalar single-pass suffix scan (which
        also fixes the historical double scan — argmax THEN a separate
        ``.any()`` over the same suffix)."""
        h0 = int(math.ceil(wall))
        if self.engine == "reference":
            idx = next_revocation_scalar(self._rev_matrix[market_id], h0)
            return None if idx is None else float(idx)
        if self._next_rev_table is None:
            self._next_rev_table = next_revocation_table(self._rev_matrix)
        if h0 < 0:
            h0 = 0
        if h0 >= self._next_rev_table.shape[1]:
            return None
        idx = int(self._next_rev_table[market_id, h0])
        return None if idx < 0 else float(idx)

    def _next_allocation_revocation(
        self, alloc: Allocation, wall: float
    ) -> Tuple[Optional[float], Optional[int]]:
        """Earliest trace revocation across the allocation's legs: (hour,
        revoked leg's market). Any leg revocation interrupts the job —
        the min-composition the allocation MTTR prices a priori. Leg order
        breaks exact ties (deterministic)."""
        best: Tuple[Optional[float], Optional[int]] = (None, None)
        for m in alloc.markets:
            t = self._next_trace_revocation(m, wall)
            if t is not None and (best[0] is None or t < best[0]):
                best = (t, m)
        return best

    def _ft_revocation_points(self, job: Job, n: int, salt: int) -> List[float]:
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed, spawn_key=(job.job_id, salt))
        )
        return sorted(rng.uniform(0.0, job.length_hours, size=n).tolist())

    # ------------------------------------------------------------------
    # policies
    # ------------------------------------------------------------------
    def run_job(
        self,
        job: Job,
        policy,
        n_revocations: int = 0,
        start_wall: float = 0.0,
    ) -> Breakdown:
        from repro.core.portfolio import PortfolioPolicy

        # Both engines run the SAME policy code below and bill bit-identical
        # breakdowns, so with a recorder active they emit IDENTICAL event
        # logs — a cross-engine pin tests/test_obs.py holds with ==.
        rec = obs_current()
        if rec.enabled:
            rec.emit(
                obs_ev.RunStart(
                    t=start_wall,
                    subsystem="simulator",
                    label=type(policy).__name__,
                    horizon_hours=float(self.future.n_hours),
                )
            )
            rec.emit(obs_ev.price_trace(start_wall, self.future.prices))
        if isinstance(policy, PortfolioPolicy):
            bd = self._run_portfolio(job, policy, start_wall)
        elif isinstance(policy, SiwoftPolicy):
            bd = self._run_siwoft(job, policy, start_wall)
        elif isinstance(policy, CheckpointPolicy):
            bd = self._run_checkpoint(job, policy, n_revocations, start_wall)
        elif isinstance(policy, MigrationPolicy):
            bd = self._run_migration(job, policy, n_revocations, start_wall)
        elif isinstance(policy, ReplicationPolicy):
            bd = self._run_replication(job, policy, n_revocations, start_wall)
        elif isinstance(policy, OnDemandPolicy):
            bd = self._run_on_demand(job, start_wall)
        else:
            raise TypeError(policy)
        if bd.wall_time == 0.0:
            bd.wall_time = bd.total_time
        if rec.enabled:
            rec.emit(obs_ev.breakdown_pin(bd.wall_time, bd))
            rec.emit(obs_ev.RunEnd(t=bd.wall_time, wall_hours=bd.wall_time))
        return bd

    def run_jobs(self, jobs: Sequence[Job], policy, n_revocations: int = 0) -> Breakdown:
        """Alg. 1 steps 4–20: totals over the job set (step 19/21)."""
        total = Breakdown()
        for job in jobs:
            total.add(self.run_job(job, policy, n_revocations=n_revocations))
        return total

    # --- P-SIWOFT ------------------------------------------------------
    def _run_siwoft(self, job: Job, policy: SiwoftPolicy, start_wall: float) -> Breakdown:
        """Progress is tracked in WORK hours (reference-shape compute); the
        provisioned allocation converts work ↔ wall at its (combined)
        throughput θ, so a faster shape bills fewer wall hours for the same
        job. Candidates are allocations: single-leg whenever one menu shape
        fits (the paper's case, bit-identical to the pre-allocation
        simulator), multi-leg splits over DCN when none does. A revocation
        of ONE leg interrupts the whole attempt (min-MTTR semantics); the
        restriction step then excludes markets correlated with the revoked
        leg or with any surviving leg."""
        rec = obs_current()
        bd = Breakdown()
        suitable = self._suitable_allocations(job, policy)  # step 2
        if not suitable:
            raise ValueError(
                f"job {job.job_id}: {job.memory_gb} GB fits no allocation of "
                f"≤{policy.max_legs} legs — widen max_legs or the menu"
            )
        lifetimes = alg.compute_allocation_lifetimes(self.feats, suitable)  # step 3
        S = alg.server_based_lifetime(job, lifetimes, policy, self.feats)  # step 5
        wall = start_wall
        max_progress = 0.0
        last_ckpt = 0.0  # only advances in the beyond-paper hybrid mode
        revoked: Set[int] = set()

        for _ in range(MAX_ATTEMPTS):                                  # step 6
            a = alg.highest(S)                                         # step 7
            thr = max(alg.allocation_throughput(a, self.feats), 1e-9)
            # step 9's revocation-probability estimate (wall / MTTR) is
            # folded into the expected-cost-to-complete ranking that
            # ordered S — see alg.expected_cost_to_complete
            session = Session(a.legs[0].market, wall, legs=a.markets)
            session.add("startup", self.ov.startup_hours)              # provision (step 10)
            if rec.enabled:
                rec.emit(
                    obs_ev.Provision(
                        t=wall,
                        market_id=int(a.legs[0].market),
                        legs=tuple(int(m) for m in a.markets),
                    )
                )
            resume_from = last_ckpt if policy.uses_checkpoints else 0.0
            if policy.uses_checkpoints and resume_from > 0:
                session.add("recovery", self.ov.restore_hours(job.memory_gb))

            t_rev, rev_market = self._next_allocation_revocation(a, wall)  # step 11 driver
            compute_start = wall + session.used_hours
            progress = resume_from

            def run_until(target_progress: float, available_wall: float) -> Tuple[float, float]:
                """Advance ≤ available wall hours toward the target work
                progress at rate θ; returns (new progress, wall hours
                spent) split into exec/re-exec components."""
                nonlocal max_progress
                span = min(target_progress - progress, available_wall * thr)
                if span <= 0:
                    return progress, 0.0
                redo = max(0.0, min(max_progress, progress + span) - progress)
                fresh = span - redo
                if redo > 0:
                    session.add("re_execution", redo / thr)
                if fresh > 0:
                    session.add("execution", fresh / thr)
                max_progress = max(max_progress, progress + span)
                return progress + span, span / thr

            if policy.uses_checkpoints:
                # hybrid (beyond paper): periodic checkpoints while running
                horizon = math.inf if t_rev is None else t_rev - compute_start
                t_used = 0.0
                while progress < job.length_hours and t_used < horizon:
                    next_stop = min(last_ckpt + policy.ckpt_interval_hours, job.length_hours)
                    progress, spent = run_until(next_stop, horizon - t_used)
                    t_used += spent
                    if progress >= next_stop and progress < job.length_hours:
                        ck = self.ov.ckpt_hours(job.memory_gb)
                        if t_used + ck > horizon:
                            break
                        session.add("checkpointing", ck)
                        t_used += ck
                        last_ckpt = progress
                    if progress >= job.length_hours:
                        break
            else:
                horizon = math.inf if t_rev is None else t_rev - compute_start
                progress, _ = run_until(job.length_hours, horizon)

            if rec.enabled:
                rec.emit(obs_ev.session_billed(wall, session))
            wall_used = bill_session(session, self._price, bd)
            wall += wall_used
            if progress >= job.length_hours:                            # step 18
                return bd
            # revocation (steps 11–15): lose everything since last_ckpt.
            # Only ONE leg's market revoked; the whole attempt is
            # interrupted, but surviving legs stay eligible for repairs.
            bd.revocations += 1
            if rec.enabled:
                rec.emit(obs_ev.Revoke(t=wall, market_id=int(rev_market)))
            revoked.add(rev_market)
            surviving_legs = tuple(m for m in a.markets if m != rev_market)
            W = alg.find_low_correlation(
                self.feats, rev_market, policy, surviving=surviving_legs
            )                                                          # step 13
            # re-rank for the REMAINING work: the cost-to-complete tie-break
            # integrates price/throughput over what is left — for hybrid,
            # everything past the newest checkpoint (last_ckpt may have
            # advanced during this attempt); for pure siwoft, the whole job
            surviving = last_ckpt if policy.uses_checkpoints else 0.0
            rem = alg.remaining_job(job, job.length_hours - surviving)
            S = alg.restrict_after_revocation(
                S, a, W, lifetimes, revoked, self.feats, job=rem,
                surviving=surviving_legs,
            )                                                          # step 14
            wall = max(wall, 0.0 if t_rev is None else t_rev)
        raise RuntimeError("siwoft: exceeded MAX_ATTEMPTS")

    # --- beyond-paper: portfolio failover chain ---------------------------
    def _run_portfolio(self, job: Job, policy, start_wall: float) -> Breakdown:
        """Same no-FT execution as P-SIWOFT; provisioning order is the
        proactively diversified portfolio chain (core/portfolio.py)."""
        from repro.core.portfolio import portfolio_failover_order

        rec = obs_current()
        bd = Breakdown()
        order = portfolio_failover_order(job, self.feats, policy)
        wall = start_wall
        max_progress = 0.0
        for s_m in order:
            thr = self._throughput(s_m)
            session = Session(s_m, wall)
            session.add("startup", self.ov.startup_hours)
            if rec.enabled:
                rec.emit(
                    obs_ev.Provision(t=wall, market_id=int(s_m), legs=(int(s_m),))
                )
            t_rev = self._next_trace_revocation(s_m, wall)
            compute_start = wall + session.used_hours
            horizon = math.inf if t_rev is None else t_rev - compute_start
            # work done before the revocation horizon, at the shape's rate
            span = min(job.length_hours, max(horizon, 0.0) * thr)
            redo = min(max_progress, span)
            if redo > 0:
                session.add("re_execution", redo / thr)
            if span - redo > 0:
                session.add("execution", (span - redo) / thr)
            max_progress = max(max_progress, span)
            if rec.enabled:
                rec.emit(obs_ev.session_billed(wall, session))
            wall += bill_session(session, self._price, bd)
            if span >= job.length_hours:
                return bd
            bd.revocations += 1
            if rec.enabled:
                rec.emit(obs_ev.Revoke(t=wall, market_id=int(s_m)))
            wall = max(wall, 0.0 if t_rev is None else t_rev)
        raise RuntimeError("portfolio: exhausted every market")

    # --- FT baseline: checkpointing -------------------------------------
    def _run_checkpoint(
        self, job: Job, policy: CheckpointPolicy, n_rev: int, start_wall: float
    ) -> Breakdown:
        rec = obs_current()
        bd = Breakdown()
        rev_points = self._ft_revocation_points(job, n_rev, salt=1)
        wall = start_wall
        progress = 0.0
        max_progress = 0.0
        last_ckpt = 0.0
        revoked: Set[int] = set()
        rev_iter = iter(rev_points + [math.inf])
        next_rev = next(rev_iter)
        first = True

        for _ in range(MAX_ATTEMPTS):
            m = self._select_ft_market(job, wall, revoked, policy.market_selection, salt=11)
            thr = self._throughput(m)
            session = Session(m, wall)
            session.add("startup", self.ov.startup_hours)
            if rec.enabled:
                rec.emit(obs_ev.Provision(t=wall, market_id=int(m), legs=(int(m),)))
            if not first:
                session.add("recovery", self.ov.restore_hours(job.memory_gb))
            first = False

            # run until either completion or the next injected revocation
            # (progress / revocation points are WORK coordinates; the
            # session bills wall hours at the provisioned shape's rate)
            while progress < job.length_hours and progress < next_rev:
                stop = min(
                    last_ckpt + policy.ckpt_interval_hours,
                    job.length_hours,
                    next_rev,
                )
                span = stop - progress
                redo = max(0.0, min(max_progress, stop) - progress)
                fresh = span - redo
                if redo > 0:
                    session.add("re_execution", redo / thr)
                if fresh > 0:
                    session.add("execution", fresh / thr)
                max_progress = max(max_progress, stop)
                progress = stop
                if (
                    progress >= last_ckpt + policy.ckpt_interval_hours
                    and progress < job.length_hours
                    and progress < next_rev
                ):
                    session.add("checkpointing", self.ov.ckpt_hours(job.memory_gb))
                    last_ckpt = progress

            if rec.enabled:
                rec.emit(obs_ev.session_billed(wall, session))
            wall += bill_session(session, self._price, bd)
            if progress >= job.length_hours:
                return bd
            # revocation: roll back to the last checkpoint
            bd.revocations += 1
            if rec.enabled:
                rec.emit(obs_ev.Revoke(t=wall, market_id=int(m)))
            revoked.add(m)
            progress = last_ckpt
            next_rev = next(rev_iter)
        raise RuntimeError("checkpoint: exceeded MAX_ATTEMPTS")

    # --- FT baseline: migration ----------------------------------------
    def _run_migration(
        self, job: Job, policy: MigrationPolicy, n_rev: int, start_wall: float
    ) -> Breakdown:
        rec = obs_current()
        bd = Breakdown()
        rev_points = self._ft_revocation_points(job, n_rev, salt=2)
        wall = start_wall
        progress = 0.0
        max_progress = 0.0
        revoked: Set[int] = set()
        rev_iter = iter(rev_points + [math.inf])
        next_rev = next(rev_iter)
        mig_ok = (
            job.memory_gb <= self.ov.live_migration_max_gb
            and self.ov.migration_hours(job.memory_gb) <= self.ov.revocation_notice_hours
        )

        for _ in range(MAX_ATTEMPTS):
            m = self._select_ft_market(job, wall, revoked, policy.market_selection, salt=12)
            thr = self._throughput(m)
            session = Session(m, wall)
            session.add("startup", self.ov.startup_hours)
            if rec.enabled:
                rec.emit(obs_ev.Provision(t=wall, market_id=int(m), legs=(int(m),)))
            span = min(job.length_hours, next_rev) - progress
            redo = max(0.0, min(max_progress, progress + span) - progress)
            if redo > 0:
                session.add("re_execution", redo / thr)
            if span - redo > 0:
                session.add("execution", (span - redo) / thr)
            max_progress = max(max_progress, progress + span)
            progress += span
            if progress >= job.length_hours:
                if rec.enabled:
                    rec.emit(obs_ev.session_billed(wall, session))
                wall += bill_session(session, self._price, bd)
                return bd
            # revocation with 2-minute notice
            bd.revocations += 1
            if rec.enabled:
                rec.emit(obs_ev.Revoke(t=wall, market_id=int(m)))
            revoked.add(m)
            if mig_ok:
                session.add("recovery", self.ov.migration_hours(job.memory_gb))
                # state moves: no lost work
            else:
                progress = 0.0  # unplanned kill: no FT state to resume from
            if rec.enabled:
                rec.emit(obs_ev.session_billed(wall, session))
            wall += bill_session(session, self._price, bd)
            next_rev = next(rev_iter)
        raise RuntimeError("migration: exceeded MAX_ATTEMPTS")

    # --- FT baseline: replication ---------------------------------------
    def _run_replication(
        self, job: Job, policy: ReplicationPolicy, n_rev: int, start_wall: float
    ) -> Breakdown:
        """Degree-k task duplication: k replicas run the whole job; the n_rev
        injected revocations each kill one replica (round-robin), which
        restarts FROM SCRATCH on a fresh market (no state is carried — that
        is the point of replication). The job completes when the first
        replica finishes; every other replica-hour is ``re_execution``
        overhead, which is how replication pays for its fault tolerance.

        Replicas must be interchangeable (any survivor IS the job), so all
        of them are placed within the tightest-fitting instance-shape
        class at that class's fastest throughput — the heterogeneous menu
        is a siwoft/portfolio degree of freedom, not a replication one."""
        rec = obs_current()
        bd = Breakdown()
        totals = self.feats.total_memory_gb
        best_total = totals[totals >= job.memory_gb].min()
        cls = [i for i in range(len(totals)) if totals[i] == best_total]
        # same-total markets can still be different mesh shapes (e.g. 1×32 GB
        # vs 2×16 GB): pin replicas to the fastest shape in the class so
        # every replica runs at one rate and any survivor IS the job
        thr = max(self._throughput(i) for i in cls)
        shape_class = {i for i in cls if self._throughput(i) == thr}
        wall_len = job.wall_hours_on(thr)
        k = policy.degree
        # kill times: wall offsets, uniform over the replica's wall length
        kills = [t / thr for t in self._ft_revocation_points(job, n_rev, salt=3)]
        # replica r is killed at kills[i] for i ≡ r (mod k)
        last_kill = [0.0] * k
        kill_lists: List[List[float]] = [[] for _ in range(k)]
        for i, t in enumerate(kills):
            kill_lists[i % k].append(t)
            last_kill[i % k] = max(last_kill[i % k], t)
        finish = [lk + wall_len for lk in last_kill]
        winner = int(np.argmin(finish))
        t_star = finish[winner]

        excl: Set[int] = set()
        for r in range(k):
            # sessions: [start, kill_1), [kill_1, kill_2), ..., [last, t*)
            boundaries = [0.0] + kill_lists[r] + [t_star]
            for s_i in range(len(boundaries) - 1):
                t0, t1 = boundaries[s_i], boundaries[s_i + 1]
                if t1 <= t0:
                    continue
                m = self._select_ft_market(
                    job, start_wall + t0, excl, policy.market_selection,
                    salt=13, within=shape_class,
                )
                excl.add(m)
                session = Session(m, start_wall + t0)
                session.add("startup", self.ov.startup_hours)
                if rec.enabled:
                    rec.emit(
                        obs_ev.Provision(
                            t=start_wall + t0,
                            market_id=int(m),
                            legs=(int(m),),
                            replica_id=r,
                        )
                    )
                run = min(t1 - t0, wall_len)
                is_winning_run = r == winner and s_i == len(boundaries) - 2
                session.add("execution" if is_winning_run else "re_execution", run)
                if s_i < len(boundaries) - 2:
                    bd.revocations += 1
                    if rec.enabled:
                        rec.emit(
                            obs_ev.Revoke(
                                t=start_wall + t1, market_id=int(m), replica_id=r
                            )
                        )
                if rec.enabled:
                    rec.emit(obs_ev.session_billed(start_wall + t0, session))
                bill_session(session, self._price, bd)
        bd.wall_time = t_star + self.ov.startup_hours
        return bd

    # --- on-demand reference ---------------------------------------------
    def _run_on_demand(self, job: Job, start_wall: float) -> Breakdown:
        rec = obs_current()
        bd = Breakdown()
        price, thr = self._od_choice(job)
        session = Session(-1, start_wall)
        session.add("startup", self.ov.startup_hours)
        session.add("execution", job.wall_hours_on(thr))
        if rec.enabled:
            rec.emit(obs_ev.Provision(t=start_wall, market_id=-1, legs=(-1,)))
            # the constant on-demand price replays via PriceTable.constant —
            # identical on both engines, whatever _const_price returned
            rec.emit(obs_ev.session_billed(start_wall, session, price_const=float(price)))
        bill_session(session, self._const_price(price), bd)
        return bd
