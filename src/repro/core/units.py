"""Named unit-conversion constants — the single home for conversion factors.

Every module that converts between the repo's canonical units imports the
factor from here instead of writing a bare ``3600`` / ``1e9`` / ``2**30``
literal. The ``repro-lint`` units pass (``tools/analysis/units.py``, rule
U002) enforces this: a bare conversion literal in arithmetic under
``src/repro/{core,serve,dist}`` or ``benchmarks/`` is a lint error,
because a mixed-up factor silently invalidates every BENCH_*.json number.

Canonical units, for reference (see docs/accounting.md):

* wall time     — **hours** (``*_hours``); the router works in seconds
  internally (``*_seconds``) and converts at the Breakdown boundary.
* money         — **USD** (``*_usd``); spot prices are ``$/h``.
* state volume  — **bytes** (``*_bytes``); menus quote memory in decimal
  ``*_gb`` and wire bandwidth in ``*_gbps`` (decimal GB/s).
* demand        — **tokens** and ``tokens_per_sec``.

Each constant is exactly the literal it replaces, so swapping them in is
bit-exact — no BENCH column moves.
"""
from __future__ import annotations

# wall time
SECONDS_PER_HOUR = 3600.0
MINUTES_PER_HOUR = 60.0
# int, not float: day counts scale array extents (np.empty((n, n_hours)))
HOURS_PER_DAY = 24

# state volume: decimal GB for bandwidth math (``*_gbps`` quotes GB/s),
# binary GiB for memory-footprint reporting (matches the 16 GiB HBM spec)
BYTES_PER_GB = 1e9
BYTES_PER_GIB = 2**30

# timer / token-volume reporting scales
MICROSECONDS_PER_SECOND = 1e6
TOKENS_PER_MEGATOKEN = 1e6
