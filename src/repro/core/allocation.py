"""Multi-leg allocations: one job spread across several spot markets.

The paper's Algorithm 1 assumes one job ↔ one spot market, so a job whose
footprint exceeds every shape in the menu simply cannot be provisioned
without fault tolerance. Composing capacity from several markets at once
(Voorsluys, Garg & Buyya — *Provisioning Spot Market Cloud Resources to
Create Cost-effective Virtual Clusters*) removes that cliff: an
:class:`Allocation` is an ordered set of ``(market, device_count)``
**legs** plus the DCN bandwidth that couples them. A single-leg allocation
IS the paper's one-market provisioning — every downstream layer
(provisioner, simulator, orchestrator, accounting) must treat it
identically to the bare market index it replaces.

Physics of a split (all model-level; the provisioner prices it):

* **throughput** — the union device count scales sublinearly exactly like
  a single mesh (``repro.core.market.shape_throughput``), but the scaling
  exponent is set by the *effective* cross-leg bandwidth: the DCN egress,
  further capped by the slowest leg's interconnect (a collective cannot
  drain a leg faster than that leg's own fabric). A split is therefore
  never faster than the same devices behind one interconnect.
* **survival** — any leg revocation interrupts the job, so an
  allocation's MTTR composes as the **min** over its legs' MTTRs. Wider
  splits face a strictly harder admission test; that is the honest model,
  not a penalty knob.
* **price** — legs bill independently ($/h of each leg's market), so the
  allocation's hourly price is the sum over legs and the accounting layer
  carries a per-leg cost breakdown that must sum to the total.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence, Tuple

from repro.core.market import shape_throughput

# Cross-market egress (GB/s) over the data-center network. Device
# interconnects in the menu run 10–60 GB/s; crossing markets means leaving
# the instance fabric, so a split mesh's collectives drain at DCN speed —
# the discount that keeps a split from ever beating the same devices on
# one interconnect.
DCN_BANDWIDTH_GBPS = 2.5


@dataclasses.dataclass(frozen=True)
class Leg:
    """One leg of an allocation: ``device_count`` devices in ``market``."""

    market: int
    device_count: int = 1


@dataclasses.dataclass(frozen=True)
class Allocation:
    """An ordered set of legs provisioned together for one job.

    Hashable and order-preserving: the leg order is the mesh-construction
    order (`dist.meshplan.plan_for_allocation` assigns device spans in leg
    order), and two allocations with the same legs in the same order are
    the same allocation.
    """

    legs: Tuple[Leg, ...]
    dcn_gbps: float = DCN_BANDWIDTH_GBPS

    def __post_init__(self):
        assert self.legs, "an allocation has at least one leg"
        assert len({l.market for l in self.legs}) == len(self.legs), (
            "one spot request per market: legs must name distinct markets"
        )

    @classmethod
    def single(cls, market: int, device_count: int = 1,
               dcn_gbps: float = DCN_BANDWIDTH_GBPS) -> "Allocation":
        """The degenerate one-market allocation — the paper's setting."""
        return cls(legs=(Leg(int(market), int(device_count)),), dcn_gbps=dcn_gbps)

    @classmethod
    def of(cls, markets: Iterable[int], device_counts: Iterable[int],
           dcn_gbps: float = DCN_BANDWIDTH_GBPS) -> "Allocation":
        return cls(
            legs=tuple(Leg(int(m), int(d)) for m, d in zip(markets, device_counts)),
            dcn_gbps=dcn_gbps,
        )

    def __len__(self) -> int:
        return len(self.legs)

    @property
    def markets(self) -> Tuple[int, ...]:
        return tuple(l.market for l in self.legs)

    @property
    def device_counts(self) -> Tuple[int, ...]:
        return tuple(l.device_count for l in self.legs)

    @property
    def total_devices(self) -> int:
        return sum(l.device_count for l in self.legs)

    @property
    def is_split(self) -> bool:
        return len(self.legs) > 1

    def touches(self, market: int) -> bool:
        return any(l.market == market for l in self.legs)

    def surviving(self, revoked_market: int) -> Tuple[Leg, ...]:
        """The legs that outlive a revocation of ``revoked_market``."""
        return tuple(l for l in self.legs if l.market != revoked_market)

    def replace_leg(self, revoked_market: int, new_leg: Leg) -> "Allocation":
        """The repaired allocation: the revoked leg swapped in place for
        ``new_leg`` — the partial-reshard re-provisioning primitive."""
        assert self.touches(revoked_market)
        return Allocation(
            legs=tuple(
                new_leg if l.market == revoked_market else l for l in self.legs
            ),
            dcn_gbps=self.dcn_gbps,
        )


def combined_throughput(
    device_counts: Sequence[int],
    interconnects_gbps: Sequence[float],
    dcn_gbps: float = DCN_BANDWIDTH_GBPS,
) -> float:
    """Relative steps/hour of a multi-leg mesh over DCN.

    The union device count scales by the same sublinear law as a single
    mesh, but at the effective bandwidth ``min(dcn, slowest leg egress)``:
    the cross-leg collective both crosses the DCN and drains through each
    leg's own fabric, so the slowest of those pipes sets the exponent.
    Properties (pinned by tests/test_allocation.py):

    * one leg → exactly ``shape_throughput(n, interconnect)`` (no DCN in
      the path — the single-market physics, bit-identical),
    * never better than the same devices behind any single leg's
      interconnect (α is non-decreasing in bandwidth and the effective
      bandwidth is a min),
    * still strictly more work/hour than the bigger leg alone whenever the
      DCN is not absurdly slow — which is what makes a split worth pricing.
    """
    counts = [int(c) for c in device_counts]
    assert counts and all(c >= 1 for c in counts)
    if len(counts) == 1:
        return shape_throughput(counts[0], float(interconnects_gbps[0]))
    eff_bw = min(float(dcn_gbps), min(float(b) for b in interconnects_gbps))
    return shape_throughput(sum(counts), eff_bw)
