"""Beyond-paper: portfolio-driven provisioning (inspired by the paper's own
related work, Sharma et al., "Portfolio-driven resource management for
transient cloud servers" — reference [6] of the paper).

P-SIWOFT picks markets greedily by MTTR and only consults the correlation
feature reactively (AFTER a revocation). The portfolio policy instead
selects the whole failover chain UP FRONT by a mean-variance-style greedy
objective that trades expected lifetime against price and against
co-revocation with markets already in the portfolio:

    pick  argmax_m ( div(m|P),  log(MTTR_m) · div(m|P) / price_m^γ )   (lexicographic)
    div(m|P) = 1 − max_{p∈P} corr(m, p)

Diversity is the primary key because the heterogeneous instance menu
spans a ~4× absolute-price band: a scalar price-weighted score would let
a cheap-but-correlated shape outrank an uncorrelated one.

Execution semantics are identical to Algorithm 1 (no FT mechanism; restart
from scratch on revocation) — only the provisioning ORDER differs, so the
comparison isolates the value of proactive diversification. In calm markets
(rare-revocation markets exist) the two coincide on the first pick; the
portfolio wins in volatile regimes where consecutive failovers matter.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence


from repro.core import provisioner as alg
from repro.core.policies import Job, SiwoftPolicy
from repro.core.provisioner import MarketFeatures


@dataclasses.dataclass(frozen=True)
class PortfolioPolicy(SiwoftPolicy):
    name: str = "portfolio"
    size: int = 4                 # failover-chain length selected up front
    price_gamma: float = 0.5      # price sensitivity in the greedy score
    lifetime_factor: float = 2.0


def select_portfolio(
    job: Job, feats: MarketFeatures, policy: PortfolioPolicy
) -> List[int]:
    """Greedy diversified failover chain over the suitable markets."""
    suitable = alg.find_suitable_servers(job, feats)
    lifetimes = alg.compute_lifetime(feats, suitable)
    admitted = [
        i for i in suitable
        if lifetimes[i] >= policy.lifetime_factor * job.length_hours
    ] or list(suitable)

    chain: List[int] = []
    rest = set(admitted)
    while rest and len(chain) < policy.size:
        def div(m: int) -> float:
            if not chain:
                return 1.0
            return 1.0 - max(float(feats.corr[m, p]) for p in chain)

        def score(m: int) -> float:
            # price per unit of WORK (the shape-throughput-normalized $/h):
            # a pricey fast mesh can outscore a cheap slow one
            price = max(
                float(feats.avg_price[m]) / max(float(feats.throughput[m]), 1e-9),
                1e-9,
            )
            return math.log(max(lifetimes[m], 1.001)) * max(div(m), 0.0) / price**policy.price_gamma

        # diversity first, lexicographically: the heterogeneous menu spans a
        # ~4x absolute-price band, so a price-weighted scalar score would let
        # a cheap-but-correlated shape outrank an uncorrelated one; price and
        # lifetime only arbitrate among equally-diversified candidates.
        best = max(sorted(rest), key=lambda m: (div(m), score(m)))
        chain.append(best)
        rest.discard(best)
    return chain


def portfolio_failover_order(
    job: Job, feats: MarketFeatures, policy: PortfolioPolicy
) -> List[int]:
    """The full provisioning order: the portfolio chain, then any remaining
    suitable markets MTTR-descending (the chain should rarely be exhausted)."""
    chain = select_portfolio(job, feats, policy)
    suitable = alg.find_suitable_servers(job, feats)
    lifetimes = alg.compute_lifetime(feats, suitable)
    tail = sorted(
        (i for i in suitable if i not in chain),
        key=lambda i: (
            -lifetimes[i],
            alg.expected_cost_to_complete(job.length_hours, feats, i),
            i,
        ),
    )
    return chain + tail


def max_chain_correlation(feats: MarketFeatures, chain: Sequence[int]) -> float:
    """Diagnostic: worst pairwise co-revocation within a chain prefix."""
    worst = 0.0
    for a in range(len(chain)):
        for b in range(a + 1, len(chain)):
            worst = max(worst, float(feats.corr[chain[a], chain[b]]))
    return worst
