"""Beyond-paper: portfolio-driven provisioning (inspired by the paper's own
related work, Sharma et al., "Portfolio-driven resource management for
transient cloud servers" — reference [6] of the paper).

P-SIWOFT picks markets greedily by MTTR and only consults the correlation
feature reactively (AFTER a revocation). The portfolio policy instead
selects the whole failover chain UP FRONT by a mean-variance-style greedy
objective that trades expected lifetime against price and against
co-revocation with markets already in the portfolio:

    score(m | P) = log(MTTR_m) · (1 − max_{p∈P} corr(m, p)) / price_m^γ

Execution semantics are identical to Algorithm 1 (no FT mechanism; restart
from scratch on revocation) — only the provisioning ORDER differs, so the
comparison isolates the value of proactive diversification. In calm markets
(rare-revocation markets exist) the two coincide on the first pick; the
portfolio wins in volatile regimes where consecutive failovers matter.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

import numpy as np

from repro.core import provisioner as alg
from repro.core.policies import Job, SiwoftPolicy
from repro.core.provisioner import MarketFeatures


@dataclasses.dataclass(frozen=True)
class PortfolioPolicy(SiwoftPolicy):
    name: str = "portfolio"
    size: int = 4                 # failover-chain length selected up front
    price_gamma: float = 0.5      # price sensitivity in the greedy score
    lifetime_factor: float = 2.0


def select_portfolio(
    job: Job, feats: MarketFeatures, policy: PortfolioPolicy
) -> List[int]:
    """Greedy diversified failover chain over the suitable markets."""
    suitable = alg.find_suitable_servers(job, feats)
    lifetimes = alg.compute_lifetime(feats, suitable)
    admitted = [
        i for i in suitable
        if lifetimes[i] >= policy.lifetime_factor * job.length_hours
    ] or list(suitable)

    chain: List[int] = []
    rest = set(admitted)
    while rest and len(chain) < policy.size:
        def score(m: int) -> float:
            div = 1.0
            if chain:
                div = 1.0 - max(float(feats.corr[m, p]) for p in chain)
            price = max(float(feats.avg_price[m]), 1e-9)
            return math.log(max(lifetimes[m], 1.001)) * max(div, 0.0) / price**policy.price_gamma

        best = max(sorted(rest), key=score)
        chain.append(best)
        rest.discard(best)
    return chain


def portfolio_failover_order(
    job: Job, feats: MarketFeatures, policy: PortfolioPolicy
) -> List[int]:
    """The full provisioning order: the portfolio chain, then any remaining
    suitable markets MTTR-descending (the chain should rarely be exhausted)."""
    chain = select_portfolio(job, feats, policy)
    suitable = alg.find_suitable_servers(job, feats)
    lifetimes = alg.compute_lifetime(feats, suitable)
    tail = sorted(
        (i for i in suitable if i not in chain),
        key=lambda i: (-lifetimes[i], float(feats.avg_price[i]), i),
    )
    return chain + tail


def max_chain_correlation(feats: MarketFeatures, chain: Sequence[int]) -> float:
    """Diagnostic: worst pairwise co-revocation within a chain prefix."""
    worst = 0.0
    for a in range(len(chain)):
        for b in range(a + 1, len(chain)):
            worst = max(worst, float(feats.corr[chain[a], chain[b]]))
    return worst
