"""Cloud spot markets: instance types, price traces, and the three market
features P-SIWOFT consumes (§III-A of the paper):

1. **lifetime / MTTR** — mean time until the spot price rises above the
   corresponding on-demand price (the paper's revocation proxy: customers
   won't bid above on-demand),
2. **revocation probability** of a provisioned instance
   = job_length / MTTR,
3. **revocation correlation** between markets — how often two markets
   revoked in the *same hourly billing cycle* over the past three months.

The paper collects real EC2 REST price traces; offline we generate
synthetic traces calibrated to the stylized facts the paper and its
citations report (spot ≈ 10–40 % of on-demand; *rare-revocation markets
exist* with MTTR > 600 h [Sharma et al., HotCloud'16]; revocations are
correlated within an availability zone and nearly independent across
zones/regions [Sharma et al. 2017]). A CSV loader accepts real traces.
Everything is seeded and deterministic.
"""
from __future__ import annotations

import csv
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

try:  # optional: 1.6× faster AR(1) (bit-exact; see _ar1_noise)
    from scipy.signal import lfilter as _lfilter
except ImportError:  # pragma: no cover - scipy ships in the repro image
    _lfilter = None

HOURS_3_MONTHS = 24 * 90  # one billing cycle per hour, 3-month feature window

# ---------------------------------------------------------------------------
# Per-shape throughput model
# ---------------------------------------------------------------------------
# A shape's delivered training speed, in units where the 1-device reference
# shape ≡ 1.0 work-hour per wall-hour. Scaling across devices is sublinear
# (collectives, stragglers): ``n`` devices deliver ``n^α`` speedup with
# α < 1, and the interconnect sets WHERE α lands between the floor and the
# ceiling — a faster fabric loses less of each step to collectives, so it
# scales closer to linear, but never reaches it. Because the bandwidth
# enters through the exponent, a 1-device shape (n^α = 1 for any α) is
# interconnect-invariant and exactly 1.0 — which is what keeps legacy
# single-device traces bit-identical to the pre-throughput simulator —
# and doubling devices multiplies throughput by 2^α < 2 at EVERY
# bandwidth, so the model cannot be gamed into superlinear scaling.
THROUGHPUT_EFFICIENCY_FLOOR = 0.6     # scaling exponent as bandwidth -> 0
THROUGHPUT_EFFICIENCY_CEIL = 0.95     # < 1: sublinear even on infinite fabric
REFERENCE_INTERCONNECT_GBPS = 10.0    # bandwidth at the floor/ceil midpoint


def shape_throughput(
    device_count: int,
    interconnect_gbps: float = REFERENCE_INTERCONNECT_GBPS,
    *,
    efficiency_floor: float = THROUGHPUT_EFFICIENCY_FLOOR,
    efficiency_ceil: float = THROUGHPUT_EFFICIENCY_CEIL,
) -> float:
    """Relative steps/hour of a mesh shape vs the 1-device reference.

    ``throughput(1, anything) == 1.0`` exactly; strictly increasing and
    sublinear in ``device_count`` (2× devices < 2× speed at any
    bandwidth); non-decreasing in ``interconnect_gbps`` for n > 1.
    The scaling exponent saturates from the floor toward the ceiling as
    ``bw / (bw + 10 GB/s)``: α(10) ≈ 0.78, α(25) = 0.85, α(60) = 0.9.
    """
    n = max(int(device_count), 1)
    if n == 1:
        return 1.0
    bw = max(float(interconnect_gbps), 0.0)
    alpha = efficiency_ceil - (efficiency_ceil - efficiency_floor) * (
        REFERENCE_INTERCONNECT_GBPS / (REFERENCE_INTERCONNECT_GBPS + bw)
    )
    return float(n) ** alpha


def resolved_throughput(
    steps_per_hour: Optional[float], device_count: int, interconnect_gbps: float
) -> float:
    """A shape's relative steps/hour: the measured ``steps_per_hour``
    override when present, else the analytic model — the single resolution
    rule shared by :class:`InstanceShape` and :class:`Market`."""
    if steps_per_hour is not None:
        return float(steps_per_hour)
    return shape_throughput(device_count, interconnect_gbps)


@dataclasses.dataclass(frozen=True)
class InstanceShape:
    """One instance-menu entry: a *mesh shape*, not just a price point.

    ``memory_gb`` is per accelerator device; a job fits when its sharded
    state fits ``memory_gb × device_count``. ``interconnect_gbps`` is the
    device-to-device bandwidth (GB/s) a live reshard moves bytes over —
    the denominator of the ``reshard`` time/cost component.
    ``steps_per_hour``, when set, overrides the analytic throughput model
    with a measured rate (relative to the 1-device reference shape).
    """

    instance_type: str
    memory_gb: int               # GiB per device
    on_demand_price: float       # $/h for the whole instance
    device_count: int = 1        # accelerators per instance
    interconnect_gbps: float = 10.0  # GB/s device interconnect
    steps_per_hour: Optional[float] = None  # measured relative throughput

    @property
    def total_memory_gb(self) -> float:
        return float(self.memory_gb * self.device_count)

    @property
    def throughput(self) -> float:
        """Relative steps/hour: measured override, else the analytic model."""
        return resolved_throughput(
            self.steps_per_hour, self.device_count, self.interconnect_gbps
        )


# EC2-ish accelerator menu. Deviation from the paper (which models CPU
# instances as memory sizes only): each entry is a mesh shape — device
# count and interconnect bandwidth — so heterogeneous-type provisioning
# (Voorsluys & Buyya; Qu et al.) has a real degree of freedom. Several
# entries share a total-memory class at different device counts so the
# suitable set spans *different mesh shapes* for the same job. Pricing is
# deliberately heterogeneous in $/throughput, the quantity the related
# heterogeneous-spot work shows varies wildly across types: the small
# accelerator box (g5.2xlarge) carries a per-device premium, while the big
# boxes get volume-style pricing that undercuts the 1-device reference per
# unit of WORK despite a much higher sticker $/h — price vs speed is a
# real trade, not a monotone ladder.
INSTANCE_MENU: Tuple[InstanceShape, ...] = (
    InstanceShape("m5.xlarge", 16, 0.192, device_count=1, interconnect_gbps=10.0),
    InstanceShape("m5.2xlarge", 32, 0.384, device_count=1, interconnect_gbps=10.0),
    InstanceShape("g5.2xlarge", 16, 0.402, device_count=2, interconnect_gbps=25.0),
    InstanceShape("g5.12xlarge", 16, 0.550, device_count=4, interconnect_gbps=25.0),
    InstanceShape("p3.16xlarge", 16, 1.100, device_count=8, interconnect_gbps=50.0),
    InstanceShape("p4d.24xlarge", 40, 1.200, device_count=8, interconnect_gbps=60.0),
)


def legacy_menu(menu: Sequence[InstanceShape] = INSTANCE_MENU) -> Tuple[InstanceShape, ...]:
    """The paper's memory-size-only menu: every shape collapsed to a single
    device holding its total memory. All throughputs are exactly 1.0, so
    provisioning trades price against MTTR only — the pre-throughput
    physics. Paper-exact reproductions (``benchmarks/fig1.py``, the C1–C3
    simulator tests) run on this; the heterogeneous default menu is the
    beyond-paper setting where price also trades against speed."""
    return tuple(
        dataclasses.replace(
            s,
            memory_gb=int(s.total_memory_gb),
            device_count=1,
            interconnect_gbps=REFERENCE_INTERCONNECT_GBPS,
            steps_per_hour=None,
        )
        for s in menu
    )

# 6 regions × 4 AZs = 24 markets per instance type. EC2 reality is ~75+;
# what matters for the paper's premise is that P(no rare-revocation market
# exists for a type) is negligible (0.75^24 ≈ 0.1 % here vs 3 % at 12).
REGIONS = (
    "us-east-1", "us-west-2", "eu-west-1",
    "ap-southeast-1", "ap-northeast-1", "eu-central-1",
)
ZONES_PER_REGION = 4


@dataclasses.dataclass(frozen=True)
class Market:
    """One (instance type × availability zone) spot market.

    Carries the menu entry's topology (``device_count``,
    ``interconnect_gbps``) so the provisioner can treat the market as a
    mesh shape and price a live reshard onto it.
    """

    market_id: int
    instance_type: str
    region: str
    zone: str
    memory_gb: int                   # GiB per device
    on_demand_price: float
    device_count: int = 1
    interconnect_gbps: float = 10.0
    steps_per_hour: Optional[float] = None  # measured relative throughput

    @property
    def total_memory_gb(self) -> float:
        return float(self.memory_gb * self.device_count)

    @property
    def throughput(self) -> float:
        """Relative steps/hour: measured override, else the analytic model."""
        return resolved_throughput(
            self.steps_per_hour, self.device_count, self.interconnect_gbps
        )


@dataclasses.dataclass
class MarketSet:
    """Markets + their hourly price traces (rows: market, cols: hour)."""

    markets: List[Market]
    prices: np.ndarray          # (n_markets, n_hours) $/h spot price
    start_hour: int = 0

    @property
    def n_hours(self) -> int:
        return self.prices.shape[1]

    def revocation_matrix(self) -> np.ndarray:
        """bool (n_markets, n_hours): hour h is a revocation hour for market m
        iff spot price > on-demand price (the paper's proxy)."""
        od = np.array([m.on_demand_price for m in self.markets])[:, None]
        return self.prices > od

    # ---- feature 1: lifetime / MTTR ------------------------------------
    def mttr_hours(self) -> np.ndarray:
        """Mean time between revocation events per market, in hours.

        Markets with zero revocations in the window get MTTR = n_hours × 2
        (">600 h" rare-revocation markets for a 3-month window)."""
        rev = self.revocation_matrix()
        counts = rev.sum(axis=1)
        with np.errstate(divide="ignore"):
            mttr = self.n_hours / np.maximum(counts, 1)
        mttr[counts == 0] = 2.0 * self.n_hours
        return mttr

    # ---- feature 3: revocation correlation -----------------------------
    def correlation_matrix(self) -> np.ndarray:
        """Jaccard co-revocation: |hours both revoked| / |hours either|.

        0 for pairs that never co-revoke (including never-revoking markets);
        1 on the diagonal for markets that ever revoke."""
        rev = self.revocation_matrix().astype(np.float64)
        inter = rev @ rev.T
        counts = rev.sum(axis=1)
        union = counts[:, None] + counts[None, :] - inter
        with np.errstate(divide="ignore", invalid="ignore"):
            corr = np.where(union > 0, inter / np.maximum(union, 1e-12), 0.0)
        return corr

    def spot_price(self, market_id: int, hour: int) -> float:
        h = min(int(hour), self.n_hours - 1)
        return float(self.prices[market_id, h])


def revocation_probability(job_length_hours: float, mttr_hours: float) -> float:
    """Paper §III-A / Alg.1 step 9: estimated revocation probability of a
    provisioned instance = job length / MTTR (clipped to [0, 1])."""
    if mttr_hours <= 0:
        return 1.0
    return float(min(1.0, job_length_hours / mttr_hours))


# ---------------------------------------------------------------------------
# Next-revocation index tables
# ---------------------------------------------------------------------------

def next_revocation_table(rev: np.ndarray) -> np.ndarray:
    """``table[m, h]`` = first hour ≥ h at which market m revokes, or -1.

    One vectorized suffix min-scan over the whole revocation matrix
    replaces the per-query ``np.argmax`` suffix slicing the simulators
    used to do on every provisioning decision: after this O(markets ×
    hours) build, each "when is this leg revoked next?" query is an O(1)
    table read. Semantics are pinned against the scalar reference
    (:func:`next_revocation_scalar`) by a hypothesis property test.
    """
    rev = np.asarray(rev, dtype=bool)
    _, n_hours = rev.shape
    # int32 indices (a year is 8760 hours) + in-place suffix scan: the
    # build is memory-bound, so halving the element size and skipping the
    # two intermediate allocations cuts it ~3× at 1000×8760 scale
    hours = np.arange(n_hours, dtype=np.int32)
    # n_hours acts as +inf; suffix-min from the right finds the next hit
    cand = np.where(rev, hours[None, :], np.int32(n_hours))
    np.minimum.accumulate(cand[:, ::-1], axis=1, out=cand[:, ::-1])
    cand[cand == n_hours] = -1
    return cand


def next_revocation_scalar(rev_row: np.ndarray, h0: int) -> Optional[int]:
    """Scalar oracle for :func:`next_revocation_table`: first True index of
    ``rev_row`` at or after ``h0`` in a single suffix pass (argmax, then an
    O(1) check of the element it landed on — not a separate ``.any()``
    scan), or None when the suffix is revocation-free or empty."""
    h0 = max(int(h0), 0)
    if h0 >= rev_row.shape[0]:
        return None
    tail = rev_row[h0:]
    idx = int(np.argmax(tail))
    return h0 + idx if tail[idx] else None


# ---------------------------------------------------------------------------
# Synthetic trace generator
# ---------------------------------------------------------------------------

def _build_markets(
    regions: Sequence[str],
    zones_per_region: int,
    menu: Sequence[InstanceShape],
) -> List[Market]:
    """The |regions| × zones × |menu| market list (no RNG involved)."""
    markets: List[Market] = []
    mid = 0
    for region in regions:
        for z in range(zones_per_region):
            zone = f"{region}{chr(ord('a') + z)}"
            for shape in menu:
                markets.append(
                    Market(
                        mid,
                        shape.instance_type,
                        region,
                        zone,
                        shape.memory_gb,
                        shape.on_demand_price,
                        device_count=shape.device_count,
                        interconnect_gbps=shape.interconnect_gbps,
                        steps_per_hour=shape.steps_per_hour,
                    )
                )
                mid += 1
    return markets


def _ar1_noise(eps: np.ndarray, phi: float) -> np.ndarray:
    """AR(1) recursion ``x[h] = phi * x[h-1] + eps[:, h]`` for ALL markets,
    bit-identical to :func:`_ar1_noise_scalar` (pinned by a hypothesis
    property test).

    Preferred path: ``scipy.signal.lfilter`` with ``b=[1], a=[1, -phi]``.
    Its direct-form-II-transposed update is ``y[n] = 1.0*x[n] + z;
    z = phi*y[n]`` — the same two IEEE-double ops as the recurrence with
    the addition commuted, and float addition is exactly commutative, so
    the output is bit-identical to the scalar loop (verified over random
    inputs before adoption, re-pinned by the property test). Fallback when
    scipy is absent: one Python pass over hours, each update elementwise
    across the market axis — also bit-identical, O(hours) interpreter
    steps instead of O(markets × hours)."""
    if _lfilter is not None:
        return _lfilter([1.0], [1.0, -phi], eps, axis=1)
    noise = np.empty_like(eps)
    x = np.zeros(eps.shape[0])
    for h in range(eps.shape[1]):  # single hour pass, vector across markets  # repro-lint: disable=V001
        x = phi * x + eps[:, h]
        noise[:, h] = x
    return noise


def _ar1_noise_scalar(eps: np.ndarray, phi: float) -> np.ndarray:
    """Scalar-oracle AR(1): the original per-market-per-hour loop."""
    noise = np.empty_like(eps)
    for i in range(eps.shape[0]):  # scalar oracle, kept for the bit-exactness tests  # repro-lint: disable=V001
        x = 0.0
        for h in range(eps.shape[1]):  # scalar oracle, kept for the bit-exactness tests  # repro-lint: disable=V001
            x = phi * x + eps[i, h]
            noise[i, h] = x
    return noise


def _draw_market_randomness(
    rng: np.random.Generator,
    markets: Sequence[Market],
    n_hours: int,
    rare_market_fraction: float,
):
    """Every per-market RNG draw of the trace generator, in the EXACT
    stream order the original scalar implementation consumed them
    (base_ratio → eps → rare → local_rate → local_spikes → zone-damp →
    spike_mult, market by market). Collecting the draws into (markets ×
    hours) arrays first is what lets the price composition be one
    vectorized expression without perturbing a single sample."""
    n = len(markets)
    # zone-shared spike trains (same-hour revocations within a zone)
    zones = sorted({m.zone for m in markets})
    zone_rate = {z: rng.uniform(0.0005, 0.004) for z in zones}
    zone_spikes = {
        z: rng.random(n_hours) < zone_rate[z] for z in zones
    }

    base_ratio = np.empty(n)
    eps = np.empty((n, n_hours))
    spikes = np.empty((n, n_hours), dtype=bool)
    spike_mult = np.empty((n, n_hours))
    for i, m in enumerate(markets):
        # EC2 spot discounts average 60–70 % off on-demand, but the paper's
        # F ≥ O cost ordering (Fig. 1d–f) implies its traces sat at the
        # shallow end; we default to U(0.55, 0.80) and ship a sensitivity
        # sweep over the ratio (benchmarks/fig1.py --ratio-sweep).
        base_ratio[i] = rng.uniform(0.55, 0.80)
        eps[i] = rng.normal(0.0, 0.015, n_hours)
        rare = rng.random() < rare_market_fraction
        local_rate = 0.0 if rare else rng.uniform(0.001, 0.02)
        local_spikes = rng.random(n_hours) < local_rate
        if rare:
            # rare markets ignore even most zone shocks (deeper capacity pool)
            spikes[i] = local_spikes | (
                zone_spikes[m.zone] & (rng.random(n_hours) < 0.1)
            )
        else:
            spikes[i] = local_spikes | zone_spikes[m.zone]
        spike_mult[i] = rng.uniform(1.05, 1.6, n_hours)
    return base_ratio, eps, spikes, spike_mult


def generate_markets(
    *,
    seed: int = 0,
    n_hours: int = HOURS_3_MONTHS,
    regions: Sequence[str] = REGIONS,
    zones_per_region: int = ZONES_PER_REGION,
    menu: Sequence[InstanceShape] = INSTANCE_MENU,
    rare_market_fraction: float = 0.25,
) -> MarketSet:
    """Markets = |regions| × zones × |menu|; hourly prices for ``n_hours``.

    Price process per market: base spot ratio ~ U(0.15, 0.40) of on-demand
    with AR(1) jitter, plus *spike* processes that push the price above
    on-demand (a revocation hour):

    * market-local spikes: Poisson with rate drawn per market; a
      ``rare_market_fraction`` of markets get rate ≈ 0 (the MTTR > 600 h
      markets the paper's key idea relies on),
    * zone-shared spikes: a per-zone shock hits every market in that zone
      (intra-zone revocation correlation; across zones independent).

    Vectorized over markets × hours, bit-identical to the retained scalar
    oracle :func:`generate_markets_scalar` (same ``default_rng`` draw
    order; see ``docs/simulator-perf.md`` for the contract).
    """
    rng = np.random.default_rng(seed)
    markets = _build_markets(regions, zones_per_region, menu)
    base_ratio, eps, spikes, spike_mult = _draw_market_randomness(
        rng, markets, n_hours, rare_market_fraction
    )
    # AR(1) mean-reverting jitter around the base ratio. The composition
    # runs in place on the (markets × hours) buffers we already own — at
    # 1000×8760 each avoided temporary is a 70 MB pass. Every rewrite is
    # value-exact: += / *= commute float + and × (exactly commutative),
    # clip(out=) and copyto(where=) select the same elements np.where
    # would.
    noise = _ar1_noise(eps, phi=0.97)
    noise += base_ratio[:, None]
    np.clip(noise, 0.05, 0.95, out=noise)              # ratio
    od = np.array([m.on_demand_price for m in markets])[:, None]
    noise *= od                                        # ratio * od
    spike_mult *= od                                   # od * spike_mult
    np.copyto(noise, spike_mult, where=spikes)         # spike hours win
    return MarketSet(markets=markets, prices=noise)


def generate_markets_scalar(
    *,
    seed: int = 0,
    n_hours: int = HOURS_3_MONTHS,
    regions: Sequence[str] = REGIONS,
    zones_per_region: int = ZONES_PER_REGION,
    menu: Sequence[InstanceShape] = INSTANCE_MENU,
    rare_market_fraction: float = 0.25,
) -> MarketSet:
    """Scalar-oracle trace generator: the original per-market-per-hour
    implementation of :func:`generate_markets`, kept verbatim as the
    reference the vectorized path must match bit-for-bit (asserted by
    ``benchmarks/sim_bench.py`` and ``tests/test_vectorized_core.py``)."""
    rng = np.random.default_rng(seed)
    markets = _build_markets(regions, zones_per_region, menu)
    n = len(markets)
    prices = np.empty((n, n_hours))

    zones = sorted({m.zone for m in markets})
    zone_rate = {z: rng.uniform(0.0005, 0.004) for z in zones}
    zone_spikes = {
        z: rng.random(n_hours) < zone_rate[z] for z in zones
    }

    for i, m in enumerate(markets):
        base_ratio = rng.uniform(0.55, 0.80)
        noise = np.empty(n_hours)
        x = 0.0
        phi, sig = 0.97, 0.015
        eps = rng.normal(0.0, sig, n_hours)
        for h in range(n_hours):  # scalar oracle, kept for the bit-exactness tests  # repro-lint: disable=V001
            x = phi * x + eps[h]
            noise[h] = x
        ratio = np.clip(base_ratio + noise, 0.05, 0.95)

        rare = rng.random() < rare_market_fraction
        local_rate = 0.0 if rare else rng.uniform(0.001, 0.02)
        local_spikes = rng.random(n_hours) < local_rate
        spikes = local_spikes | zone_spikes[m.zone]
        if rare:
            spikes = local_spikes | (zone_spikes[m.zone] & (rng.random(n_hours) < 0.1))

        price = ratio * m.on_demand_price
        spike_mult = rng.uniform(1.05, 1.6, n_hours)
        price = np.where(spikes, m.on_demand_price * spike_mult, price)
        prices[i] = price
    return MarketSet(markets=markets, prices=prices)


def split_history_future(ms: MarketSet, history_hours: int) -> Tuple[MarketSet, MarketSet]:
    """Features are computed on the past window; jobs run on the future one."""
    hist = MarketSet(ms.markets, ms.prices[:, :history_hours], start_hour=0)
    fut = MarketSet(
        ms.markets, ms.prices[:, history_hours:], start_hour=history_hours
    )
    return hist, fut


def load_csv_traces(path: str) -> MarketSet:
    """Real-trace loader: CSV columns = market_id,instance_type,region,zone,
    memory_gb,on_demand_price[,device_count,interconnect_gbps]
    [,steps_per_hour],h0,h1,... (one row per market; full schema in
    ``docs/trace-format.md``). The topology and throughput columns are
    optional — legacy traces without them load as single-device instances
    with unit throughput. Detection is header-driven: a headerless file is
    always parsed as the legacy 6-meta-column format, so traces that carry
    any optional column MUST include the header row. An empty
    ``steps_per_hour`` cell means "no measurement" (analytic model used)."""
    markets: List[Market] = []
    rows: List[List[float]] = []
    n_meta = 6
    col: Dict[str, int] = {}
    with open(path) as f:
        for rec in csv.reader(f):
            if rec[0] == "market_id":
                if "h0" in rec:
                    n_meta = rec.index("h0")
                elif any(
                    c in rec
                    for c in ("device_count", "interconnect_gbps", "steps_per_hour")
                ):
                    # price columns unlabeled: the header names exactly the
                    # metadata block, so its length IS the block width (the
                    # PR 2 topology traces shipped this way)
                    n_meta = len(rec)
                col = {name: i for i, name in enumerate(rec[:n_meta])}
                continue
            kw = {}
            if "device_count" in col:
                kw["device_count"] = int(rec[col["device_count"]])
            if "interconnect_gbps" in col:
                kw["interconnect_gbps"] = float(rec[col["interconnect_gbps"]])
            if "steps_per_hour" in col and rec[col["steps_per_hour"]].strip():
                kw["steps_per_hour"] = float(rec[col["steps_per_hour"]])
            markets.append(
                Market(
                    market_id=int(rec[0]),
                    instance_type=rec[1],
                    region=rec[2],
                    zone=rec[3],
                    memory_gb=int(rec[4]),
                    on_demand_price=float(rec[5]),
                    **kw,
                )
            )
            rows.append([float(x) for x in rec[n_meta:]])
    return MarketSet(markets=markets, prices=np.asarray(rows))
