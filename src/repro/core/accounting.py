"""Completion-time and deployment-cost accounting (paper Fig. 1 structure).

Every simulated job produces a :class:`Breakdown` with the exact stacked
components the paper plots:

time components  : execution, re_execution, checkpointing, recovery,
                   reshard, startup
cost components  : the same six (time × in-effect spot price) plus
                   billing_buffer — the cost of the unused remainder of each
                   started billing cycle (EC2 bills whole hours; the paper
                   calls these "buffer costs of billing cycles").

``reshard`` (beyond the paper) is the live cross-mesh migration a spot
revocation triggers in siwoft/hybrid modes: bytes actually moved (see
``repro.dist.meshplan.reshard_bytes``) over the destination market's
interconnect. It sits head-to-head with ``recovery`` (checkpoint restore
through remote storage) in Fig-1-style breakdowns, so the "no-FT is
cheaper" comparison is priced in bytes and dollars, not asserted.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

TIME_COMPONENTS = (
    "execution", "re_execution", "checkpointing", "recovery", "reshard", "startup",
)
COST_COMPONENTS = TIME_COMPONENTS + ("billing_buffer",)

BILLING_CYCLE_HOURS = 1.0


@dataclasses.dataclass
class Breakdown:
    time: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in TIME_COMPONENTS}
    )
    cost: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COST_COMPONENTS}
    )
    revocations: int = 0
    sessions: int = 0
    # wall-clock completion time; == total_time for serial policies, less for
    # replication (replicas burn hours in parallel)
    wall_time: float = 0.0
    # per-leg cost: market_id -> $ billed against that market across every
    # session (multi-leg allocations bill each leg at its own spot price;
    # market_id -1 is the on-demand reference). INVARIANT, pinned by
    # tests/test_allocation.py: sum(leg_cost.values()) == total_cost.
    leg_cost: Dict[int, float] = dataclasses.field(default_factory=dict)

    @property
    def total_time(self) -> float:
        return sum(self.time.values())

    @property
    def total_cost(self) -> float:
        return sum(self.cost.values())

    def add_leg_cost(self, market_id: int, dollars: float) -> None:
        self.leg_cost[market_id] = self.leg_cost.get(market_id, 0.0) + dollars

    def add(self, other: "Breakdown") -> "Breakdown":
        for k in self.time:
            self.time[k] += other.time[k]
        for k in self.cost:
            self.cost[k] += other.cost[k]
        for m, c in other.leg_cost.items():
            self.add_leg_cost(m, c)
        self.revocations += other.revocations
        self.sessions += other.sessions
        self.wall_time += other.wall_time
        return self


@dataclasses.dataclass
class Session:
    """One continuous occupancy of one *allocation*: a list of (component,
    duration) intervals billed against an hourly price function.

    ``legs`` is the tuple of market ids billing concurrently — one entry
    per allocation leg, each charged at its own spot price for the whole
    session (legs run in lockstep; a leg is occupied for every wall hour
    the job runs, whatever component that hour lands in). Defaults to the
    single-market ``(market_id,)``, which bills identically to the
    pre-allocation accounting."""

    market_id: int
    start_wall: float
    intervals: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    legs: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.legs is None:
            self.legs = (self.market_id,)

    def add(self, component: str, hours: float) -> None:
        if hours > 0:
            self.intervals.append((component, hours))

    @property
    def used_hours(self) -> float:
        return sum(h for _, h in self.intervals)


def bill_session(
    session: Session,
    price_of_hour,  # (market_id, absolute_hour) -> $/h
    breakdown: Breakdown,
) -> float:
    """Accrue a session into a breakdown with per-billing-cycle pricing.

    Each component interval is charged at the spot price in effect during
    the wall-clock hour it runs in — summed over the session's legs, each
    leg at its own market's price — and the per-leg shares land in
    ``Breakdown.leg_cost`` so allocation bills decompose exactly. The
    unused tail of the final billing cycle (per leg: whole-hour billing is
    per spot request) is charged to ``billing_buffer``. Returns the wall
    time consumed.
    """
    t = session.start_wall
    for comp, dur in session.intervals:
        remaining = dur
        while remaining > 1e-12:
            hour_idx = math.floor(t)
            step = min(remaining, (hour_idx + 1) - t)
            breakdown.time[comp] += step
            for leg in session.legs:
                leg_dollars = step * price_of_hour(leg, hour_idx)
                breakdown.cost[comp] += leg_dollars
                breakdown.add_leg_cost(leg, leg_dollars)
            t += step
            remaining -= step
    used = session.used_hours
    billed = math.ceil(max(used, 1e-9) / BILLING_CYCLE_HOURS) * BILLING_CYCLE_HOURS
    buffer_hours = billed - used
    tail_hour = math.floor(t)
    for leg in session.legs:
        leg_buffer = buffer_hours * price_of_hour(leg, tail_hour)
        breakdown.cost["billing_buffer"] += leg_buffer
        breakdown.add_leg_cost(leg, leg_buffer)
    breakdown.sessions += 1
    return used
