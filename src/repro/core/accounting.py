"""Completion-time and deployment-cost accounting (paper Fig. 1 structure).

Every simulated job produces a :class:`Breakdown` with the exact stacked
components the paper plots:

time components  : execution, re_execution, checkpointing, recovery,
                   reshard, startup, slo_violation
cost components  : the same seven (time × in-effect spot price) plus
                   billing_buffer — the cost of the unused remainder of each
                   started billing cycle (EC2 bills whole hours; the paper
                   calls these "buffer costs of billing cycles").

``reshard`` (beyond the paper) is the live cross-mesh migration a spot
revocation triggers in siwoft/hybrid modes: bytes actually moved (see
``repro.dist.meshplan.reshard_bytes``) over the destination market's
interconnect. It sits head-to-head with ``recovery`` (checkpoint restore
through remote storage) in Fig-1-style breakdowns, so the "no-FT is
cheaper" comparison is priced in bytes and dollars, not asserted.

``slo_violation`` (beyond the paper, serving) is the wall time a serving
fleet spent out of its latency SLO (``repro.serve.router``); the fleet
simulator adds it to ``Breakdown.time`` directly — it is a penalty clock,
not an occupancy interval, so no session bills dollars against it. The
serving token counters (``served_tokens`` / ``shed_tokens`` /
``queued_token_seconds``) ride on the Breakdown the same way
``revocations`` does: merged by :meth:`Breakdown.add`, zero for batch
jobs.

Leg-level billing-cycle staggering (beyond the paper): by default every
leg of a session starts its billing cycle at the session start and pays
its buffer at the session end ("cycles aligned"). A session may instead
carry per-leg ``leg_anchors`` (the absolute wall hour each leg's cycle
phase is anchored to — its tenure start) and ``leg_releases`` (whether
the leg's occupancy ends with this session). An unreleased leg pays NO
buffer at session end — its cycle continues into the next session that
carries the same anchor — so a mid-cycle one-leg repair bills only the
replaced leg's partial hour; :func:`settle_leg` charges the final partial
cycle of a leg whose tenure ends without a closing session.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

TIME_COMPONENTS = (
    "execution", "re_execution", "checkpointing", "recovery", "reshard", "startup",
    "slo_violation",
)
COST_COMPONENTS = TIME_COMPONENTS + ("billing_buffer",)

BILLING_CYCLE_HOURS = 1.0


@dataclasses.dataclass
class Breakdown:
    time: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in TIME_COMPONENTS}
    )
    cost: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COST_COMPONENTS}
    )
    revocations: int = 0
    sessions: int = 0
    # wall-clock completion time; == total_time for serial policies, less for
    # replication (replicas burn hours in parallel)
    wall_time: float = 0.0
    # per-leg cost: market_id -> $ billed against that market across every
    # session (multi-leg allocations bill each leg at its own spot price;
    # market_id -1 is the on-demand reference). INVARIANT, pinned by
    # tests/test_allocation.py: sum(leg_cost.values()) == total_cost.
    leg_cost: Dict[int, float] = dataclasses.field(default_factory=dict)
    # serving counters (repro.serve.router): tokens the fleet served /
    # shed, and the integral of queued tokens over time (token·seconds).
    # Zero for batch jobs; the SLO-violation CLOCK lands in
    # time["slo_violation"], these carry the matching token volumes.
    served_tokens: float = 0.0
    shed_tokens: float = 0.0
    queued_token_seconds: float = 0.0

    @property
    def total_time(self) -> float:
        return sum(self.time.values())

    @property
    def total_cost(self) -> float:
        return sum(self.cost.values())

    def add_leg_cost(self, market_id: int, dollars: float) -> None:
        self.leg_cost[market_id] = self.leg_cost.get(market_id, 0.0) + dollars

    def add(self, other: "Breakdown") -> "Breakdown":
        for k in self.time:
            self.time[k] += other.time[k]
        for k in self.cost:
            self.cost[k] += other.cost[k]
        for m, c in other.leg_cost.items():
            self.add_leg_cost(m, c)
        self.revocations += other.revocations
        self.sessions += other.sessions
        self.wall_time += other.wall_time
        self.served_tokens += other.served_tokens
        self.shed_tokens += other.shed_tokens
        self.queued_token_seconds += other.queued_token_seconds
        return self


@dataclasses.dataclass
class Session:
    """One continuous occupancy of one *allocation*: a list of (component,
    duration) intervals billed against an hourly price function.

    ``legs`` is the tuple of market ids billing concurrently — one entry
    per allocation leg, each charged at its own spot price for the whole
    session (legs run in lockstep; a leg is occupied for every wall hour
    the job runs, whatever component that hour lands in). Defaults to the
    single-market ``(market_id,)``, which bills identically to the
    pre-allocation accounting.

    ``leg_anchors`` (optional, one per leg) staggers billing cycles: each
    leg's whole-hour cycles are phased from its own anchor — the absolute
    wall hour its tenure began, ≤ ``start_wall`` — instead of the shared
    session start. ``leg_releases`` (optional, one per leg) marks which
    legs' occupancy ENDS with this session; a leg not released pays no
    billing buffer here (its current cycle continues into a later session
    carrying the same anchor, or is settled by :func:`settle_leg`). When
    ``leg_anchors`` is None the legacy aligned-cycle billing applies
    exactly: every leg anchors at the session start and is released at
    the session end."""

    market_id: int
    start_wall: float
    intervals: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    legs: Optional[Tuple[int, ...]] = None
    leg_anchors: Optional[Tuple[float, ...]] = None
    leg_releases: Optional[Tuple[bool, ...]] = None

    def __post_init__(self):
        if self.legs is None:
            self.legs = (self.market_id,)
        if self.leg_anchors is not None:
            assert len(self.leg_anchors) == len(self.legs)
            assert all(a <= self.start_wall + 1e-12 for a in self.leg_anchors), (
                "a leg's cycle anchor is its tenure start — never after the "
                "session it bills in"
            )
        if self.leg_releases is not None:
            assert len(self.leg_releases) == len(self.legs)

    def add(self, component: str, hours: float) -> None:
        if hours > 0:
            self.intervals.append((component, hours))

    @property
    def used_hours(self) -> float:
        return sum(h for _, h in self.intervals)


def bill_session(
    session: Session,
    price_of_hour,  # (market_id, absolute_hour) -> $/h
    breakdown: Breakdown,
) -> float:
    """Accrue a session into a breakdown with per-billing-cycle pricing.

    Each component interval is charged at the spot price in effect during
    the wall-clock hour it runs in — summed over the session's legs, each
    leg at its own market's price — and the per-leg shares land in
    ``Breakdown.leg_cost`` so allocation bills decompose exactly. The
    unused tail of the final billing cycle (per leg: whole-hour billing is
    per spot request) is charged to ``billing_buffer``. With staggered
    ``leg_anchors``, each RELEASED leg's buffer runs from the session end
    to the next cycle boundary of ITS OWN anchor (unreleased legs pay no
    buffer — their cycle is still open). Returns the wall time consumed.
    """
    t = session.start_wall
    for comp, dur in session.intervals:
        remaining = dur
        while remaining > 1e-12:
            hour_idx = math.floor(t)
            step = min(remaining, (hour_idx + 1) - t)
            breakdown.time[comp] += step
            for leg in session.legs:
                leg_dollars = step * price_of_hour(leg, hour_idx)
                breakdown.cost[comp] += leg_dollars
                breakdown.add_leg_cost(leg, leg_dollars)
            t += step
            remaining -= step
    used = session.used_hours
    tail_hour = math.floor(t)
    if session.leg_anchors is None:
        # legacy aligned cycles: every leg billed ceil(used) whole hours
        billed = math.ceil(max(used, 1e-9) / BILLING_CYCLE_HOURS) * BILLING_CYCLE_HOURS
        buffer_hours = billed - used
        for leg in session.legs:
            leg_buffer = buffer_hours * price_of_hour(leg, tail_hour)
            breakdown.cost["billing_buffer"] += leg_buffer
            breakdown.add_leg_cost(leg, leg_buffer)
    else:
        releases = session.leg_releases or (True,) * len(session.legs)
        end = session.start_wall + used
        for leg, anchor, released in zip(session.legs, session.leg_anchors, releases):
            if not released:
                continue  # cycle still open; settled by a later session
            # anchor == session start reproduces the legacy ceil(used)
            # arithmetic EXACTLY (no (start + used) - anchor float drift)
            held = used if anchor == session.start_wall else end - anchor
            buffer_hours = _held_buffer_hours(held)
            leg_buffer = buffer_hours * price_of_hour(leg, tail_hour)
            breakdown.cost["billing_buffer"] += leg_buffer
            breakdown.add_leg_cost(leg, leg_buffer)
    breakdown.sessions += 1
    return used


def _held_buffer_hours(held: float) -> float:
    """Unused remainder of the billing cycle open after ``held`` hours of
    occupancy since the leg's anchor: the distance to the next cycle
    boundary, one full cycle if the tenure never ran (whole-hour billing
    starts at provisioning, exactly like the legacy ceil rule)."""
    held = max(held, 0.0)
    billed = math.ceil(max(held, 1e-9) / BILLING_CYCLE_HOURS) * BILLING_CYCLE_HOURS
    return billed - held


def settle_leg(
    breakdown: Breakdown,
    market_id: int,
    anchor: float,
    end_wall: float,
    price_of_hour,
) -> float:
    """Close a staggered leg's final billing cycle OUTSIDE a session: charge
    the unused remainder from ``end_wall`` (when the leg's occupancy really
    ended) to the next cycle boundary of its ``anchor``. Used when a leg
    deferred its buffer (``leg_releases`` False) but the allocation that
    replaced it no longer carries the leg. Returns the dollars charged."""
    buffer_hours = _held_buffer_hours(end_wall - anchor)
    dollars = buffer_hours * price_of_hour(market_id, math.floor(end_wall))
    breakdown.cost["billing_buffer"] += dollars
    breakdown.add_leg_cost(market_id, dollars)
    return dollars
