"""Completion-time and deployment-cost accounting (paper Fig. 1 structure).

Every simulated job produces a :class:`Breakdown` with the exact stacked
components the paper plots:

time components  : execution, re_execution, checkpointing, recovery,
                   reshard, startup, slo_violation
cost components  : the same seven (time × in-effect spot price) plus
                   billing_buffer — the cost of the unused remainder of each
                   started billing cycle (EC2 bills whole hours; the paper
                   calls these "buffer costs of billing cycles").

``reshard`` (beyond the paper) is the live cross-mesh migration a spot
revocation triggers in siwoft/hybrid modes: bytes actually moved (see
``repro.dist.meshplan.reshard_bytes``) over the destination market's
interconnect. It sits head-to-head with ``recovery`` (checkpoint restore
through remote storage) in Fig-1-style breakdowns, so the "no-FT is
cheaper" comparison is priced in bytes and dollars, not asserted.

``slo_violation`` (beyond the paper, serving) is the wall time a serving
fleet spent out of its latency SLO (``repro.serve.router``); the fleet
simulator adds it to ``Breakdown.time`` directly — it is a penalty clock,
not an occupancy interval, so no session bills dollars against it. The
serving token counters (``served_tokens`` / ``shed_tokens`` /
``queued_token_seconds``) ride on the Breakdown the same way
``revocations`` does: merged by :meth:`Breakdown.add`, zero for batch
jobs.

Leg-level billing-cycle staggering (beyond the paper): by default every
leg of a session starts its billing cycle at the session start and pays
its buffer at the session end ("cycles aligned"). A session may instead
carry per-leg ``leg_anchors`` (the absolute wall hour each leg's cycle
phase is anchored to — its tenure start) and ``leg_releases`` (whether
the leg's occupancy ends with this session). An unreleased leg pays NO
buffer at session end — its cycle continues into the next session that
carries the same anchor — so a mid-cycle one-leg repair bills only the
replaced leg's partial hour; :func:`settle_leg` charges the final partial
cycle of a leg whose tenure ends without a closing session.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

TIME_COMPONENTS = (
    "execution", "re_execution", "checkpointing", "recovery", "reshard", "startup",
    "slo_violation",
)
COST_COMPONENTS = TIME_COMPONENTS + ("billing_buffer",)

BILLING_CYCLE_HOURS = 1.0


@dataclasses.dataclass
class Breakdown:
    time: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in TIME_COMPONENTS}
    )
    cost: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COST_COMPONENTS}
    )
    revocations: int = 0
    sessions: int = 0
    # wall-clock completion time; == total_time for serial policies, less for
    # replication (replicas burn hours in parallel)
    wall_time: float = 0.0
    # per-leg cost: market_id -> $ billed against that market across every
    # session (multi-leg allocations bill each leg at its own spot price;
    # market_id -1 is the on-demand reference). INVARIANT, pinned by
    # tests/test_allocation.py: sum(leg_cost.values()) == total_cost.
    leg_cost: Dict[int, float] = dataclasses.field(default_factory=dict)
    # serving counters (repro.serve.router): tokens the fleet served /
    # shed, and the integral of queued tokens over time (token·seconds).
    # Zero for batch jobs; the SLO-violation CLOCK lands in
    # time["slo_violation"], these carry the matching token volumes.
    served_tokens: float = 0.0
    shed_tokens: float = 0.0
    queued_token_seconds: float = 0.0

    @property
    def total_time(self) -> float:
        return sum(self.time.values())

    @property
    def total_cost(self) -> float:
        return sum(self.cost.values())

    def add_leg_cost(self, market_id: int, dollars: float) -> None:
        self.leg_cost[market_id] = self.leg_cost.get(market_id, 0.0) + dollars

    def add(self, other: "Breakdown") -> "Breakdown":
        for k in self.time:
            self.time[k] += other.time[k]
        for k in self.cost:
            self.cost[k] += other.cost[k]
        for m, c in other.leg_cost.items():
            self.add_leg_cost(m, c)
        self.revocations += other.revocations
        self.sessions += other.sessions
        self.wall_time += other.wall_time
        self.served_tokens += other.served_tokens
        self.shed_tokens += other.shed_tokens
        self.queued_token_seconds += other.queued_token_seconds
        return self


@dataclasses.dataclass
class Session:
    """One continuous occupancy of one *allocation*: a list of (component,
    duration) intervals billed against an hourly price function.

    ``legs`` is the tuple of market ids billing concurrently — one entry
    per allocation leg, each charged at its own spot price for the whole
    session (legs run in lockstep; a leg is occupied for every wall hour
    the job runs, whatever component that hour lands in). Defaults to the
    single-market ``(market_id,)``, which bills identically to the
    pre-allocation accounting.

    ``leg_anchors`` (optional, one per leg) staggers billing cycles: each
    leg's whole-hour cycles are phased from its own anchor — the absolute
    wall hour its tenure began, ≤ ``start_wall`` — instead of the shared
    session start. ``leg_releases`` (optional, one per leg) marks which
    legs' occupancy ENDS with this session; a leg not released pays no
    billing buffer here (its current cycle continues into a later session
    carrying the same anchor, or is settled by :func:`settle_leg`). When
    ``leg_anchors`` is None the legacy aligned-cycle billing applies
    exactly: every leg anchors at the session start and is released at
    the session end."""

    market_id: int
    start_wall: float
    intervals: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    legs: Optional[Tuple[int, ...]] = None
    leg_anchors: Optional[Tuple[float, ...]] = None
    leg_releases: Optional[Tuple[bool, ...]] = None

    def __post_init__(self):
        if self.legs is None:
            self.legs = (self.market_id,)
        if self.leg_anchors is not None:
            assert len(self.leg_anchors) == len(self.legs)
            assert all(a <= self.start_wall + 1e-12 for a in self.leg_anchors), (
                "a leg's cycle anchor is its tenure start — never after the "
                "session it bills in"
            )
        if self.leg_releases is not None:
            assert len(self.leg_releases) == len(self.legs)

    def add(self, component: str, hours: float) -> None:
        if hours > 0:
            self.intervals.append((component, hours))

    @property
    def used_hours(self) -> float:
        return sum(h for _, h in self.intervals)


class PriceTable:
    """Vectorized ``(market_id, absolute_hour) -> $/h`` price source.

    Wraps a ``(n_markets, n_hours)`` price matrix; calling it reproduces
    the legacy closures (``MarketSet.spot_price`` and the simulators'
    ``_price`` lambdas) exactly, including the clamp of out-of-range hours
    to the final column. Passing a PriceTable — instead of an opaque
    callable — to :func:`bill_session` is what unlocks the vectorized
    billing path: the biller can gather a whole interval's hourly prices
    in one numpy indexing op instead of one Python call per (hour, leg).
    """

    __slots__ = ("prices", "_broadcast")

    def __init__(self, prices: np.ndarray, *, broadcast_market: bool = False):
        self.prices = np.asarray(prices, dtype=float)
        assert self.prices.ndim == 2 and self.prices.shape[1] >= 1
        self._broadcast = broadcast_market

    @classmethod
    def constant(cls, price: float) -> "PriceTable":
        """A flat price for every market and hour (the on-demand case)."""
        return cls(np.array([[float(price)]]), broadcast_market=True)

    def row(self, market_id: int) -> np.ndarray:
        return self.prices[0] if self._broadcast else self.prices[market_id]

    def __call__(self, market_id: int, hour: int) -> float:
        row = self.row(market_id)
        return float(row[min(int(hour), row.shape[0] - 1)])


_EMPTY = np.empty(0)


def _interval_layout(t: float, dur: float) -> Tuple[int, float, int, float, float]:
    """Closed-form replay of the scalar billing loop over ONE interval.

    Returns ``(first_hour, cell0, n_ones, tail, t_after)`` describing the
    exact hour-cell sequence ``[cell0] + [1.0]*n_ones + ([tail] if tail)``
    billed in consecutive wall hours from ``first_hour`` — step-for-step
    identical to the scalar ``while remaining > 1e-12`` loop starting at
    wall time ``t``. A zero-length interval reports ``cell0 == 0.0`` (no
    cells). Exactness argument (the reason no per-hour iteration is
    needed):

    * the first partial step ``(floor(t)+1) - t`` re-adds to exactly the
      next hour boundary, so after it ``t`` is exactly integral;
    * from an integral ``t``, every full cycle decrements ``remaining`` by
      exactly 1.0 (both exact float ops for ``remaining ≥ 1``), so the
      cell list is ``int(remaining)`` ones plus an exact fractional tail;
    * a tail ≤ 1e-12 is NOT billed and does NOT advance ``t`` — the same
      epsilon guard the scalar loop applies.
    """
    remaining = dur
    if not remaining > 1e-12:
        return 0, 0.0, 0, 0.0, t
    first_hour = math.floor(t)
    width = (first_hour + 1) - t
    if remaining <= width:
        return first_hour, remaining, 0, 0.0, t + remaining
    remaining = dur - width
    n_full = int(remaining)
    tail = remaining - n_full
    if tail > 1e-12:
        return first_hour, width, n_full, tail, float(first_hour + 1 + n_full) + tail
    return first_hour, width, n_full, 0.0, float(first_hour + 1 + n_full)


def _interval_cells(t: float, dur: float) -> Tuple[np.ndarray, int, float]:
    """:func:`_interval_layout` materialized as a step array — the form the
    property tests compare against the scalar loop cell-by-cell."""
    first_hour, cell0, n_ones, tail, t_after = _interval_layout(t, dur)
    if cell0 == 0.0:
        return _EMPTY, 0, t_after
    steps = np.ones(1 + n_ones + (1 if tail else 0))
    steps[0] = cell0
    if tail:
        steps[-1] = tail
    return steps, first_hour, t_after


def _fold(start: float, terms: np.ndarray) -> float:
    """Strict left-to-right float accumulation ``start + terms[0] + ...``.

    ``np.add.accumulate`` is sequential for float64 (pairwise summation
    only applies to ``add.reduce``), so this is bit-identical to the
    scalar ``+=`` loop it replaces — the property tests in
    ``tests/test_vectorized_core.py`` pin that equivalence.
    """
    if terms.size == 0:
        return start
    acc = np.empty(terms.size + 1)
    acc[0] = start
    acc[1:] = terms
    return float(np.add.accumulate(acc)[-1])


def bill_session(
    session: Session,
    price_of_hour,  # (market_id, absolute_hour) -> $/h, or a PriceTable
    breakdown: Breakdown,
) -> float:
    """Accrue a session into a breakdown with per-billing-cycle pricing.

    Each component interval is charged at the spot price in effect during
    the wall-clock hour it runs in — summed over the session's legs, each
    leg at its own market's price — and the per-leg shares land in
    ``Breakdown.leg_cost`` so allocation bills decompose exactly. The
    unused tail of the final billing cycle (per leg: whole-hour billing is
    per spot request) is charged to ``billing_buffer``. With staggered
    ``leg_anchors``, each RELEASED leg's buffer runs from the session end
    to the next cycle boundary of ITS OWN anchor (unreleased legs pay no
    buffer — their cycle is still open). Returns the wall time consumed.

    When ``price_of_hour`` is a :class:`PriceTable` the vectorized biller
    runs (one numpy gather per interval instead of one Python call per
    hour per leg); arbitrary callables take the scalar-oracle path. Both
    produce bit-identical breakdowns — see ``docs/simulator-perf.md``.
    """
    if isinstance(price_of_hour, PriceTable) and len(set(session.legs)) == len(
        session.legs
    ):
        return _bill_session_table(session, price_of_hour, breakdown)
    return _bill_session_scalar(session, price_of_hour, breakdown)


def _bill_session_scalar(
    session: Session,
    price_of_hour,
    breakdown: Breakdown,
) -> float:
    """Scalar-oracle biller: the original per-hour-cell Python loop, kept
    verbatim as the reference :func:`_bill_session_table` must match
    bit-for-bit (pinned by hypothesis tests and ``sim_bench``)."""
    t = session.start_wall
    for comp, dur in session.intervals:
        remaining = dur
        while remaining > 1e-12:
            hour_idx = math.floor(t)
            step = min(remaining, (hour_idx + 1) - t)
            breakdown.time[comp] += step
            for leg in session.legs:
                leg_dollars = step * price_of_hour(leg, hour_idx)
                breakdown.cost[comp] += leg_dollars
                breakdown.add_leg_cost(leg, leg_dollars)
            t += step
            remaining -= step
    _bill_cycle_buffers(session, price_of_hour, breakdown, math.floor(t))
    breakdown.sessions += 1
    return session.used_hours


def _bill_session_table(
    session: Session,
    table: PriceTable,
    breakdown: Breakdown,
) -> float:
    """Vectorized biller: generate every interval's exact hour-cell layout
    in closed form (:func:`_interval_layout`, pure scalar arithmetic), then
    build the whole session's cell/price arrays in O(1) numpy ops and
    accumulate via sequential :func:`_fold` sums. Numpy call count scales
    with the number of components + legs, NOT with the interval count —
    checkpoint sessions carry hundreds of tiny intervals, and paying
    per-interval array overhead on those was slower than the scalar loop.

    Bit-exactness: each accumulator key receives exactly the addends the
    scalar loop feeds it, in the scalar loop's order — ``time[comp]`` /
    ``cost[comp]`` in interval order restricted to that component
    (cell-major, leg-minor for cost), ``leg_cost[leg]`` in global interval
    order — and :func:`_fold` is a strict left-to-right sum."""
    t = session.start_wall
    legs = session.legs
    rows = [table.row(leg) for leg in legs]
    row_len = rows[0].shape[0]

    # pass 1: pure-scalar cell layout per interval
    offsets, firsts, cell0s = [], [], []          # per non-empty interval
    tail_at, tail_val = [], []                    # tail-cell positions
    spans: Dict[str, list] = {}                   # comp -> [(start, stop)]
    total = 0
    for comp, dur in session.intervals:
        first_hour, cell0, n_ones, tail, t = _interval_layout(t, dur)
        if cell0 == 0.0:
            continue
        n_cells = 1 + n_ones + (1 if tail else 0)
        offsets.append(total)
        firsts.append(first_hour)
        cell0s.append(cell0)
        if tail:
            tail_at.append(total + n_cells - 1)
            tail_val.append(tail)
        spans.setdefault(comp, []).append((total, total + n_cells))
        total += n_cells

    if total:
        # pass 2: one array build + one price gather for the whole session
        steps_all = np.ones(total)
        steps_all[offsets] = cell0s
        if tail_at:
            steps_all[tail_at] = tail_val
        # hour of cell k = first_hour of its interval + (k - interval start),
        # clamped to the trace end like PriceTable.__call__
        hour_idx = np.repeat(
            np.asarray(firsts) - np.asarray(offsets), np.diff(offsets + [total])
        ) + np.arange(total)
        np.minimum(hour_idx, row_len - 1, out=hour_idx)
        # dollars[k, j] = steps[k] * price(leg j, hour k): the scalar
        # loop's per-cell products, computed in one broadcast
        dollars = steps_all[:, None] * np.stack(
            [row[hour_idx] for row in rows], axis=1
        )
        for comp, sp in spans.items():
            comp_rows = (
                dollars[sp[0][0]:sp[0][1]]
                if len(sp) == 1
                else np.concatenate([dollars[a:b] for a, b in sp])
            )
            breakdown.time[comp] = _fold(
                breakdown.time[comp],
                steps_all[sp[0][0]:sp[0][1]]
                if len(sp) == 1
                else np.concatenate([steps_all[a:b] for a, b in sp]),
            )
            breakdown.cost[comp] = _fold(breakdown.cost[comp], comp_rows.ravel())
        for j, leg in enumerate(legs):
            breakdown.leg_cost[leg] = _fold(
                breakdown.leg_cost.get(leg, 0.0), dollars[:, j]
            )
    _bill_cycle_buffers(session, table, breakdown, math.floor(t))
    breakdown.sessions += 1
    return session.used_hours


def _bill_cycle_buffers(
    session: Session,
    price_of_hour,
    breakdown: Breakdown,
    tail_hour: int,
) -> None:
    """Charge each released leg's unused billing-cycle remainder (shared by
    both billers; identical arithmetic to the original inline block)."""
    used = session.used_hours
    if session.leg_anchors is None:
        # legacy aligned cycles: every leg billed ceil(used) whole hours
        billed = math.ceil(max(used, 1e-9) / BILLING_CYCLE_HOURS) * BILLING_CYCLE_HOURS
        buffer_hours = billed - used
        for leg in session.legs:
            leg_buffer = buffer_hours * price_of_hour(leg, tail_hour)
            breakdown.cost["billing_buffer"] += leg_buffer
            breakdown.add_leg_cost(leg, leg_buffer)
    else:
        releases = session.leg_releases or (True,) * len(session.legs)
        end = session.start_wall + used
        for leg, anchor, released in zip(session.legs, session.leg_anchors, releases):
            if not released:
                continue  # cycle still open; settled by a later session
            # anchor == session start reproduces the legacy ceil(used)
            # arithmetic EXACTLY (no (start + used) - anchor float drift)
            held = used if anchor == session.start_wall else end - anchor
            buffer_hours = _held_buffer_hours(held)
            leg_buffer = buffer_hours * price_of_hour(leg, tail_hour)
            breakdown.cost["billing_buffer"] += leg_buffer
            breakdown.add_leg_cost(leg, leg_buffer)


def _held_buffer_hours(held: float) -> float:
    """Unused remainder of the billing cycle open after ``held`` hours of
    occupancy since the leg's anchor: the distance to the next cycle
    boundary, one full cycle if the tenure never ran (whole-hour billing
    starts at provisioning, exactly like the legacy ceil rule)."""
    held = max(held, 0.0)
    billed = math.ceil(max(held, 1e-9) / BILLING_CYCLE_HOURS) * BILLING_CYCLE_HOURS
    return billed - held


def settle_leg(
    breakdown: Breakdown,
    market_id: int,
    anchor: float,
    end_wall: float,
    price_of_hour,
) -> float:
    """Close a staggered leg's final billing cycle OUTSIDE a session: charge
    the unused remainder from ``end_wall`` (when the leg's occupancy really
    ended) to the next cycle boundary of its ``anchor``. Used when a leg
    deferred its buffer (``leg_releases`` False) but the allocation that
    replaced it no longer carries the leg. Returns the dollars charged."""
    buffer_hours = _held_buffer_hours(end_wall - anchor)
    dollars = buffer_hours * price_of_hour(market_id, math.floor(end_wall))
    breakdown.cost["billing_buffer"] += dollars
    breakdown.add_leg_cost(market_id, dollars)
    return dollars
