"""Completion-time and deployment-cost accounting (paper Fig. 1 structure).

Every simulated job produces a :class:`Breakdown` with the exact stacked
components the paper plots:

time components  : execution, re_execution, checkpointing, recovery,
                   reshard, startup
cost components  : the same six (time × in-effect spot price) plus
                   billing_buffer — the cost of the unused remainder of each
                   started billing cycle (EC2 bills whole hours; the paper
                   calls these "buffer costs of billing cycles").

``reshard`` (beyond the paper) is the live cross-mesh migration a spot
revocation triggers in siwoft/hybrid modes: bytes actually moved (see
``repro.dist.meshplan.reshard_bytes``) over the destination market's
interconnect. It sits head-to-head with ``recovery`` (checkpoint restore
through remote storage) in Fig-1-style breakdowns, so the "no-FT is
cheaper" comparison is priced in bytes and dollars, not asserted.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

TIME_COMPONENTS = (
    "execution", "re_execution", "checkpointing", "recovery", "reshard", "startup",
)
COST_COMPONENTS = TIME_COMPONENTS + ("billing_buffer",)

BILLING_CYCLE_HOURS = 1.0


@dataclasses.dataclass
class Breakdown:
    time: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in TIME_COMPONENTS}
    )
    cost: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COST_COMPONENTS}
    )
    revocations: int = 0
    sessions: int = 0
    # wall-clock completion time; == total_time for serial policies, less for
    # replication (replicas burn hours in parallel)
    wall_time: float = 0.0

    @property
    def total_time(self) -> float:
        return sum(self.time.values())

    @property
    def total_cost(self) -> float:
        return sum(self.cost.values())

    def add(self, other: "Breakdown") -> "Breakdown":
        for k in self.time:
            self.time[k] += other.time[k]
        for k in self.cost:
            self.cost[k] += other.cost[k]
        self.revocations += other.revocations
        self.sessions += other.sessions
        self.wall_time += other.wall_time
        return self


@dataclasses.dataclass
class Session:
    """One continuous occupancy of one instance: a list of (component,
    duration) intervals billed against an hourly price function."""

    market_id: int
    start_wall: float
    intervals: List[Tuple[str, float]] = dataclasses.field(default_factory=list)

    def add(self, component: str, hours: float) -> None:
        if hours > 0:
            self.intervals.append((component, hours))

    @property
    def used_hours(self) -> float:
        return sum(h for _, h in self.intervals)


def bill_session(
    session: Session,
    price_of_hour,  # (market_id, absolute_hour) -> $/h
    breakdown: Breakdown,
) -> float:
    """Accrue a session into a breakdown with per-billing-cycle pricing.

    Each component interval is charged at the spot price in effect during
    the wall-clock hour it runs in; the unused tail of the final billing
    cycle is charged to ``billing_buffer``. Returns the wall time consumed.
    """
    t = session.start_wall
    for comp, dur in session.intervals:
        remaining = dur
        while remaining > 1e-12:
            hour_idx = math.floor(t)
            step = min(remaining, (hour_idx + 1) - t)
            price = price_of_hour(session.market_id, hour_idx)
            breakdown.time[comp] += step
            breakdown.cost[comp] += step * price
            t += step
            remaining -= step
    used = session.used_hours
    billed = math.ceil(max(used, 1e-9) / BILLING_CYCLE_HOURS) * BILLING_CYCLE_HOURS
    buffer_hours = billed - used
    tail_price = price_of_hour(session.market_id, math.floor(t))
    breakdown.cost["billing_buffer"] += buffer_hours * tail_price
    breakdown.sessions += 1
    return used
