"""THE PAPER: P-SIWOFT — provisioning spot instances without fault-tolerance
mechanisms (Alourani & Kshemkalyani, ISPDC 2020).

market.py       spot markets, price traces, MTTR / correlation features
provisioner.py  Algorithm 1, step-for-step
policies.py     P-SIWOFT + FT baselines (checkpoint / migration / replication)
simulator.py    discrete-event executor reproducing Fig. 1
accounting.py   per-billing-cycle cost/time breakdowns
orchestrator.py bridges the provisioner to the real JAX training loop
"""
from repro.core.accounting import Breakdown, PriceTable
from repro.core.allocation import DCN_BANDWIDTH_GBPS, Allocation, Leg, combined_throughput
from repro.core.market import (
    INSTANCE_MENU,
    InstanceShape,
    Market,
    MarketSet,
    generate_markets,
    generate_markets_scalar,
    legacy_menu,
    load_csv_traces,
    next_revocation_scalar,
    next_revocation_table,
    revocation_probability,
    shape_throughput,
    split_history_future,
)
from repro.core.policies import (
    CheckpointPolicy,
    Job,
    MigrationPolicy,
    OnDemandPolicy,
    OverheadModel,
    ReplicationPolicy,
    SiwoftPolicy,
)
from repro.core.portfolio import PortfolioPolicy
from repro.core.provisioner import (
    MarketFeatures,
    allocation_expected_cost_to_complete,
    allocation_throughput,
    cost_to_complete,
    expected_cost_to_complete,
    find_suitable_allocations,
)
from repro.core.simulator import Simulator

__all__ = [
    "INSTANCE_MENU", "InstanceShape",
    "Market", "MarketSet", "generate_markets", "generate_markets_scalar",
    "legacy_menu", "load_csv_traces", "next_revocation_scalar",
    "next_revocation_table", "revocation_probability", "shape_throughput",
    "split_history_future", "PriceTable",
    "CheckpointPolicy", "Job", "MigrationPolicy", "OnDemandPolicy",
    "OverheadModel", "ReplicationPolicy", "SiwoftPolicy",
    "MarketFeatures", "PortfolioPolicy", "Simulator", "Breakdown",
    "cost_to_complete", "expected_cost_to_complete",
    "Allocation", "Leg", "DCN_BANDWIDTH_GBPS", "combined_throughput",
    "find_suitable_allocations", "allocation_throughput",
    "allocation_expected_cost_to_complete",
]
