"""Flash attention as a Pallas TPU kernel.

TPU adaptation of the CUDA flash-attention idea: instead of warp-level
tiling, we tile for the MXU (128-aligned q/kv blocks) and exploit the fact
that a TPU Pallas grid executes SEQUENTIALLY per core — the online-softmax
running state (m, l, acc) lives in VMEM scratch and is carried across the
innermost (kv-block) grid dimension, with ``pl.when`` guards initializing
it at kv==0 and writing the normalized output at the last kv block.

Memory: per grid step only (block_q × hd) + (block_k × hd) tiles + the
(block_q × hd) f32 accumulator are resident in VMEM — O(S) HBM traffic
instead of the O(S²) score materialization XLA does (see §Perf).

GQA is handled in the BlockSpec index maps: the kv index maps divide the
query-head index by the group size, so no repeated KV is ever materialized.
Causal/sliding-window blocks that are fully masked are skipped with
``pl.when`` (the ~2× causal FLOP saving).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,           # VMEM tiles
    o_ref, lse_ref,                 # output tiles (lse feeds the backward)
    m_scr, l_scr, acc_scr,          # VMEM scratch carried over kv blocks
    *,
    sm_scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    n_kv: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_offset + qi * block_q
    k_start = kj * block_k

    # skip blocks that are entirely masked out
    run = jnp.bool_(True)
    if causal:
        run &= q_start + block_q - 1 >= k_start
    if window:
        run &= k_start + block_k - 1 > q_start - window

    @pl.when(run)
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32)       # (bq, hd)
        k = k_ref[0, 0, :, :].astype(jnp.float32)       # (bk, hd)
        v = v_ref[0, 0, :, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                                     # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                              # (bq, 1)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                           # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                # (bq, hd)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kj == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)
        # log-sum-exp per query row (f32), consumed by the backward kernels
        lse_ref[0, 0, :, :] = m_scr[...] + jnp.log(l)


def flash_attention(
    q: jax.Array,                  # (B, H, Sq, hd)
    k: jax.Array,                  # (B, KVH, Skv, hd)
    v: jax.Array,                  # (B, KVH, Skv, hd)
    *,
    causal: bool = True,
    window: int = 0,
    sm_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """Head-major flash attention. Shapes must be block-aligned (ops.py pads)."""
    B, H, Sq, hd = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    G = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, block_q, Skv, block_k)
    n_q, n_kv = Sq // block_q, Skv // block_k
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        n_kv=n_kv,
        q_offset=q_offset,
    )
    grid = (B, H, n_q, n_kv)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, kj: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, qi, kj: (b, h // G, kj, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, qi, kj: (b, h // G, kj, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, kj: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_q, 1), lambda b, h, qi, kj: (b, h, qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse
