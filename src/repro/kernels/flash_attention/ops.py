"""Jitted public wrapper around the flash-attention Pallas kernels.

* accepts the model's (B, S, H, hd) layout, transposes to the kernels'
  head-major (B, H, S, hd),
* pads sequence lengths up to block multiples (padded rows/cols are inert:
  causal masking plus zero cotangents keep them out of every gradient),
* ``custom_vjp`` wired to the REAL Pallas backward kernels
  (kernel_bwd.flash_attention_bwd): the forward saves only (q, k, v, o,
  lse) — O(S·hd), never the S×S probabilities — and the backward recomputes
  p tile-by-tile in VMEM, accumulating dk/dv over the sequential q-block
  grid dim and dq over the kv-block dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention as _fwd_kernel
from repro.kernels.flash_attention.kernel_bwd import flash_attention_bwd as _bwd_kernel


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _to_head_major_padded(q, k, v, causal, block_q, block_k):
    Skv = k.shape[1]
    qm = _pad_to(jnp.moveaxis(q, 2, 1), 2, block_q)      # (B, H, Sq+, hd)
    km = _pad_to(jnp.moveaxis(k, 2, 1), 2, block_k)
    vm = _pad_to(jnp.moveaxis(v, 2, 1), 2, block_k)
    if km.shape[2] != Skv and not causal:
        raise ValueError("non-causal flash requires block-aligned KV length")
    return qm, km, vm


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(
    q: jax.Array,                  # (B, Sq, H, hd)
    k: jax.Array,                  # (B, Skv, KVH, hd)
    v: jax.Array,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    o, _ = _run_fwd(q, k, v, causal, window, q_offset, block_q, block_k, interpret)
    return o


def _run_fwd(q, k, v, causal, window, q_offset, block_q, block_k, interpret):
    B, Sq, H, hd = q.shape
    qm, km, vm = _to_head_major_padded(q, k, v, causal, block_q, block_k)
    o, lse = _fwd_kernel(
        qm, km, vm,
        causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return jnp.moveaxis(o[:, :, :Sq, :], 1, 2), (qm, km, vm, o, lse)


def _fwd(q, k, v, causal, window, q_offset, block_q, block_k, interpret):
    out, res = _run_fwd(q, k, v, causal, window, q_offset, block_q, block_k, interpret)
    return out, (res, q.shape, k.shape)


def _bwd(causal, window, q_offset, block_q, block_k, interpret, saved, do):
    (qm, km, vm, o, lse), q_shape, k_shape = saved
    B, Sq, H, hd = q_shape
    Skv = k_shape[1]
    dom = _pad_to(jnp.moveaxis(do, 2, 1), 2, block_q)
    dq, dk, dv = _bwd_kernel(
        qm, km, vm, o, lse, dom,
        causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    dq = jnp.moveaxis(dq[:, :, :Sq, :], 1, 2)
    dk = jnp.moveaxis(dk[:, :, :Skv, :], 1, 2)
    dv = jnp.moveaxis(dv[:, :, :Skv, :], 1, 2)
    return dq, dk, dv


flash_attention.defvjp(_fwd, _bwd)
