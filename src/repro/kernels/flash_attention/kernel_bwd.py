"""Flash-attention backward as two Pallas TPU kernels.

Standard flash backward decomposition (Dao et al., adapted to the TPU's
sequential grid + VMEM scratch accumulation):

    D_t  = Σ_d do_t ⊙ o_t                              (precomputed outside)
    p_ij = exp(q_i·k_jᵀ·scale − lse_i)                 (recomputed per tile)
    dv_j = Σ_i p_ijᵀ · do_i
    ds   = p ⊙ (do·vᵀ − D) · scale
    dk_j = Σ_i ds_ijᵀ · q_i
    dq_i = Σ_j ds_ij · k_j

Kernel A (`_dkdv_kernel`): grid (B, KVH, n_kv, n_q·G) — the innermost dim
walks (q-block × group) sequentially, accumulating the (block_k, hd) dk/dv
tiles in VMEM scratch; GQA is handled by folding the group index into the
inner dim so each KV head's gradient sums over its G query heads without
ever materializing repeated KV.

Kernel B (`_dq_kernel`): grid (B, H, n_q, n_kv) — accumulates dq over kv
blocks, mirroring the forward's schedule. Fully-masked tiles are skipped
with ``pl.when`` in both kernels (same 2× causal saving as the forward).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp
import numpy as np


def _tile_mask(q_start, k_start, block_q, block_k, causal, window):
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window:
        mask &= q_pos - k_pos < window
    return mask


def _tile_live(q_start, k_start, block_q, block_k, causal, window):
    live = jnp.bool_(True)
    if causal:
        live &= q_start + block_q - 1 >= k_start
    if window:
        live &= k_start + block_k - 1 > q_start - window
    return live


def _dkdv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
    dk_ref, dv_ref,
    dk_scr, dv_scr,
    *,
    sm_scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    n_inner: int,
    q_offset: int,
    n_q: int,
):
    it = pl.program_id(3)            # folded (group, q-block) index
    qi = it % n_q

    @pl.when(it == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    kj = pl.program_id(2)
    q_start = q_offset + qi * block_q
    k_start = kj * block_k

    @pl.when(_tile_live(q_start, k_start, block_q, block_k, causal, window))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)        # (bq, hd)
        lse = lse_ref[0, 0]                          # (bq, 1)
        delta = delta_ref[0, 0]                      # (bq, 1)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        mask = _tile_mask(q_start, k_start, block_q, block_k, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)   # (bq, bk)

        dv_scr[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * sm_scale
        dk_scr[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(it == n_inner - 1)
    def _fin():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
    dq_ref,
    dq_scr,
    *,
    sm_scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    n_kv: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q_start = q_offset + qi * block_q
    k_start = kj * block_k

    @pl.when(_tile_live(q_start, k_start, block_q, block_k, causal, window))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        mask = _tile_mask(q_start, k_start, block_q, block_k, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * sm_scale
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(kj == n_kv - 1)
    def _fin():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def flash_attention_bwd(
    q: jax.Array,      # (B, H, Sq, hd)
    k: jax.Array,      # (B, KVH, Skv, hd)
    v: jax.Array,
    o: jax.Array,      # (B, H, Sq, hd)   forward output
    lse: jax.Array,    # (B, H, Sq, 1)    forward log-sum-exp
    do: jax.Array,     # (B, H, Sq, hd)
    *,
    causal: bool = True,
    window: int = 0,
    sm_scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    q_offset: int = 0,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (dq, dk, dv) with dk/dv in the (B, KVH, Skv, hd) GQA layout."""
    B, H, Sq, hd = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    G = H // KVH
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    n_q, n_kv = Sq // block_q, Skv // block_k
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(hd)

    delta = jnp.sum(
        do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1, keepdims=True
    )  # (B, H, Sq, 1)

    # ---- kernel A: dk, dv (grid inner dim folds group × q-block) ----------
    n_inner = G * n_q
    dkdv = functools.partial(
        _dkdv_kernel, sm_scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_inner=n_inner,
        q_offset=q_offset, n_q=n_q,
    )
    # query-head index for a folded inner step: h = kvh * G + it // n_q
    qmap = lambda b, kvh, kj, it: (b, kvh * G + it // n_q, it % n_q, 0)
    dk, dv = pl.pallas_call(
        dkdv,
        grid=(B, KVH, n_kv, n_inner),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), qmap),                              # q
            pl.BlockSpec((1, 1, block_k, hd), lambda b, kvh, kj, it: (b, kvh, kj, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, kvh, kj, it: (b, kvh, kj, 0)),
            pl.BlockSpec((1, 1, block_q, hd), qmap),                              # do
            pl.BlockSpec((1, 1, block_q, 1), qmap),                               # lse
            pl.BlockSpec((1, 1, block_q, 1), qmap),                               # delta
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, hd), lambda b, kvh, kj, it: (b, kvh, kj, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, kvh, kj, it: (b, kvh, kj, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KVH, Skv, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KVH, Skv, hd), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, hd), jnp.float32),
            pltpu.VMEM((block_k, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # ---- kernel B: dq ------------------------------------------------------
    dqk = functools.partial(
        _dq_kernel, sm_scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv=n_kv, q_offset=q_offset,
    )
    dq = pl.pallas_call(
        dqk,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, kj: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, qi, kj: (b, h // G, kj, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, qi, kj: (b, h // G, kj, 0)),
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, kj: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_q, 1), lambda b, h, qi, kj: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_q, 1), lambda b, h, qi, kj: (b, h, qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, qi, kj: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)
