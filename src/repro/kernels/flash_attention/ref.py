"""Pure-jnp oracle for the flash-attention kernel.

Materializes the full (Sq × Skv) score matrix — O(S²) memory, fine at test
shapes, exact math for allclose sweeps. Supports causal masking, sliding
windows, and grouped-query attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(
    q: jax.Array,          # (B, Sq, H, hd)
    k: jax.Array,          # (B, Skv, KVH, hd)
    v: jax.Array,          # (B, Skv, KVH, hd)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    sm_scale: float | None = None,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(hd)

    qg = q.reshape(B, Sq, KVH, G, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale

    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window:
        mask &= q_pos[:, None] - kv_pos[None, :] < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)

    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)
