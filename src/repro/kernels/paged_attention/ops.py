"""Public wrapper around the paged decode-attention Pallas kernel.

Decode attention is inference-only — no custom_vjp, no padding gymnastics:
the pool/page layout is already block-aligned by construction (the engine
allocates whole pages), so the wrapper only validates the layout contract
and dispatches to the kernel. ``interpret=True`` runs the same kernel
through the Pallas interpreter on CPU (the CI smoke path); backends with
neither fall back to :func:`paged_attention_ref` at the model layer
(``models/layers.py``), which is bit-compared against the kernel in
``tests/test_kernels.py``.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.paged_attention.kernel import paged_attention as _kernel


def paged_decode_attention(
    q: jax.Array,            # (B, H, hd)
    k_pages: jax.Array,      # (P, page_size, KVH, hd)
    v_pages: jax.Array,      # (P, page_size, KVH, hd)
    block_table: jax.Array,  # (B, max_blocks) int32
    seq_lens: jax.Array,     # (B,) int32
    *,
    sm_scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    B, H, hd = q.shape
    P, page_size, KVH, hd_k = k_pages.shape
    assert hd == hd_k, (hd, hd_k)
    assert H % KVH == 0, (H, KVH)
    assert v_pages.shape == k_pages.shape, (v_pages.shape, k_pages.shape)
    assert block_table.shape[0] == B and seq_lens.shape == (B,), (
        block_table.shape, seq_lens.shape, B,
    )
    return _kernel(
        q, k_pages, v_pages, block_table, seq_lens,
        sm_scale=sm_scale, interpret=interpret,
    )
