"""Paged decode attention as a Pallas TPU kernel.

One decode token per sequence attends over that sequence's pages of a
shared KV block pool (vLLM-style paged KV cache). The physical page for
grid step (b, h, j) is read from the *scalar-prefetched* block table
inside the k/v BlockSpec index maps — ``pltpu.PrefetchScalarGridSpec``
makes ``block_table``/``seq_lens`` available before the kernel body runs,
so the DMA engine fetches exactly the pages the sequence occupies and the
HBM traffic is O(seq_len), not O(max_context) like the dense-cache decode
path.

Grid: (B, KVH, max_blocks) with the page axis innermost — a TPU Pallas
grid executes sequentially per core, so the online-softmax state (m, l,
acc) for the (G = H/KVH)-head query group lives in VMEM scratch and is
carried across pages, exactly like the prefill flash kernel. Pages past
``seq_lens[b]`` are skipped with ``pl.when`` (unassigned table entries
are clamped to page 0 in the index map; the mask keeps them out of the
math). A dead lane (seq_len 0) runs no page and finalizes to a zero
vector — deterministic, and never read by the engine.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def _paged_kernel(
    bt_ref, sl_ref,                 # scalar-prefetch: block table, seq lens
    q_ref, k_ref, v_ref,            # VMEM tiles
    o_ref,                          # output tile
    m_scr, l_scr, acc_scr,          # VMEM scratch carried over the page axis
    *,
    sm_scale: float,
    page_size: int,
    n_blocks: int,
):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    seq_len = sl_ref[b]
    base = j * page_size

    @pl.when(base < seq_len)
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32)        # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # (ps, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                                      # (G, ps)
        k_pos = base + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, dimension=1
        )
        s = jnp.where(k_pos < seq_len, s, NEG_INF)

        m_prev = m_scr[...]                               # (G, 1)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                            # (G, ps)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )                                                 # (G, hd)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_attention(
    q: jax.Array,            # (B, H, hd)
    k_pages: jax.Array,      # (P, page_size, KVH, hd)
    v_pages: jax.Array,      # (P, page_size, KVH, hd)
    block_table: jax.Array,  # (B, max_blocks) int32
    seq_lens: jax.Array,     # (B,) int32
    *,
    sm_scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Paged decode attention over a shared block pool. Returns (B, H, hd)."""
    B, H, hd = q.shape
    page_size, KVH = k_pages.shape[1], k_pages.shape[2]
    n_blocks = block_table.shape[1]
    G = H // KVH
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(hd)

    q4 = q.reshape(B, KVH, G, hd)
    kernel = functools.partial(
        _paged_kernel,
        sm_scale=scale,
        page_size=page_size,
        n_blocks=n_blocks,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, n_blocks),
        in_specs=[
            pl.BlockSpec(
                (1, 1, G, hd), lambda b, h, j, bt, sl: (b, h, 0, 0)
            ),
            pl.BlockSpec(
                (1, page_size, 1, hd),
                lambda b, h, j, bt, sl: (jnp.maximum(bt[b, j], 0), 0, h, 0),
            ),
            pl.BlockSpec(
                (1, page_size, 1, hd),
                lambda b, h, j, bt, sl: (jnp.maximum(bt[b, j], 0), 0, h, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, hd), lambda b, h, j, bt, sl: (b, h, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      q4, k_pages, v_pages)
    return out.reshape(B, H, hd)
