"""Pure-jnp oracle for the paged decode-attention kernel.

Gathers each sequence's pages through its block table and runs exact
masked softmax attention over the gathered positions — O(max_blocks ·
page_size) memory per sequence, fine at test shapes, exact math for the
allclose sweeps AND the model-side jnp fallback (``models/layers.py``
calls this directly on backends without Pallas).

Layout contract (shared with kernel.py / ops.py):

* ``q``           — (B, H, hd): one decode token per sequence, head-major
  after the model's (B, 1, H, hd) squeeze;
* ``k_pages``/``v_pages`` — (P, page_size, KVH, hd): the shared block
  pool; a page holds ``page_size`` consecutive token positions of ONE
  sequence;
* ``block_table`` — (B, max_blocks) int32: ``block_table[b, j]`` is the
  pool page holding positions ``[j·page_size, (j+1)·page_size)`` of
  sequence ``b``; ``-1`` = unassigned (clamped to page 0 and masked);
* ``seq_lens``    — (B,) int32: valid positions per sequence (0 = dead
  lane; its output is a deterministic zero-information vector that the
  engine never reads).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def paged_attention_ref(
    q: jax.Array,            # (B, H, hd)
    k_pages: jax.Array,      # (P, page_size, KVH, hd)
    v_pages: jax.Array,      # (P, page_size, KVH, hd)
    block_table: jax.Array,  # (B, max_blocks) int32
    seq_lens: jax.Array,     # (B,) int32
    *,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    B, H, hd = q.shape
    page_size, KVH = k_pages.shape[1], k_pages.shape[2]
    max_blocks = block_table.shape[1]
    G = H // KVH
    T = max_blocks * page_size
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(hd)

    tbl = jnp.maximum(block_table, 0)                       # clamp -1
    k = jnp.take(k_pages, tbl, axis=0)                      # (B, nb, ps, KVH, hd)
    v = jnp.take(v_pages, tbl, axis=0)
    k = k.reshape(B, T, KVH, hd)
    v = v.reshape(B, T, KVH, hd)

    qg = q.reshape(B, KVH, G, hd).astype(jnp.float32)
    s = jnp.einsum(
        "bkgd,btkd->bkgt", qg, k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale                                               # (B, KVH, G, T)
    kv_pos = jnp.arange(T, dtype=jnp.int32)
    valid = kv_pos[None, :] < seq_lens[:, None]             # (B, T)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    # dead lanes (seq_len 0): softmax over an all-masked row is uniform, so
    # zero the output explicitly to match the kernel's finalize semantics
    o = jnp.where(seq_lens[:, None, None, None] > 0, o, 0.0)
    return o.reshape(B, H, hd).astype(q.dtype)
