"""Chunked selective scan (Mamba recurrence) as a Pallas TPU kernel.

GPU Mamba fuses the whole scan into one kernel with warp shuffles; the TPU
adaptation chunks the sequence instead: the grid's innermost dim walks
chunks SEQUENTIALLY (TPU grid order guarantee) carrying the (block_inner, N)
state in VMEM scratch, and the per-chunk work is dense VPU/MXU-friendly
elementwise math over (chunk, block_inner) tiles. The ``inner`` channel dim
is blocked in the middle grid dim so arbitrary expand×d_model fits VMEM.

Inputs are the post-projection selective params (ops.py batches the
projections as big matmuls — same split as the jnp path in models/ssm.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp


def _ssm_kernel(
    u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, h0_ref,
    y_ref, hout_ref,
    h_scr,
    *,
    chunk: int,
    n_chunks: int,
):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        h_scr[...] = h0_ref[0, :, :].astype(jnp.float32)

    u = u_ref[0, :, :].astype(jnp.float32)      # (chunk, bi)
    dt = dt_ref[0, :, :].astype(jnp.float32)    # (chunk, bi)
    b = b_ref[0, :, :].astype(jnp.float32)      # (chunk, N)
    c = c_ref[0, :, :].astype(jnp.float32)      # (chunk, N)
    a = a_ref[...].astype(jnp.float32)          # (bi, N)
    d = d_ref[...].astype(jnp.float32)          # (1, bi)

    def step(t, carry):
        h = carry                                # (bi, N)
        da = jnp.exp(dt[t, :][:, None] * a)      # (bi, N)
        db = dt[t, :][:, None] * b[t, :][None, :]
        h = da * h + db * u[t, :][:, None]
        y_t = jnp.sum(h * c[t, :][None, :], axis=1) + d[0] * u[t, :]
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(cj == n_chunks - 1)
    def _fin():
        hout_ref[0, :, :] = h_scr[...]


def ssm_scan(
    u: jax.Array,        # (B, S, inner)
    dt: jax.Array,       # (B, S, inner)
    B_: jax.Array,       # (B, S, N)
    C_: jax.Array,       # (B, S, N)
    A: jax.Array,        # (inner, N)
    D: jax.Array,        # (inner,)
    h0: Optional[jax.Array] = None,
    *,
    chunk: int = 64,
    block_inner: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,inner), h_final (B,inner,N) f32)."""
    Bb, S, inner = u.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    block_inner = min(block_inner, inner)
    assert S % chunk == 0 and inner % block_inner == 0
    n_chunks = S // chunk
    n_blk = inner // block_inner
    if h0 is None:
        h0 = jnp.zeros((Bb, inner, N), jnp.float32)
    d2 = D.reshape(1, inner)

    kernel = functools.partial(_ssm_kernel, chunk=chunk, n_chunks=n_chunks)
    grid = (Bb, n_blk, n_chunks)
    y, h_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_inner), lambda b, i, c: (b, c, i)),  # u
            pl.BlockSpec((1, chunk, block_inner), lambda b, i, c: (b, c, i)),  # dt
            pl.BlockSpec((1, chunk, N), lambda b, i, c: (b, c, 0)),            # B
            pl.BlockSpec((1, chunk, N), lambda b, i, c: (b, c, 0)),            # C
            pl.BlockSpec((block_inner, N), lambda b, i, c: (i, 0)),            # A
            pl.BlockSpec((1, block_inner), lambda b, i, c: (0, i)),            # D
            pl.BlockSpec((1, block_inner, N), lambda b, i, c: (b, i, 0)),      # h0
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_inner), lambda b, i, c: (b, c, i)),  # y
            pl.BlockSpec((1, block_inner, N), lambda b, i, c: (b, i, 0)),      # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, inner), u.dtype),
            jax.ShapeDtypeStruct((Bb, inner, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_inner, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, B_, C_, A, d2, h0)
    return y, h_out
