"""Public wrapper for the selective-scan kernel: does the MXU-friendly
selective-parameter projections as plain jnp matmuls, calls the Pallas
recurrence, and pads ragged shapes to block multiples."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssm_scan as _kernel


def ssm_scan(
    u, dt, B_, C_, A, D, h0=None, *, chunk: int = 64, block_inner: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    Bb, S, inner = u.shape
    pad_s = (-S) % chunk
    if pad_s:
        widths3 = ((0, 0), (0, pad_s), (0, 0))
        u = jnp.pad(u, widths3)
        # pad dt with zeros -> exp(0·A)=1, db=0: state passes through unchanged
        dt = jnp.pad(dt, widths3)
        B_ = jnp.pad(B_, widths3)
        C_ = jnp.pad(C_, widths3)
    bi = min(block_inner, inner)
    while inner % bi:
        bi //= 2
    y, h = _kernel(
        u, dt, B_, C_, A, D, h0, chunk=chunk, block_inner=max(bi, 1),
        interpret=interpret,
    )
    return y[:, :S, :], h
