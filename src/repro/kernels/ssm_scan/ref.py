"""Pure-jnp oracle for the chunked selective-scan (Mamba) kernel.

Sequential reference recurrence, f32 state:
    h_t = exp(dt_t ⊙ A) ⊙ h_{t-1} + (dt_t ⊙ B_t) · u_t
    y_t = C_t · h_t + D ⊙ u_t
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssm_scan_ref(
    u: jax.Array,        # (B, S, inner)
    dt: jax.Array,       # (B, S, inner)
    B_: jax.Array,       # (B, S, N)
    C_: jax.Array,       # (B, S, N)
    A: jax.Array,        # (inner, N)  negative decay rates
    D: jax.Array,        # (inner,)
    h0: Optional[jax.Array] = None,   # (B, inner, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,inner) in u.dtype, h_final (B,inner,N) f32)."""
    Bb, S, inner = u.shape
    N = A.shape[1]
    Af = A.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bb, inner, N), jnp.float32)

    def step(h, xs):
        ut, dtt, bt, ct = xs
        da = jnp.exp(dtt.astype(jnp.float32)[..., None] * Af)      # (B,inner,N)
        db = dtt.astype(jnp.float32)[..., None] * bt.astype(jnp.float32)[:, None, :]
        h = da * h + db * ut.astype(jnp.float32)[..., None]
        y = jnp.einsum("bin,bn->bi", h, ct.astype(jnp.float32))
        y = y + D.astype(jnp.float32) * ut.astype(jnp.float32)
        return h, y

    h, ys = jax.lax.scan(
        step, h0,
        (u.swapaxes(0, 1), dt.swapaxes(0, 1), B_.swapaxes(0, 1), C_.swapaxes(0, 1)),
    )
    return ys.swapaxes(0, 1).astype(u.dtype), h
