"""Public wrapper for the chunkwise mLSTM kernel: pads ragged sequence
lengths (gate pads use f̃=0, ĩ=-inf so padded steps are no-ops) and exposes
the (B, S, H, hd) model layout."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.mlstm.kernel import mlstm_chunkwise as _kernel

NEG = -1e30


def mlstm(
    q: jax.Array,       # (B, S, H, hd)
    k: jax.Array,
    v: jax.Array,
    gates: jax.Array,   # (B, S, H, 2)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array, jax.Array]]:
    B, S, H, hd = q.shape
    pad = (-S) % min(chunk, S)
    qm = jnp.moveaxis(q, 2, 1)
    km = jnp.moveaxis(k, 2, 1)
    vm = jnp.moveaxis(v, 2, 1)
    gm = jnp.moveaxis(gates, 2, 1)
    if pad:
        w4 = ((0, 0), (0, 0), (0, pad), (0, 0))
        qm, km, vm = jnp.pad(qm, w4), jnp.pad(km, w4), jnp.pad(vm, w4)
        gpad = jnp.concatenate(
            [jnp.full((B, H, pad, 1), NEG, gm.dtype), jnp.zeros((B, H, pad, 1), gm.dtype)],
            axis=-1,
        )
        gm = jnp.concatenate([gm, gpad], axis=2)
    h, state = _kernel(qm, km, vm, gm, chunk=min(chunk, S), interpret=interpret)
    return jnp.moveaxis(h[:, :, :S], 1, 2), state
