"""Sequential oracle for the mLSTM matrix-memory recurrence (xLSTM).

Per head, with log-space gate pre-activations ĩ_t, f̃_t and stabilizer m:

    m_t = max(f̃_t + m_{t-1}, ĩ_t)
    i'  = exp(ĩ_t − m_t);  f' = exp(f̃_t + m_{t-1} − m_t)
    C_t = f'·C_{t-1} + i'·v_t (k_t/√hd)ᵀ
    n_t = f'·n_{t-1} + i'·(k_t/√hd)
    h_t = (C_t q_t) / max(|n_t·q_t|, 1)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def mlstm_ref(
    q: jax.Array,       # (B, H, S, hd)
    k: jax.Array,
    v: jax.Array,
    gates: jax.Array,   # (B, H, S, 2): [:, :, :, 0]=ĩ, [:, :, :, 1]=f̃
    state: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array, jax.Array]]:
    B, H, S, hd = q.shape
    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.zeros((B, H), jnp.float32)
    else:
        C0, n0, m0 = state

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, gt = xs
        it, ft = gt[..., 0].astype(jnp.float32), gt[..., 1].astype(jnp.float32)
        m_new = jnp.maximum(ft + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(ft + m - m_new)
        kf = kt.astype(jnp.float32) / np.sqrt(hd)
        C = f_[..., None, None] * C + i_[..., None, None] * (
            vt.astype(jnp.float32)[..., :, None] * kf[..., None, :]
        )
        n = f_[..., None] * n + i_[..., None] * kf
        qf = qt.astype(jnp.float32)
        num = jnp.einsum("bhij,bhj->bhi", C, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qf)), 1.0)
        return (C, n, m_new), num / den[..., None]

    (C, n, m), hs = jax.lax.scan(
        step, (C0, n0, m0),
        (q.swapaxes(0, 2).swapaxes(1, 2), k.swapaxes(0, 2).swapaxes(1, 2),
         v.swapaxes(0, 2).swapaxes(1, 2), gates.swapaxes(0, 2).swapaxes(1, 2)),
    )
    # hs: (S, B, H, hd) -> (B, H, S, hd)
    h = jnp.moveaxis(hs, 0, 2)
    return h.astype(q.dtype), (C, n, m)
