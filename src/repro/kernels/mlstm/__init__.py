from repro.kernels.mlstm.kernel import mlstm_chunkwise
from repro.kernels.mlstm.ops import mlstm
from repro.kernels.mlstm.ref import mlstm_ref

__all__ = ["mlstm", "mlstm_chunkwise", "mlstm_ref"]
