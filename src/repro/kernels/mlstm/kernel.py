"""Chunkwise-parallel mLSTM as a Pallas TPU kernel.

The sequential recurrence (see ref.py) admits an exact chunkwise
decomposition — the insight that makes the xLSTM matrix memory trainable on
matmul hardware. Within a chunk (b = cumsum(f̃), inclusive):

    m_t   = b_t + M_t,   M_t = max(m_in, runmax_{s≤t}(ĩ_s − b_s))
    D_ts  = exp(ĩ_s − b_s − M_t)  for s ≤ t, else 0        (c × c decay)
    num_t = (q K̂ᵀ ⊙ D) V  +  exp(m_in − M_t) · q · C_in    (all matmuls)
    n_t   = D K̂  +  exp(m_in − M_t) · n_in
    h_t   = num_t / max(|n_t · q_t|, 1)

with K̂ = K/√hd; chunk-end carries use the same weights at t = c. Every
term is a (chunk × chunk) or (chunk × hd) matmul — MXU work — while the
inter-chunk state (C: hd×hd, n: hd, m: scalar) is carried in VMEM scratch
across the sequential innermost grid dim, exactly like the flash-attention
kernel carries its online-softmax state.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp
import numpy as np


def _mlstm_kernel(
    q_ref, k_ref, v_ref, g_ref,
    h_ref, cout_ref, nout_ref, mout_ref,
    c_scr, n_scr, m_scr,
    *,
    chunk: int,
    n_chunks: int,
    hd: int,
):
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.zeros_like(m_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # (c, hd)
    k = k_ref[0, 0].astype(jnp.float32) / np.sqrt(hd)
    v = v_ref[0, 0].astype(jnp.float32)
    ig = g_ref[0, 0, :, 0].astype(jnp.float32)   # (c,)
    fg = g_ref[0, 0, :, 1].astype(jnp.float32)

    C_in = c_scr[...]                            # (hd, hd)  Σ v kᵀ layout
    n_in = n_scr[...]                            # (1, hd)
    m_in = m_scr[0, 0]

    b = jnp.cumsum(fg)                           # (c,) inclusive log-decay
    a_shift = ig - b                             # ĩ_s − b_s
    M = jnp.maximum(m_in, jax.lax.cummax(a_shift, axis=0))  # (c,)

    # decay matrix D_ts = exp(ĩ_s − b_s − M_t) · [s ≤ t]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    logd = a_shift[None, :] - M[:, None]
    D = jnp.where(s_idx <= t_idx, jnp.exp(logd), 0.0)       # (c, c)

    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c, c)
    num = jax.lax.dot_general(qk * D, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (c, hd)
    carry_w = jnp.exp(m_in - M)                               # (c,)
    num += carry_w[:, None] * jax.lax.dot_general(
        q, C_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # q · C_inᵀ? C layout: C[d_v, d_k]; num_t[i] = Σ_j C[i,j] q[j] -> q @ C^T

    n_t = jax.lax.dot_general(D, k, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (c, hd)
    n_t += carry_w[:, None] * n_in                            # (c, hd)

    den = jnp.maximum(jnp.abs(jnp.sum(n_t * q, axis=1, keepdims=True)), 1.0)
    h_ref[0, 0] = (num / den).astype(h_ref.dtype)

    # ---- chunk-end carries ----
    # m_out = b_c + M_c  ⇒  carry weights exp(b_c − b_s + ĩ_s − m_out)
    # simplify to exp(ĩ_s − b_s − M_c):
    m_out = b[-1] + M[-1]
    w = jnp.exp(a_shift - M[-1])
    c_new = jax.lax.dot_general(
        v * w[:, None], k, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                          # (hd_v, hd_k)
    carry_scale = jnp.exp(m_in - M[-1])
    c_scr[...] = carry_scale * C_in + c_new
    n_scr[...] = carry_scale * n_in + jnp.sum(k * w[:, None], axis=0, keepdims=True)
    m_scr[0, 0] = m_out

    @pl.when(cj == n_chunks - 1)
    def _fin():
        cout_ref[0, 0] = c_scr[...]
        nout_ref[0, 0] = n_scr[0, :]
        mout_ref[0, 0] = m_scr[0, 0]


def mlstm_chunkwise(
    q: jax.Array,       # (B, H, S, hd)
    k: jax.Array,
    v: jax.Array,
    gates: jax.Array,   # (B, H, S, 2)
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array, jax.Array]]:
    B, H, S, hd = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    n_chunks = S // chunk
    kernel = functools.partial(
        _mlstm_kernel, chunk=chunk, n_chunks=n_chunks, hd=hd
    )
    grid = (B, H, n_chunks)
    h, C, n, m = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, chunk, hd), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, chunk, 2), lambda b, hh, c: (b, hh, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, hh, c: (b, hh, 0, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, hh, c: (b, hh, 0)),
            pl.BlockSpec((1, 1), lambda b, hh, c: (b, hh)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, gates)
    return h, (C, n, m)
