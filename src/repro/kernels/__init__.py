"""Pallas TPU kernels for the model zoo's compute hot spots.

flash_attention/  blockwise online-softmax attention (causal, SWA, GQA)
paged_attention/  decode attention over a paged KV block pool (serving)
ssm_scan/         chunked Mamba selective scan
mlstm/            chunkwise-parallel xLSTM matrix-memory cell

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (public
jit-able wrapper), ref.py (pure-jnp oracle). Validated with interpret=True
on CPU; the TPU target uses the same BlockSpecs with VMEM tiling.
"""
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.mlstm import mlstm, mlstm_chunkwise, mlstm_ref
from repro.kernels.paged_attention import (
    paged_attention_ref,
    paged_decode_attention,
)
from repro.kernels.ssm_scan import ssm_scan, ssm_scan_ref

__all__ = [
    "attention_ref", "flash_attention",
    "mlstm", "mlstm_chunkwise", "mlstm_ref",
    "paged_attention_ref", "paged_decode_attention",
    "ssm_scan", "ssm_scan_ref",
]
