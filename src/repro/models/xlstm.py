"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Follows arXiv:2405.04517. The mLSTM cell keeps a per-head (hd × hd) matrix
memory C, a normalizer n, and a max-state m for numerically-stable
exponential gating:

    i_t = exp(ĩ_t),  f_t = exp(f̃_t)          (stabilized via m_t)
    C_t = f C_{t-1} + i v_t k_tᵀ,   n_t = f n_{t-1} + i k_t
    h_t = o ⊙ (C_t q_t) / max(|n_tᵀ q_t|, 1)

The recurrence is chunked like the Mamba scan (projections batched per
chunk, the sequential part carries only (B, H, hd, hd)). The layer stack is
arranged as ``groups × (slstm_every-1 mLSTM + 1 sLSTM)`` super-blocks so
both block kinds scan over layers (see transformer.py).

Decode state per mLSTM layer: {"C": (B,H,hd,hd), "n": (B,H,hd), "m": (B,H)};
per sLSTM layer: {"c","n","h","m": (B,d)} — constant per token, which makes
xLSTM a ``long_500k``-capable arch.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.models import common
from repro.models.common import ParamSpec


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mdims(cfg: ModelConfig) -> Tuple[int, int, int]:
    H = cfg.num_heads
    inner = 2 * cfg.d_model  # up-projection factor 2 (paper's mLSTM block)
    hd = inner // H
    return H, inner, hd


def mlstm_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    H, inner, hd = _mdims(cfg)
    return {
        "up_proj": ParamSpec((d, 2 * inner), ("embed", "ssm_inner")),
        "wq": ParamSpec((inner, inner), ("ssm_inner", "q_dim")),
        "wk": ParamSpec((inner, inner), ("ssm_inner", "q_dim")),
        "wv": ParamSpec((inner, inner), ("ssm_inner", "q_dim")),
        "w_if": ParamSpec((inner, 2 * H), ("ssm_inner", None)),  # i,f gate pre-acts
        "b_if": ParamSpec((2 * H,), (None,), init="zeros"),
        "down_proj": ParamSpec((inner, d), ("ssm_inner", "embed")),
    }


def mlstm_state_spec(cfg: ModelConfig, batch: int) -> Dict[str, ParamSpec]:
    H, _, hd = _mdims(cfg)
    return {
        "C": ParamSpec((batch, H, hd, hd), ("batch", "heads", "head_dim", None), init="zeros"),
        "n": ParamSpec((batch, H, hd), ("batch", "heads", "head_dim"), init="zeros"),
        "m": ParamSpec((batch, H), ("batch", "heads"), init="zeros"),
    }


def _mlstm_scan(
    q: jax.Array, k: jax.Array, v: jax.Array, gates: jax.Array, state: Dict, chunk: int
) -> Tuple[jax.Array, Dict]:
    """Chunkwise-parallel mLSTM (same exact decomposition as the Pallas
    kernel in repro.kernels.mlstm — see its docstring for the math).

    q/k/v: (B,S,H,hd); gates: (B,S,2H). Returns (h (B,S,H,hd), state).

    Why chunkwise and not a per-step scan: differentiating an S-step scan
    whose carry is the (B,H,hd,hd) matrix memory makes JAX save S copies of
    C for the backward pass — terabytes at S=4096. The chunkwise form
    carries C only at the S/chunk boundaries and does all intra-chunk work
    as (chunk×chunk)/(chunk×hd) matmuls, with jax.checkpoint recomputing
    inside each chunk during backward.
    """
    B, S, H, hd = q.shape
    C0 = state["C"].astype(jnp.float32)
    n0 = state["n"].astype(jnp.float32)
    m0 = state["m"].astype(jnp.float32)

    chunk = max(1, min(chunk, S))
    if S % chunk:
        chunk = 1
    n_chunks = S // chunk

    def to_chunks(x):  # (B,S,...) -> (n_chunks, B, chunk, ...)
        return x.reshape(B, n_chunks, chunk, *x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, gc = map(to_chunks, (q, k, v, gates))
    t_idx = jnp.arange(chunk)[:, None]
    s_idx = jnp.arange(chunk)[None, :]
    tri = s_idx <= t_idx  # (c, c)

    @jax.checkpoint
    def chunk_step(carry, xs):
        C_in, n_in, m_in = carry                     # (B,H,hd,hd),(B,H,hd),(B,H)
        qt, kt, vt, gt = xs                          # (B,c,H,hd) ×3, (B,c,2H)
        qf = qt.astype(jnp.float32)
        kf = kt.astype(jnp.float32) / np.sqrt(hd)
        vf = vt.astype(jnp.float32)
        ig = gt[..., :H].astype(jnp.float32)         # (B,c,H)
        fg = gt[..., H:].astype(jnp.float32)

        b = jnp.cumsum(fg, axis=1)                   # (B,c,H) inclusive
        a_shift = ig - b
        M = jnp.maximum(m_in[:, None, :], jax.lax.cummax(a_shift, axis=1))  # (B,c,H)

        # D_ts = exp(ĩ_s − b_s − M_t) for s ≤ t
        logd = a_shift[:, None, :, :] - M[:, :, None, :]          # (B,t,s,H)
        D = jnp.where(tri[None, :, :, None], jnp.exp(logd), 0.0)

        qk = jnp.einsum("bthd,bshd->btsh", qf, kf)               # (B,t,s,H)
        num = jnp.einsum("btsh,bshd->bthd", qk * D, vf)          # (B,c,H,hd)
        carry_w = jnp.exp(m_in[:, None, :] - M)                  # (B,c,H)
        num += carry_w[..., None] * jnp.einsum("bthd,bhed->bthe", qf, C_in)

        n_t = jnp.einsum("btsh,bshd->bthd", D, kf)
        n_t += carry_w[..., None] * n_in[:, None]
        den = jnp.maximum(jnp.abs(jnp.sum(n_t * qf, axis=-1)), 1.0)
        h = num / den[..., None]                                  # (B,c,H,hd)

        # chunk-end carries: weights exp(ĩ_s − b_s − M_c)
        M_c = M[:, -1, :]                                         # (B,H)
        w = jnp.exp(a_shift - M_c[:, None, :])                    # (B,c,H)
        C_new = jnp.einsum("bshd,bshe->bhde", vf * w[..., None], kf)
        cscale = jnp.exp(m_in - M_c)                              # (B,H)
        C_out = cscale[..., None, None] * C_in + C_new
        n_out = cscale[..., None] * n_in + jnp.sum(kf * w[..., None], axis=1)
        m_out = b[:, -1, :] + M_c
        return (C_out, n_out, m_out), h

    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, gc))
    h = hs.swapaxes(0, 1).reshape(B, S, H, hd)
    return h, {"C": C, "n": n, "m": m}


def mlstm_block(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    state: Optional[Dict] = None,
) -> Tuple[jax.Array, Dict]:
    """x: (B,S,d) -> (y (B,S,d), new state)."""
    B, S, d = x.shape
    H, inner, hd = _mdims(cfg)
    ct = jnp.dtype(cfg.dtype)
    if state is None:
        state = {
            "C": jnp.zeros((B, H, hd, hd), jnp.float32),
            "n": jnp.zeros((B, H, hd), jnp.float32),
            "m": jnp.zeros((B, H), jnp.float32),
        }
    up = common.dense(x, params["up_proj"], cfg.dtype)
    u, z = jnp.split(up, 2, axis=-1)  # (B,S,inner) ×2
    q = common.dense(u, params["wq"], cfg.dtype).reshape(B, S, H, hd)
    k = common.dense(u, params["wk"], cfg.dtype).reshape(B, S, H, hd)
    v = common.dense(u, params["wv"], cfg.dtype).reshape(B, S, H, hd)
    gates = common.dense(u, params["w_if"], "float32") + params["b_if"].astype(jnp.float32)
    h, new_state = _mlstm_scan(q, k, v, gates, state, cfg.ssm.chunk if cfg.ssm else 64)
    y = h.reshape(B, S, inner).astype(ct) * jax.nn.silu(z)
    return common.dense(y, params["down_proj"], cfg.dtype), new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    return {
        "w_gates": ParamSpec((d, 4 * d), ("embed", "ssm_inner")),  # z,i,f,o pre-acts
        "r_gates": ParamSpec((d, 4 * d), ("embed", "ssm_inner"), scale=0.5),
        "b_gates": ParamSpec((4 * d,), ("ssm_inner",), init="zeros"),
        "up_proj": ParamSpec((d, 2 * d), ("embed", "ffn")),
        "down_proj": ParamSpec((d, d), ("ffn", "embed")),
    }


def slstm_state_spec(cfg: ModelConfig, batch: int) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    return {
        "c": ParamSpec((batch, d), ("batch", "embed"), init="zeros"),
        "n": ParamSpec((batch, d), ("batch", "embed"), init="zeros"),
        "h": ParamSpec((batch, d), ("batch", "embed"), init="zeros"),
        "m": ParamSpec((batch, d), ("batch", "embed"), init="zeros"),
    }


def slstm_block(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    state: Optional[Dict] = None,
) -> Tuple[jax.Array, Dict]:
    """sLSTM with exponential gating and recurrent connections. x: (B,S,d)."""
    B, S, d = x.shape
    ct = jnp.dtype(cfg.dtype)
    if state is None:
        z = jnp.zeros((B, d), jnp.float32)
        state = {"c": z, "n": z, "h": z, "m": z}

    wx = common.dense(x, params["w_gates"], "float32") + params["b_gates"].astype(
        jnp.float32
    )  # (B,S,4d)

    def step(carry, wx_t):
        c, n, h, m = carry
        pre = wx_t + common.dense(h, params["r_gates"], "float32")
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        m_new = jnp.maximum(ft + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(ft + m - m_new)
        c = f_ * c + i_ * zt
        n = f_ * n + i_
        h = ot * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    # two-level scan: backward saves only chunk-boundary carries, not all S
    # per-step states (jax.checkpoint recomputes within a chunk)
    chunk = 64 if S % 64 == 0 else (S if S < 64 else 1)
    n_chunks = max(S // chunk, 1)
    wxc = wx.swapaxes(0, 1).reshape(n_chunks, chunk, B, 4 * d)

    @jax.checkpoint
    def chunk_step(carry, wx_chunk):
        carry, hs = jax.lax.scan(step, carry, wx_chunk)
        return carry, hs

    (c, n, h, m), hs = jax.lax.scan(
        chunk_step, (state["c"], state["n"], state["h"], state["m"]), wxc
    )
    y = hs.reshape(S, B, d).swapaxes(0, 1).astype(ct)  # (B,S,d)
    # position-wise up/down projection (GEGLU-style)
    u = common.dense(y, params["up_proj"], cfg.dtype)
    a, b = jnp.split(u, 2, axis=-1)
    out = common.dense(jax.nn.gelu(a) * b, params["down_proj"], cfg.dtype)
    return out, {"c": c, "n": n, "h": h, "m": m}
