"""Model zoo: 10 assigned architectures behind one functional API."""
from repro.models.transformer import RunOpts
from repro.models.zoo import Model, build_model, concrete_inputs, input_specs

__all__ = ["Model", "RunOpts", "build_model", "concrete_inputs", "input_specs"]
