"""Unified decoder/enc-dec model covering all 10 assigned architectures.

One parameter tree + three drivers:

* ``forward_train``  — full-sequence forward -> logits (training).
* ``prefill``        — full-sequence forward that also *builds* the KV /
                       SSM-state cache -> (last-position logits, cache).
* ``decode_step``    — one token against the cache -> (logits, cache).

Layers are stacked and driven by ``jax.lax.scan`` (configurable remat
policy), so the HLO stays O(1) in depth — essential for 64-layer archs in
the 512-device dry-run. Heterogeneous stacks (xLSTM's mLSTM/sLSTM pattern)
scan over *super-blocks* (groups).

Positional encoding is RoPE everywhere, including the Whisper backbone
(deviation from learned/sinusoidal embeddings, noted in DESIGN.md: the
assigned decode_32k shape exceeds Whisper's 448-token learned table).
Modality frontends (Whisper conv, InternViT) are stubs per the assignment:
``batch["frames"]`` / ``batch["patches"]`` carry precomputed embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import AttentionKind, BlockKind, ModelConfig
from repro.models import common, layers, moe, ssm, xlstm
from repro.models.common import ParamSpec


@dataclasses.dataclass(frozen=True)
class RunOpts:
    """Execution knobs (from ShardingLayout) that change HLO, not semantics."""

    attn_impl: str = "masked"      # masked | triangular
    q_chunk: int = 512
    kv_chunk: int = 1024
    remat: str = "full"            # none | full | dots
    scan_layers: bool = True
    # decode unrolls the layer loop: a scanned decode carries the whole
    # stacked KV cache through the while loop, and XLA-CPU float
    # normalization then keeps a second f32 copy of it (2x cache memory).
    # Unrolled, each layer's slice converts transiently. On TPU either works;
    # unrolled also lets the scheduler overlap per-layer collectives.
    decode_unroll: bool = True
    int8_kv_cache: bool = False
    constrain: Callable[[jax.Array, str], jax.Array] = staticmethod(
        lambda x, name: x
    )


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _norm_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    if cfg.family == "audio":  # whisper uses LayerNorm
        return layers.layernorm_spec(cfg.d_model)
    return layers.rmsnorm_spec(cfg.d_model)


def block_spec(cfg: ModelConfig) -> Dict[str, Any]:
    """Spec for ONE decoder block of this config's kind (unstacked)."""
    b = cfg.block
    spec: Dict[str, Any] = {"ln1": _norm_spec(cfg)}
    if b in (BlockKind.DENSE, BlockKind.ENCDEC):
        spec["attn"] = layers.attention_spec(cfg)
        spec["ln2"] = _norm_spec(cfg)
        spec["mlp"] = layers.mlp_spec(cfg)
        if b == BlockKind.ENCDEC:
            spec["ln_cross"] = _norm_spec(cfg)
            spec["cross"] = layers.attention_spec(cfg, cross=True)
    elif b == BlockKind.MOE:
        spec["attn"] = layers.attention_spec(cfg)
        spec["ln2"] = _norm_spec(cfg)
        spec["moe"] = moe.moe_spec(cfg)
    elif b == BlockKind.HYBRID_PARALLEL:
        spec["attn"] = layers.attention_spec(cfg)
        spec["mamba"] = ssm.mamba_spec(cfg)
        spec["fuse_attn"] = layers.rmsnorm_spec(cfg.d_model)
        spec["fuse_ssm"] = layers.rmsnorm_spec(cfg.d_model)
        spec["ln2"] = _norm_spec(cfg)
        spec["mlp"] = layers.mlp_spec(cfg)
    else:
        raise ValueError(b)
    return spec


def _xlstm_group_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, mlstm_per_group, has_slstm)."""
    if cfg.slstm_every:
        per = cfg.slstm_every
        assert cfg.num_layers % per == 0
        return cfg.num_layers // per, per - 1, 1
    return 1, cfg.num_layers, 0


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    spec: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "embed"), init="embed"),
        "final_norm": _norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = ParamSpec((d, cfg.vocab_size), ("embed", "vocab"))

    if cfg.block in (BlockKind.MLSTM, BlockKind.SLSTM):
        groups, m_per, has_s = _xlstm_group_layout(cfg)
        g: Dict[str, Any] = {
            "mlstm": common.stacked(
                {"block": xlstm.mlstm_spec(cfg), "ln": layers.rmsnorm_spec(d)}, m_per
            )
        }
        if has_s:
            g["slstm"] = {"block": xlstm.slstm_spec(cfg), "ln": layers.rmsnorm_spec(d)}
        spec["groups"] = common.stacked(g, groups, axis_name="groups")
    else:
        spec["blocks"] = common.stacked(block_spec(cfg), cfg.num_layers)

    if cfg.encoder_layers:  # whisper encoder (self-attn only, non-causal)
        enc_block = {
            "ln1": _norm_spec(cfg),
            "attn": layers.attention_spec(cfg),
            "ln2": _norm_spec(cfg),
            "mlp": layers.mlp_spec(cfg),
        }
        spec["encoder"] = {
            "blocks": common.stacked(enc_block, cfg.encoder_layers),
            "final_norm": _norm_spec(cfg),
        }
    if cfg.vision_tokens:  # internvl stub projector
        spec["vision_proj"] = ParamSpec((cfg.vision_width, d), ("vit_embed", "embed"))
    return spec


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------

def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """Ring-buffer caches for SWA archs; +prefix for VLM prefixes. Rounded
    up to a multiple of 16 so the cache seq dim always shards over the
    model mesh axis (an unshardable 33793-slot VLM cache is 16× the HBM)."""
    n = seq_len + (cfg.vision_tokens if cfg.vision_tokens else 0)
    if cfg.attention == AttentionKind.SLIDING and cfg.window:
        n = min(n, cfg.window)
    return -(-n // 16) * 16


def cache_specs(
    cfg: ModelConfig, batch: int, seq_len: int, int8: bool = False
) -> Dict[str, Any]:
    T = cache_len_for(cfg, seq_len)
    if cfg.block in (BlockKind.MLSTM, BlockKind.SLSTM):
        groups, m_per, has_s = _xlstm_group_layout(cfg)
        g: Dict[str, Any] = {
            "mlstm": common.stacked(xlstm.mlstm_state_spec(cfg, batch), m_per)
        }
        if has_s:
            g["slstm"] = xlstm.slstm_state_spec(cfg, batch)
        return {"groups": common.stacked(g, groups, axis_name="groups")}

    one: Dict[str, Any] = {}
    if cfg.attention != AttentionKind.NONE:
        one.update(layers.make_cache_specs(cfg, batch, T, int8=int8))
    if cfg.block == BlockKind.HYBRID_PARALLEL:
        one["ssm"] = ssm.init_state(cfg, batch)
    if cfg.block == BlockKind.MOE:
        one["moe_load"] = moe.moe_load_spec(cfg, batch)
    out: Dict[str, Any] = {"blocks": common.stacked(one, cfg.num_layers)}
    if cfg.encoder_layers:
        out["memory"] = ParamSpec(
            (batch, cfg.encoder_seq_len, cfg.d_model),
            ("batch", "seq", "embed"),
            init="zeros",
            dtype=cfg.dtype,
        )
    return out


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Any:
    specs = cache_specs(cfg, batch, seq_len)
    zeros = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    # empty cache slots are marked pos_id = -1
    def fix(path, x):
        if path and path[-1] == "pos_ids":
            return jnp.full_like(x, -1)
        return x

    return _tree_map_with_path(fix, zeros)


def paged_cache_specs(
    cfg: ModelConfig, num_pages: int,
    page_size: int = layers.PAGE_SIZE, int8: bool = False,
) -> Dict[str, Any]:
    """Paged KV pool specs, stacked over layers (serving decode engine).

    Only DENSE blocks page their cache; recurrent-state archs (ssm/xlstm)
    and MOE's load counters keep dense per-lane state — the fallback
    matrix is documented in docs/kernels.md.
    """
    if cfg.block != BlockKind.DENSE:
        raise NotImplementedError(
            f"paged KV cache supports DENSE blocks only, got {cfg.block}"
        )
    one = layers.make_paged_cache_specs(cfg, num_pages, page_size, int8=int8)
    return {"blocks": common.stacked(one, cfg.num_layers)}


def init_paged_cache(
    cfg: ModelConfig, num_pages: int,
    page_size: int = layers.PAGE_SIZE, int8: bool = False,
) -> Any:
    specs = paged_cache_specs(cfg, num_pages, page_size, int8=int8)
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _tree_map_with_path(fn, tree, path=()):
    if isinstance(tree, dict):
        return {k: _tree_map_with_path(fn, v, path + (k,)) for k, v in tree.items()}
    return fn(path, tree)


# ---------------------------------------------------------------------------
# Block application (full sequence)
# ---------------------------------------------------------------------------

def _apply_block_full(
    params: Dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    opts: RunOpts,
    memory: Optional[jax.Array] = None,
    want_cache: bool = False,
    cache_len: int = 0,
) -> Tuple[jax.Array, jax.Array, Optional[Dict]]:
    """One block over a full sequence. Returns (x, aux_loss, cache | None)."""
    b = cfg.block
    aux = jnp.zeros((), jnp.float32)
    cache_out: Optional[Dict] = None
    x = opts.constrain(x, "activation")

    if b in (BlockKind.DENSE, BlockKind.MOE, BlockKind.ENCDEC):
        h = layers.norm(params["ln1"], x, cfg)
        attn_out, kv = _attn_full(params["attn"], h, positions, cfg, opts)
        x = x + attn_out
        if b == BlockKind.ENCDEC:
            h = layers.norm(params["ln_cross"], x, cfg)
            x = x + layers.cross_attention_layer(params["cross"], h, memory, cfg)
        h = layers.norm(params["ln2"], x, cfg)
        moe_load = None
        if b == BlockKind.MOE:
            m_out, aux, moe_load = moe.moe_block(params["moe"], h, cfg, opts.constrain)
            x = x + m_out
        else:
            x = x + layers.mlp(params["mlp"], h, cfg)
        if want_cache:
            cache_out = _kv_to_cache(kv, positions, cfg, cache_len, opts.int8_kv_cache)
            if moe_load is not None:
                cache_out["moe_load"] = moe_load

    elif b == BlockKind.HYBRID_PARALLEL:
        h = layers.norm(params["ln1"], x, cfg)
        attn_out, kv = _attn_full(params["attn"], h, positions, cfg, opts)
        ssm_out, ssm_state = ssm.mamba_block(params["mamba"], h, cfg)
        fused = 0.5 * (
            layers.rmsnorm(params["fuse_attn"], attn_out, cfg.norm_eps)
            + layers.rmsnorm(params["fuse_ssm"], ssm_out, cfg.norm_eps)
        )
        x = x + fused
        h = layers.norm(params["ln2"], x, cfg)
        x = x + layers.mlp(params["mlp"], h, cfg)
        if want_cache:
            cache_out = _kv_to_cache(kv, positions, cfg, cache_len, opts.int8_kv_cache)
            cache_out["ssm"] = ssm_state
    else:
        raise ValueError(b)
    return x, aux, cache_out


def _attn_full(params, h, positions, cfg, opts):
    """Self-attention returning output and the roped (k, v) for caching."""
    q, k, v = layers._project_qkv(params, h, h, cfg)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    q, k, v = layers._constrain_qkv(q, k, v, opts)
    window = cfg.window if cfg.attention == AttentionKind.SLIDING else 0
    if opts.attn_impl == "flash":
        # Pallas flash-attention prefill (serving hot path). Same math as
        # the jnp blockwise path (allclose-swept in tests/test_kernels.py);
        # interpret mode keeps it runnable on CPU CI.
        from repro.kernels.flash_attention import flash_attention

        out = flash_attention(
            q, k, v, True, window, 0, 128, 128,
            jax.default_backend() != "tpu",
        )
    else:
        out = layers.blockwise_attention(
            q, k, v,
            causal=True,
            window=window,
            q_chunk=opts.q_chunk,
            kv_chunk=opts.kv_chunk,
            impl=opts.attn_impl,
        )
    B, S = h.shape[:2]
    out = out.reshape(B, S, cfg.q_dim)
    return common.dense(out, params["wo"], cfg.dtype), (k, v)


def _kv_to_cache(kv, positions, cfg, cache_len: int, int8: bool = False) -> Dict:
    """Write the last ``cache_len`` positions of (k, v) into a fresh cache."""
    k, v = kv
    B, S = k.shape[:2]
    T = cache_len
    if S >= T:
        kc, vc = k[:, S - T :], v[:, S - T :]
        pos_ids = positions[0, S - T :].astype(jnp.int32)
        # ring-buffer layout: slot = pos % T
        slots = pos_ids % T
        kc = jnp.take(kc, jnp.argsort(slots), axis=1)
        vc = jnp.take(vc, jnp.argsort(slots), axis=1)
        pos_sorted = jnp.take(pos_ids, jnp.argsort(slots), axis=0)
        out = {"k": kc, "v": vc, "pos_ids": pos_sorted}
    else:
        pad = T - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_ids = jnp.concatenate(
            [positions[0].astype(jnp.int32), jnp.full((pad,), -1, jnp.int32)]
        )
        out = {"k": kc, "v": vc, "pos_ids": pos_ids}
    if int8:
        kq, ks = layers._quantize_kv(out["k"])
        vq, vs = layers._quantize_kv(out["v"])
        ct = jnp.dtype(cfg.dtype)
        out = {"k": kq, "v": vq, "pos_ids": out["pos_ids"],
               "k_scale": ks.astype(ct), "v_scale": vs.astype(ct)}
    return out


def _xlstm_group_full(params, x, cfg, opts, states=None, want_cache=False):
    """One xLSTM super-block (m_per mLSTM + optional sLSTM) over a sequence."""
    new_state: Dict[str, Any] = {}

    def m_body(xx, pl):
        p, st = pl
        xx = opts.constrain(xx, "activation")
        h, s = xlstm.mlstm_block(
            p["block"], layers.rmsnorm(p["ln"], xx, cfg.norm_eps), cfg, state=st
        )
        return xx + h, s

    m_params = params["mlstm"]
    m_states = states["mlstm"] if states is not None else None
    if m_states is None:
        n_m = jax.tree_util.tree_leaves(m_params)[0].shape[0]
        B = x.shape[0]
        m_states = common.stacked(xlstm.mlstm_state_spec(cfg, B), n_m)
        m_states = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)),
            m_states,
            is_leaf=lambda z: isinstance(z, ParamSpec),
        )

    def scan_body(xx, pl):
        xx, s = m_body(xx, pl)
        return xx, s

    x, m_state_out = jax.lax.scan(scan_body, x, (m_params, m_states))
    new_state["mlstm"] = m_state_out

    if "slstm" in params:
        p = params["slstm"]
        st = states["slstm"] if states is not None else None
        x = opts.constrain(x, "activation")
        h, s_state = xlstm.slstm_block(
            p["block"], layers.rmsnorm(p["ln"], x, cfg.norm_eps), cfg, state=st
        )
        x = x + h
        new_state["slstm"] = s_state
    return x, new_state if want_cache else None


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg: ModelConfig):
    """tokens (+ stub modality embeddings) -> (x, positions, memory, n_prefix)."""
    tokens = batch["tokens"]
    ct = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(ct)
    if cfg.embed_scale:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    n_prefix = 0
    if cfg.vision_tokens:
        patches = batch["patches"].astype(ct)  # (B, P, vit_width)
        prefix = common.dense(patches, params["vision_proj"], cfg.dtype)
        x = jnp.concatenate([prefix, x], axis=1)
        n_prefix = prefix.shape[1]
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    memory = None
    if cfg.encoder_layers:
        memory = _run_encoder(params["encoder"], batch["frames"].astype(ct), cfg)
    return x, positions, memory, n_prefix


def _run_encoder(enc_params, frames, cfg: ModelConfig):
    B, T, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

    def body(x, p):
        h = layers.norm(p["ln1"], x, cfg)
        q, k, v = layers._project_qkv(p["attn"], h, h, cfg)
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
        out = layers.blockwise_attention(q, k, v, causal=False, q_chunk=512, kv_chunk=512)
        out = out.reshape(B, T, cfg.q_dim)
        x = x + common.dense(out, p["attn"]["wo"], cfg.dtype)
        h = layers.norm(p["ln2"], x, cfg)
        x = x + layers.mlp(p["mlp"], h, cfg)
        return x, None

    x, _ = jax.lax.scan(body, frames, enc_params["blocks"])
    return layers.norm(enc_params["final_norm"], x, cfg)


def _maybe_remat(fn, opts: RunOpts):
    if opts.remat == "none":
        return fn
    if opts.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


def _unembed(params, x, cfg: ModelConfig):
    x = layers.norm(params["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        return common.dense(x, params["embed"].T, cfg.dtype)
    return common.dense(x, params["lm_head"], cfg.dtype)


def forward_hidden(params, batch, cfg: ModelConfig, opts: RunOpts):
    """Full-sequence forward up to (but excluding) the LM head.

    Returns (normed hidden states over TEXT positions, aux_loss) — the fused
    cross-entropy in train/steps.py consumes this and never materializes the
    full (B, S, vocab) logits.
    """
    x, positions, memory, n_prefix = _embed_inputs(params, batch, cfg)

    if cfg.block in (BlockKind.MLSTM, BlockKind.SLSTM):
        def body(xx, p):
            y, _ = _xlstm_group_full(p, xx, cfg, opts)
            return y, jnp.zeros((), jnp.float32)

        body = _maybe_remat(body, opts)
        x, auxes = jax.lax.scan(body, x, params["groups"])
    else:
        def body(xx, p):
            y, aux, _ = _apply_block_full(p, xx, positions, cfg, opts, memory=memory)
            return y, aux

        body = _maybe_remat(body, opts)
        if opts.scan_layers:
            x, auxes = jax.lax.scan(body, x, params["blocks"])
        else:
            auxes = []
            n = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
            for i in range(n):
                p_i = jax.tree_util.tree_map(lambda q: q[i], params["blocks"])
                x, a = body(x, p_i)
                auxes.append(a)
            auxes = jnp.stack(auxes)

    x = layers.norm(params["final_norm"], x[:, n_prefix:], cfg)
    return x, jnp.sum(auxes)


def unembed_weight(params, cfg: ModelConfig):
    """(d, vocab) projection — the tied-embedding transpose when tied."""
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def forward_train(params, batch, cfg: ModelConfig, opts: RunOpts):
    """Full-sequence forward. Returns (logits over TEXT positions, aux_loss)."""
    x, aux = forward_hidden(params, batch, cfg, opts)
    logits = common.dense(x, unembed_weight(params, cfg), cfg.dtype)
    return logits, aux


def prefill(params, batch, cfg: ModelConfig, opts: RunOpts, cache_seq_len: int):
    """Forward + cache build. Returns (last-position logits, cache)."""
    x, positions, memory, n_prefix = _embed_inputs(params, batch, cfg)
    T = cache_len_for(cfg, cache_seq_len)

    if cfg.block in (BlockKind.MLSTM, BlockKind.SLSTM):
        def body(xx, p):
            y, st = _xlstm_group_full(p, xx, cfg, opts, want_cache=True)
            return y, st

        x, group_states = jax.lax.scan(body, x, params["groups"])
        cache = {"groups": group_states}
    else:
        def body(xx, p):
            y, aux, c = _apply_block_full(
                p, xx, positions, cfg, opts, memory=memory,
                want_cache=True, cache_len=T,
            )
            return y, c

        x, cache_blocks = jax.lax.scan(body, x, params["blocks"])
        cache = {"blocks": cache_blocks}
        if memory is not None:
            cache["memory"] = memory

    logits = _unembed(params, x[:, -1:, :], cfg)
    return logits, cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, opts: RunOpts):
    """One decode step. tokens: (B, 1) int32; pos: scalar int32 — the TEXT
    position of the new token (callers count generated text tokens).

    Returns (logits (B, 1, V), new cache).
    """
    ct = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(ct)
    if cfg.embed_scale:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    if cfg.vision_tokens:
        # prefill ran over [vision prefix | text], so cache slots and RoPE
        # angles are prefix-absolute; without this offset the new token
        # overwrites a live slot and masks out every later prefill position
        pos = pos + cfg.vision_tokens

    if cfg.block in (BlockKind.MLSTM, BlockKind.SLSTM):
        def body(xx, pc):
            p, st = pc
            y, new_st = _xlstm_group_full(p, xx, cfg, opts, states=st, want_cache=True)
            return y, new_st

        x, new_groups = jax.lax.scan(body, x, (params["groups"], cache["groups"]))
        new_cache = {"groups": new_groups}
    else:
        memory = cache.get("memory")

        def body(xx, pc):
            p, c = pc
            # barrier: stop XLA-CPU from hoisting the dot's f32 operand
            # convert across the scan slice (it would keep a full f32 copy
            # of the stacked KV cache alive — 2x cache memory)
            c = jax.lax.optimization_barrier(c)
            xx = opts.constrain(xx, "activation")
            h = layers.norm(p["ln1"], xx, cfg)
            if cfg.block == BlockKind.HYBRID_PARALLEL:
                attn_out, kv_cache = layers.decode_attention(
                    p["attn"], {k: v_ for k, v_ in c.items() if k != "ssm"}, h, pos, cfg
                )
                ssm_out, ssm_state = ssm.mamba_decode_step(p["mamba"], h, c["ssm"], cfg)
                fused = 0.5 * (
                    layers.rmsnorm(p["fuse_attn"], attn_out, cfg.norm_eps)
                    + layers.rmsnorm(p["fuse_ssm"], ssm_out, cfg.norm_eps)
                )
                xx = xx + fused
                new_c = dict(kv_cache, ssm=ssm_state)
            else:
                attn_out, new_c = layers.decode_attention(
                    p["attn"],
                    {k: v_ for k, v_ in c.items() if k not in ("ssm", "moe_load")},
                    h, pos, cfg,
                )
                xx = xx + attn_out
                if cfg.block == BlockKind.ENCDEC:
                    h = layers.norm(p["ln_cross"], xx, cfg)
                    xx = xx + layers.cross_attention_layer(p["cross"], h, memory, cfg)
            h = layers.norm(p["ln2"], xx, cfg)
            if cfg.block == BlockKind.MOE:
                m_out, new_load = moe.moe_decode_block(
                    p["moe"], h, c["moe_load"], pos, cfg, opts.constrain
                )
                xx = xx + m_out
                new_c = dict(new_c, moe_load=new_load)
            else:
                xx = xx + layers.mlp(p["mlp"], h, cfg)
            return xx, new_c

        if opts.decode_unroll:
            n = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
            new_blocks = cache["blocks"]
            for i in range(n):
                p_i = jax.tree_util.tree_map(lambda t: t[i], params["blocks"])
                c_i = jax.tree_util.tree_map(lambda t: t[i], new_blocks)
                x, c_new = body(x, (p_i, c_i))
                # write the updated layer slice back in place: the stacked
                # cache stays ONE buffer end-to-end (donation-friendly)
                new_blocks = jax.tree_util.tree_map(
                    lambda stack, sl: jax.lax.dynamic_update_index_in_dim(
                        stack, sl.astype(stack.dtype), i, 0
                    ),
                    new_blocks, c_new,
                )
        else:
            x, new_blocks = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": new_blocks}
        if memory is not None:
            new_cache["memory"] = memory

    logits = _unembed(params, x, cfg)
    return logits, new_cache


def decode_step_paged(
    params, cache, tokens, seq_lens, block_table,
    cfg: ModelConfig, opts: RunOpts,
    *, use_kernel: bool = False, interpret: bool = False,
):
    """One continuous-batching decode step against the paged KV pool.

    tokens: (B, 1) int32; seq_lens: (B,) int32 per-lane cached-token counts
    (each lane's write position — lanes advance independently, unlike
    ``decode_step``'s single scalar ``pos``); block_table: (B, max_blocks)
    int32 with -1 for unassigned ranges (a fully dead lane produces
    deterministic garbage logits the engine never samples).

    Returns (logits (B, 1, V), new cache). DENSE blocks only — see
    ``paged_cache_specs``.
    """
    if cfg.block != BlockKind.DENSE:
        raise NotImplementedError(
            f"paged decode supports DENSE blocks only, got {cfg.block}"
        )
    ct = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(ct)
    if cfg.embed_scale:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)

    def body(xx, pc):
        p, c = pc
        c = jax.lax.optimization_barrier(c)
        xx = opts.constrain(xx, "activation")
        h = layers.norm(p["ln1"], xx, cfg)
        attn_out, new_c = layers.decode_attention_paged(
            p["attn"], c, h, seq_lens, block_table, cfg,
            use_kernel=use_kernel, interpret=interpret,
        )
        xx = xx + attn_out
        h = layers.norm(p["ln2"], xx, cfg)
        xx = xx + layers.mlp(p["mlp"], h, cfg)
        return xx, new_c

    if opts.decode_unroll:
        n = jax.tree_util.tree_leaves(params["blocks"])[0].shape[0]
        new_blocks = cache["blocks"]
        for i in range(n):
            p_i = jax.tree_util.tree_map(lambda t: t[i], params["blocks"])
            c_i = jax.tree_util.tree_map(lambda t: t[i], new_blocks)
            x, c_new = body(x, (p_i, c_i))
            new_blocks = jax.tree_util.tree_map(
                lambda stack, sl: jax.lax.dynamic_update_index_in_dim(
                    stack, sl.astype(stack.dtype), i, 0
                ),
                new_blocks, c_new,
            )
    else:
        x, new_blocks = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))

    logits = _unembed(params, x, cfg)
    return logits, {"blocks": new_blocks}
