"""Shared neural-net layers: norms, RoPE, blockwise attention, MLPs.

Attention is implemented *blockwise* (online-softmax over KV chunks, scan
over Q chunks) in pure jnp so that 32k-token prefill never materializes an
S×S score matrix — this is the XLA-side analogue of the Pallas
``flash_attention`` kernel in ``repro.kernels`` (which is the TPU-native
version of the same algorithm, validated against ``ref.py``).

Two causal implementations are selectable (``impl=``):

* ``masked``      — scan over all KV chunks with a causal mask. Simple,
                    uniform, but ~2× the useful FLOPs (upper triangle wasted).
* ``triangular``  — static unrolled loop over Q chunks; Q chunk i only visits
                    KV chunks 0..i. No wasted FLOPs; slightly larger HLO.

Sliding-window attention slices a static ``window + q_chunk`` KV band per Q
chunk (sub-quadratic — this is what makes ``long_500k`` decoding viable).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.models import common
from repro.models.common import ParamSpec

NEG_INF = -1e30  # large-negative for masking in f32 accumulation


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(dim: int, axis: str = "embed") -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((dim,), (axis,), init="ones")}


def layernorm_spec(dim: int, axis: str = "embed") -> Dict[str, ParamSpec]:
    return {
        "scale": ParamSpec((dim,), (axis,), init="ones"),
        "bias": ParamSpec((dim,), (axis,), init="zeros"),
    }


def rmsnorm(params: Dict, x: jax.Array, eps: float) -> jax.Array:
    """RMSNorm in f32 (numerics), output cast back to input dtype."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return y.astype(dtype)


def layernorm(params: Dict, x: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def norm(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "bias" in params:
        return layernorm(params, x, cfg.norm_eps)
    return rmsnorm(params, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    dtype = x.dtype
    freq = 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd))
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_spec(cfg: ModelConfig, d_in: int = 0, d_ff: int = 0) -> Dict[str, ParamSpec]:
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    if cfg.gated_mlp:
        return {
            "wi_gate": ParamSpec((d, f), ("embed", "ffn")),
            "wi_up": ParamSpec((d, f), ("embed", "ffn")),
            "wo": ParamSpec((f, d), ("ffn", "embed")),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", "ffn")),
        "bi": ParamSpec((f,), ("ffn",), init="zeros"),
        "wo": ParamSpec((f, d), ("ffn", "embed")),
        "bo": ParamSpec((d,), ("embed",), init="zeros"),
    }


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


def mlp(params: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    ct = cfg.dtype
    if cfg.gated_mlp:
        g = common.dense(x, params["wi_gate"], ct)
        u = common.dense(x, params["wi_up"], ct)
        return common.dense(_act(g, cfg.mlp_activation) * u, params["wo"], ct)
    h = common.dense(x, params["wi"], ct) + params["bi"].astype(jnp.dtype(ct))
    h = _act(h, cfg.mlp_activation)
    return common.dense(h, params["wo"], ct) + params["bo"].astype(jnp.dtype(ct))


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_spec(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    qd, kd = cfg.q_dim, cfg.kv_dim
    spec: Dict[str, ParamSpec] = {
        "wq": ParamSpec((d, qd), ("embed", "q_dim")),
        "wk": ParamSpec((d, kd), ("embed", "kv_dim")),
        "wv": ParamSpec((d, kd), ("embed", "kv_dim")),
        "wo": ParamSpec((qd, d), ("q_dim", "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamSpec((qd,), ("q_dim",), init="zeros")
        spec["bk"] = ParamSpec((kd,), ("kv_dim",), init="zeros")
        spec["bv"] = ParamSpec((kd,), ("kv_dim",), init="zeros")
    if cfg.qk_norm and not cross:
        spec["q_norm"] = ParamSpec((cfg.resolved_head_dim,), ("head_dim",), init="ones")
        spec["k_norm"] = ParamSpec((cfg.resolved_head_dim,), ("head_dim",), init="ones")
    return spec


def _project_qkv(
    params: Dict, xq: jax.Array, xkv: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(B,S,d) -> q (B,S,H,hd), k/v (B,T,KVH,hd)."""
    ct = cfg.dtype
    hd = cfg.resolved_head_dim
    q = common.dense(xq, params["wq"], ct)
    k = common.dense(xkv, params["wk"], ct)
    v = common.dense(xkv, params["wv"], ct)
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = q.reshape(*q.shape[:-1], cfg.num_heads, hd)
    k = k.reshape(*k.shape[:-1], cfg.num_kv_heads, hd)
    v = v.reshape(*v.shape[:-1], cfg.num_kv_heads, hd)
    if "q_norm" in params:
        q = rmsnorm({"scale": params["q_norm"]}, q, cfg.norm_eps)
        k = rmsnorm({"scale": params["k_norm"]}, k, cfg.norm_eps)
    return q, k, v


def _sdpa(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: Optional[jax.Array],
    scale: float,
) -> jax.Array:
    """Plain softmax attention over one (q-block × kv-block) pair.

    q: (B, Sq, KVH, G, hd)  k/v: (B, T, KVH, hd)  mask: (B, Sq, T) or None.
    Grouped-query attention without materializing repeated KV heads.
    """
    # preferred_element_type: bf16 inputs accumulate into f32 WITHOUT HLO
    # convert ops on the operands (matches MXU semantics; also prevents
    # XLA-CPU from hoisting a full-f32 copy of the KV cache out of the
    # layer loop — measured 2× cache memory without it)
    s = jnp.einsum(
        "bqhgd,bthd->bhgqt", q, k, preferred_element_type=jnp.float32
    ) * scale
    if mask is not None:
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqt,bthd->bqhgd", p, v)


def _online_block(
    carry: Tuple[jax.Array, jax.Array, jax.Array],
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: Optional[jax.Array],
    scale: float,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One online-softmax accumulation step (flash-attention recurrence).

    carry: acc (B,Sq,KVH,G,hd) f32, m (B,KVH,G,Sq) f32, l (B,KVH,G,Sq) f32.
    """
    acc, m, l = carry
    s = jnp.einsum(
        "bqhgd,bthd->bhgqt", q, k, preferred_element_type=jnp.float32
    ) * scale
    if mask is not None:
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhgqt,bthd->bqhgd", p.astype(q.dtype), v).astype(jnp.float32)
    acc_new = acc * jnp.moveaxis(corr, -1, 1)[..., None] + pv
    return acc_new, m_new, l_new


def _finish_online(acc: jax.Array, l: jax.Array, dtype) -> jax.Array:
    out = acc / jnp.maximum(jnp.moveaxis(l, -1, 1)[..., None], 1e-37)
    return out.astype(dtype)


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    impl: str = "masked",
    q_offset: int = 0,
    kv_valid: Optional[int] = None,
) -> jax.Array:
    """Blockwise (flash-style) attention in pure jnp.

    q: (B, Sq, H, hd); k/v: (B, T, KVH, hd). Returns (B, Sq, H, hd).
    ``q_offset``: absolute position of q[0] relative to k[0] (prefill = 0).
    ``kv_valid``: KV rows ≥ this index are padding and masked out.
    """
    B, Sq, H, hd = q.shape
    T = k.shape[1]
    KVH = k.shape[2]
    G = H // KVH
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, Sq, KVH, G, hd)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, T)
    if Sq <= q_chunk and T <= kv_chunk:
        # tiny: single fused block
        q_pos = q_offset + jnp.arange(Sq)
        kv_pos = jnp.arange(T)
        mask = jnp.ones((B, Sq, T), bool)
        if causal:
            mask &= q_pos[None, :, None] >= kv_pos[None, None, :]
        if window:
            mask &= q_pos[None, :, None] - kv_pos[None, None, :] < window
        if kv_valid is not None and kv_valid < T:
            mask &= (kv_pos < kv_valid)[None, None, :]
        out = _sdpa(qg, k, v, mask, scale)
        return out.reshape(B, Sq, H, hd)

    # Ragged sequence lengths (e.g. a VLM's 1025-patch prefix + 4096 text
    # tokens): pad to the chunk grid instead of falling back to an O(S²)
    # fused block; padded KV rows are masked via kv_valid, padded Q rows are
    # sliced off.
    pad_q = (-Sq) % q_chunk
    pad_kv = (-T) % kv_chunk if window == 0 else 0
    if pad_q or pad_kv:
        q_p = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        k_p = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v_p = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        out = blockwise_attention(
            q_p, k_p, v_p,
            causal=causal, window=window, q_chunk=q_chunk, kv_chunk=kv_chunk,
            impl=impl, q_offset=q_offset, kv_valid=T,
        )
        return out[:, :Sq]

    n_q = Sq // q_chunk

    if window:
        # Sliding window: per q-chunk slice a static (window + q_chunk) KV band.
        band = min(window + q_chunk, T)

        @jax.checkpoint
        def q_step(_, qi):
            qc, i = qi
            qs = q_offset + i * q_chunk
            start = jnp.clip(qs + q_chunk - band, 0, T - band)
            kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            q_pos = qs + jnp.arange(q_chunk)
            kv_pos = start + jnp.arange(band)
            mask = jnp.ones((B, q_chunk, band), bool)
            if causal:
                mask &= q_pos[None, :, None] >= kv_pos[None, None, :]
            mask &= q_pos[None, :, None] - kv_pos[None, None, :] < window
            if kv_valid is not None and kv_valid < T:
                mask &= (kv_pos < kv_valid)[None, None, :]
            return None, _sdpa(qc, kb, vb, mask, scale)

        qs_stacked = qg.reshape(B, n_q, q_chunk, KVH, G, hd).swapaxes(0, 1)
        _, outs = jax.lax.scan(q_step, None, (qs_stacked, jnp.arange(n_q)))
        out = outs.swapaxes(0, 1).reshape(B, Sq, KVH, G, hd)
        return out.reshape(B, Sq, H, hd)

    n_kv = T // kv_chunk
    k_blocks = k.reshape(B, n_kv, kv_chunk, KVH, hd).swapaxes(0, 1)
    v_blocks = v.reshape(B, n_kv, kv_chunk, KVH, hd).swapaxes(0, 1)

    def attend_q_chunk(qc: jax.Array, qi: int, n_vis: int) -> jax.Array:
        """Online softmax of one q chunk over KV chunks [0, n_vis)."""
        qs = q_offset + qi * q_chunk
        q_pos = qs + jnp.arange(q_chunk)

        # checkpoint each KV block: backward recomputes the (q_chunk×kv_chunk)
        # scores instead of saving them — the flash-attention memory win.
        @jax.checkpoint
        def kv_step(carry, blk):
            kb, vb, j = blk
            kv_pos = j * kv_chunk + jnp.arange(kv_chunk)
            mask = None
            if causal:
                mask = q_pos[None, :, None] >= kv_pos[None, None, :]
            if kv_valid is not None and kv_valid < T:
                bound = (kv_pos < kv_valid)[None, None, :]
                mask = bound if mask is None else mask & bound
            if mask is not None:
                mask = mask & jnp.ones((B, 1, 1), bool)
            return _online_block(carry, qc, kb, vb, mask, scale), None

        acc0 = jnp.zeros((B, q_chunk, KVH, G, hd), jnp.float32)
        m0 = jnp.full((B, KVH, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, q_chunk), jnp.float32)
        (acc, _, l), _ = jax.lax.scan(
            kv_step,
            (acc0, m0, l0),
            (k_blocks[:n_vis], v_blocks[:n_vis], jnp.arange(n_vis)),
        )
        return _finish_online(acc, l, q.dtype)

    if impl == "triangular" and causal:
        # Static unroll: q chunk i sees exactly KV chunks 0..i — no masked-out
        # FLOPs above the diagonal (the ~2x win recorded in §Perf).
        outs = []
        for i in range(n_q):
            qc = jax.lax.slice_in_dim(qg, i * q_chunk, (i + 1) * q_chunk, axis=1)
            n_vis = min(-(-((i + 1) * q_chunk + q_offset) // kv_chunk), n_kv)
            outs.append(jax.checkpoint(
                lambda qc_, i_=i, n_=n_vis: attend_q_chunk(qc_, i_, n_)
            )(qc))
        out = jnp.concatenate(outs, axis=1)
    else:
        qs_stacked = qg.reshape(B, n_q, q_chunk, KVH, G, hd).swapaxes(0, 1)

        @jax.checkpoint
        def q_step(_, qi):
            qc, i = qi
            return None, attend_q_chunk(qc, i, n_kv)

        _, outs = jax.lax.scan(q_step, None, (qs_stacked, jnp.arange(n_q)))
        out = outs.swapaxes(0, 1).reshape(B, Sq, KVH, G, hd)
    return out.reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------

def make_cache_specs(
    cfg: ModelConfig, batch: int, cache_len: int, int8: bool = False
) -> Dict:
    """Abstract KV-cache entry for ONE layer (stacked over layers by caller).

    ``pos_ids`` stores the absolute position held in each slot (-1 = empty),
    which uniformly supports full caches and ring-buffer window caches.

    ``int8``: quantized cache with a per-(batch, slot, kv_head) dynamic
    scale — halves HBM for the decode-dominant cache reads (the production
    fix for MHA archs like qwen1.5-32b whose 40-head 32k cache cannot fit
    at bf16).
    """
    hd = cfg.resolved_head_dim
    kv_dtype = "int8" if int8 else cfg.dtype
    spec = {
        "k": ParamSpec((batch, cache_len, cfg.num_kv_heads, hd),
                       ("batch", "seq", "kv_heads", "head_dim"),
                       init="zeros", dtype=kv_dtype),
        "v": ParamSpec((batch, cache_len, cfg.num_kv_heads, hd),
                       ("batch", "seq", "kv_heads", "head_dim"),
                       init="zeros", dtype=kv_dtype),
        "pos_ids": ParamSpec((cache_len,), (None,), init="zeros", dtype="int32"),
    }
    if int8:
        spec["k_scale"] = ParamSpec((batch, cache_len, cfg.num_kv_heads, 1),
                                    ("batch", "seq", "kv_heads", None),
                                    init="zeros", dtype=cfg.dtype)
        spec["v_scale"] = ParamSpec((batch, cache_len, cfg.num_kv_heads, 1),
                                    ("batch", "seq", "kv_heads", None),
                                    init="zeros", dtype=cfg.dtype)
    return spec


def _quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-(b, slot, head) int8 quantization. x: (B, T, KVH, hd)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def decode_attention(
    params: Dict,
    cache: Dict,
    x: jax.Array,
    pos: jax.Array,
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict]:
    """One-token attention against a (possibly ring-buffer) KV cache.

    x: (B, 1, d); pos: scalar int32 absolute position of this token.
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k_new, v_new = _project_qkv(params, x, x, cfg)
    q = rope(q, pos[None].astype(jnp.float32) * jnp.ones((B, 1)), cfg.rope_theta)
    k_new = rope(k_new, pos[None].astype(jnp.float32) * jnp.ones((B, 1)), cfg.rope_theta)

    T = cache["k"].shape[1]
    slot = (pos % T).astype(jnp.int32)
    int8 = cache["k"].dtype == jnp.int8
    if int8:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot, axis=1)
        k_scale = jax.lax.dynamic_update_slice_in_dim(
            cache["k_scale"], ks.astype(cache["k_scale"].dtype), slot, axis=1
        )
        v_scale = jax.lax.dynamic_update_slice_in_dim(
            cache["v_scale"], vs.astype(cache["v_scale"].dtype), slot, axis=1
        )
        k_use = _dequantize_kv(k, k_scale, q.dtype)
        v_use = _dequantize_kv(v, v_scale, q.dtype)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1
        )
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1
        )
        k_use, v_use = k.astype(q.dtype), v.astype(q.dtype)
    pos_ids = jax.lax.dynamic_update_slice_in_dim(
        cache["pos_ids"], pos[None].astype(jnp.int32), slot, axis=0
    )

    valid = pos_ids >= 0
    if cfg.window:
        valid &= pos - pos_ids < cfg.window
    valid &= pos_ids <= pos

    KVH = cfg.num_kv_heads
    G = cfg.num_heads // KVH
    qg = q.reshape(B, 1, KVH, G, hd)
    mask = jnp.broadcast_to(valid[None, None, :], (B, 1, T))
    out = _sdpa(qg, k_use, v_use, mask, 1.0 / np.sqrt(hd))
    out = out.reshape(B, 1, cfg.num_heads * hd)
    y = common.dense(out, params["wo"], cfg.dtype)
    new_cache = {"k": k, "v": v, "pos_ids": pos_ids}
    if int8:
        new_cache["k_scale"] = k_scale
        new_cache["v_scale"] = v_scale
    return y, new_cache


# ---------------------------------------------------------------------------
# Paged KV cache (serving decode)
# ---------------------------------------------------------------------------

PAGE_SIZE = 16  # token positions per pool page; matches cache_len_for's ×16


def make_paged_cache_specs(
    cfg: ModelConfig, num_pages: int, page_size: int = PAGE_SIZE,
    int8: bool = False,
) -> Dict:
    """Abstract paged-KV pool entry for ONE layer (stacked by caller).

    The pool is shared across all sequences: ``num_pages`` fixed-size
    blocks of ``page_size`` consecutive token positions each. Host-side
    per-sequence block tables (int32, -1 = unassigned) map logical
    position ranges to pool pages, replacing the dense
    ``(B, cache_len, KVH, hd)`` max-context over-allocation — HBM scales
    with *occupied* tokens, and the continuous-batching engine admits new
    sequences against pool occupancy instead of a static batch ceiling.
    The last pool page is reserved as a trash page: dead decode lanes
    write there and it is never allocated or attended to.
    """
    hd = cfg.resolved_head_dim
    kv_dtype = "int8" if int8 else cfg.dtype
    spec = {
        "k_pages": ParamSpec((num_pages, page_size, cfg.num_kv_heads, hd),
                             (None, None, "kv_heads", "head_dim"),
                             init="zeros", dtype=kv_dtype),
        "v_pages": ParamSpec((num_pages, page_size, cfg.num_kv_heads, hd),
                             (None, None, "kv_heads", "head_dim"),
                             init="zeros", dtype=kv_dtype),
    }
    if int8:
        spec["k_scale"] = ParamSpec((num_pages, page_size, cfg.num_kv_heads, 1),
                                    (None, None, "kv_heads", None),
                                    init="zeros", dtype=cfg.dtype)
        spec["v_scale"] = ParamSpec((num_pages, page_size, cfg.num_kv_heads, 1),
                                    (None, None, "kv_heads", None),
                                    init="zeros", dtype=cfg.dtype)
    return spec


def _paged_write(pages: jax.Array, new: jax.Array, rows: jax.Array) -> jax.Array:
    """Scatter one token per sequence into the flattened pool.

    pages: (P, ps, ...); new: (B, ...); rows: (B,) flattened pool rows.
    Live rows are unique by construction (one page owner per range); only
    trash-page rows may collide, and those are never read back.
    """
    P, ps = pages.shape[:2]
    flat = pages.reshape(P * ps, *pages.shape[2:])
    flat = flat.at[rows].set(new.astype(pages.dtype))
    return flat.reshape(pages.shape)


def _paged_attend_gathered(
    q: jax.Array, k: jax.Array, v: jax.Array, lens: jax.Array
) -> jax.Array:
    """Exact masked attention of one decode token over gathered pages.

    q: (B, H, hd); k/v: (B, T, KVH, hd) already gathered (and dequantized
    if int8) through the block table; lens: (B,) valid positions.
    """
    B, H, hd = q.shape
    KVH = k.shape[2]
    qg = q.reshape(B, 1, KVH, H // KVH, hd)
    T = k.shape[1]
    kv_pos = jnp.arange(T, dtype=jnp.int32)
    mask = (kv_pos[None, :] < lens[:, None])[:, None, :]  # (B, 1, T)
    out = _sdpa(qg, k, v, mask, 1.0 / np.sqrt(hd))
    return out.reshape(B, H, hd)


def decode_attention_paged(
    params: Dict,
    cache: Dict,
    x: jax.Array,
    seq_lens: jax.Array,     # (B,) int32: tokens already cached per lane
    block_table: jax.Array,  # (B, max_blocks) int32; -1 = unassigned
    cfg: ModelConfig,
    *,
    use_kernel: bool = False,
    interpret: bool = False,
) -> Tuple[jax.Array, Dict]:
    """One-token attention against the shared paged KV pool.

    x: (B, 1, d). ``seq_lens[b]`` is both the number of cached tokens and
    the absolute position of this token for lane b (continuous batching:
    lanes advance independently, so position is a vector, not a scalar).
    A dead lane (unassigned page at its write index) redirects its write
    to the reserved trash page and attends over zero positions, producing
    a deterministic output the engine never reads.

    ``use_kernel`` dispatches to the Pallas kernel (bf16/f32 pools only);
    the default is the pure-jnp oracle, and int8 pools always take the
    gather path with dequantization scoped to the gathered pages —
    O(seq_len) dequant per token, unlike the dense ``decode_attention``
    path which dequantizes the whole cache each step.
    """
    from repro.kernels.paged_attention import (
        paged_attention_ref, paged_decode_attention,
    )

    B = x.shape[0]
    hd = cfg.resolved_head_dim
    KVH = cfg.num_kv_heads
    k_pages = cache["k_pages"]
    P, ps = k_pages.shape[:2]
    int8 = k_pages.dtype == jnp.int8

    pos = seq_lens.astype(jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, x, cfg)
    q = rope(q, pos[:, None].astype(jnp.float32), cfg.rope_theta)
    k_new = rope(k_new, pos[:, None].astype(jnp.float32), cfg.rope_theta)

    pidx = jnp.clip(pos // ps, 0, block_table.shape[1] - 1)
    page = jnp.take_along_axis(block_table, pidx[:, None], axis=1)[:, 0]
    live = page >= 0
    dest = jnp.where(live, page, P - 1)  # trash page for dead lanes
    rows = dest * ps + pos % ps

    new_cache = dict(cache)
    if int8:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        new_cache["k_pages"] = _paged_write(cache["k_pages"], kq[:, 0], rows)
        new_cache["v_pages"] = _paged_write(cache["v_pages"], vq[:, 0], rows)
        new_cache["k_scale"] = _paged_write(cache["k_scale"], ks[:, 0], rows)
        new_cache["v_scale"] = _paged_write(cache["v_scale"], vs[:, 0], rows)
    else:
        new_cache["k_pages"] = _paged_write(cache["k_pages"], k_new[:, 0], rows)
        new_cache["v_pages"] = _paged_write(cache["v_pages"], v_new[:, 0], rows)

    lens_att = jnp.where(live, pos + 1, 0).astype(jnp.int32)
    q3 = q[:, 0]  # (B, H, hd)
    if int8:
        tbl = jnp.maximum(block_table, 0)
        T = tbl.shape[1] * ps
        kg = jnp.take(new_cache["k_pages"], tbl, axis=0)
        vg = jnp.take(new_cache["v_pages"], tbl, axis=0)
        ksg = jnp.take(new_cache["k_scale"], tbl, axis=0)
        vsg = jnp.take(new_cache["v_scale"], tbl, axis=0)
        k_use = _dequantize_kv(kg, ksg, q.dtype).reshape(B, T, KVH, hd)
        v_use = _dequantize_kv(vg, vsg, q.dtype).reshape(B, T, KVH, hd)
        out = _paged_attend_gathered(q3, k_use, v_use, lens_att)
    elif use_kernel:
        out = paged_decode_attention(
            q3, new_cache["k_pages"], new_cache["v_pages"],
            block_table, lens_att, interpret=interpret,
        )
    else:
        out = paged_attention_ref(
            q3, new_cache["k_pages"], new_cache["v_pages"],
            block_table, lens_att,
        )
    out = out.reshape(B, 1, cfg.num_heads * hd)
    y = common.dense(out, params["wo"], cfg.dtype)
    return y, new_cache


def _constrain_qkv(q, k, v, opts):
    # gather ONLY K and V (once per layer); Q keeps its sequence sharding so
    # the attention FLOPs still partition over the model axis by q rows
    k = opts.constrain(k, "attn_qkv")
    v = opts.constrain(v, "attn_qkv")
    return q, k, v


def full_attention_layer(
    params: Dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    q_chunk: int,
    kv_chunk: int,
    impl: str,
) -> jax.Array:
    """Self-attention over a full sequence (train / prefill). x: (B,S,d)."""
    q, k, v = _project_qkv(params, x, x, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    out = blockwise_attention(
        q, k, v,
        causal=True,
        window=cfg.window if cfg.attention.value == "sliding" else 0,
        q_chunk=q_chunk,
        kv_chunk=kv_chunk,
        impl=impl,
    )
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.q_dim)
    return common.dense(out, params["wo"], cfg.dtype)


def cross_attention_layer(
    params: Dict,
    x: jax.Array,
    memory: jax.Array,
    cfg: ModelConfig,
) -> jax.Array:
    """Encoder-decoder cross attention (no RoPE, no mask). memory: (B,T,d)."""
    q, k, v = _project_qkv(params, x, memory, cfg)
    out = blockwise_attention(q, k, v, causal=False, q_chunk=512, kv_chunk=512)
    B, S = x.shape[:2]
    return common.dense(out.reshape(B, S, cfg.q_dim), params["wo"], cfg.dtype)
