"""Mamba-style selective SSM block (used standalone and inside Hymba's
parallel attention+SSM blocks).

The pure-jnp path runs the recurrence as ``scan(chunks) ∘ scan(steps)`` with
an O(B·inner·N) carried state — it never materializes the (B,S,inner,N)
decay tensor (which is terabytes at our shapes). The TPU-native chunked
kernel in ``repro.kernels.ssm_scan`` computes the same recurrence with VMEM
tiling; this module is its oracle-equivalent and the path used for
lowering/dry-run.

State layout (also the decode state): ``{"conv": (B, W-1, inner),
"h": (B, inner, N)}`` — constant per-token memory, which is what makes
``long_500k`` decoding viable for SSM/hybrid archs.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import common
from repro.models.common import ParamSpec


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or math.ceil(cfg.d_model / 16)
    return inner, s.state_dim, dt_rank, s.conv_width


def mamba_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    inner, N, R, W = _dims(cfg)
    return {
        "in_proj": ParamSpec((d, 2 * inner), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((W, inner), ("conv", "ssm_inner"), scale=0.5),
        "conv_b": ParamSpec((inner,), ("ssm_inner",), init="zeros"),
        "x_proj": ParamSpec((inner, R + 2 * N), ("ssm_inner", None)),
        "dt_proj": ParamSpec((R, inner), ("dt_rank", "ssm_inner")),
        "dt_bias": ParamSpec((inner,), ("ssm_inner",), init="zeros"),
        "A_log": ParamSpec((inner, N), ("ssm_inner", "ssm_state"), init="ones"),
        "D": ParamSpec((inner,), ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((inner, d), ("ssm_inner", "embed")),
    }


def init_state(cfg: ModelConfig, batch: int) -> Dict[str, ParamSpec]:
    inner, N, _, W = _dims(cfg)
    return {
        "conv": ParamSpec((batch, W - 1, inner), ("batch", None, "ssm_inner"), init="zeros"),
        "h": ParamSpec((batch, inner, N), ("batch", "ssm_inner", "ssm_state"), init="zeros"),
    }


def _ssm_params(params: Dict, u: jax.Array, cfg: ModelConfig):
    """u: (..., inner) post-conv activations -> (dt, B_, C_) selective params."""
    inner, N, R, _ = _dims(cfg)
    proj = common.dense(u, params["x_proj"], "float32")
    dt_low, B_, C_ = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        common.dense(dt_low, params["dt_proj"], "float32")
        + params["dt_bias"].astype(jnp.float32)
    )
    return dt, B_, C_


def _step(
    params: Dict,
    h: jax.Array,
    u: jax.Array,
    dt: jax.Array,
    B_: jax.Array,
    C_: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """One recurrence step. h: (B, inner, N) f32; u/dt: (B, inner); B_/C_: (B, N)."""
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (inner, N)
    da = jnp.exp(dt[..., None] * A)                    # (B, inner, N)
    db = dt[..., None] * B_[:, None, :]                # (B, inner, N)
    h = da * h + db * u.astype(jnp.float32)[..., None]
    y = jnp.einsum("bin,bn->bi", h, C_) + params["D"].astype(jnp.float32) * u
    return h, y


def _causal_conv(params: Dict, x: jax.Array, prefix: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq. x: (B,S,inner); prefix: (B,W-1,inner)."""
    W = params["conv_w"].shape[0]
    xp = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * params["conv_w"][i].astype(x.dtype)
        for i in range(W)
    )
    return out + params["conv_b"].astype(x.dtype)


def mamba_block(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    state: Optional[Dict] = None,
) -> Tuple[jax.Array, Dict]:
    """Full-sequence Mamba block. x: (B, S, d) -> (y (B,S,d), final state)."""
    B, S, d = x.shape
    inner, N, _, W = _dims(cfg)
    ct = jnp.dtype(cfg.dtype)
    chunk = max(1, min(cfg.ssm.chunk, S))

    xz = common.dense(x, params["in_proj"], cfg.dtype)
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_prefix = (
        state["conv"] if state is not None else jnp.zeros((B, W - 1, inner), ct)
    )
    u = jax.nn.silu(_causal_conv(params, xin, conv_prefix))  # (B,S,inner)

    h0 = (
        state["h"].astype(jnp.float32)
        if state is not None
        else jnp.zeros((B, inner, N), jnp.float32)
    )

    if S % chunk:
        chunk = 1
    n_chunks = S // chunk
    uc = u.reshape(B, n_chunks, chunk, inner).swapaxes(0, 1)

    @jax.checkpoint  # backward saves only the (B, inner, N) chunk boundaries
    def chunk_step(h, u_chunk):  # u_chunk: (B, chunk, inner)
        # Selective params for the whole chunk in one batched matmul (MXU-
        # friendly); the sequential part carries only the (B, inner, N) state.
        dt, B_, C_ = _ssm_params(params, u_chunk, cfg)

        def step(hh, xs):
            ut, dtt, bt, ct_ = xs
            hh, y = _step(params, hh, ut, dtt, bt, ct_)
            return hh, y

        h, ys = jax.lax.scan(
            step,
            h,
            (
                u_chunk.swapaxes(0, 1),
                dt.swapaxes(0, 1),
                B_.swapaxes(0, 1),
                C_.swapaxes(0, 1),
            ),
        )
        return h, ys.swapaxes(0, 1)

    h_final, ys = jax.lax.scan(chunk_step, h0, uc)
    y = ys.swapaxes(0, 1).reshape(B, S, inner).astype(ct)
    y = y * jax.nn.silu(z)
    out = common.dense(y, params["out_proj"], cfg.dtype)
    new_state = {
        "conv": jnp.concatenate([conv_prefix.astype(ct), xin], axis=1)[:, -(W - 1):, :],
        "h": h_final,
    }
    return out, new_state


def mamba_decode_step(
    params: Dict, x: jax.Array, state: Dict, cfg: ModelConfig
) -> Tuple[jax.Array, Dict]:
    """Single-token step. x: (B, 1, d) -> (y (B,1,d), new state)."""
    out, new_state = mamba_block(params, x, cfg, state=state)
    return out, new_state
