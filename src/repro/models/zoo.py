"""Public model API: build any assigned architecture + its input specs.

``input_specs(cfg, shape)`` returns ShapeDtypeStructs for every model input
of an assigned (arch × input-shape) cell — weak-type-correct, shardable,
zero allocation — exactly what ``jax.jit(...).lower()`` consumes in the
multi-pod dry-run. Modality frontends are stubs: whisper gets precomputed
frame embeddings, internvl precomputed patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import InputShape, ModelConfig
from repro.models import common, transformer
from repro.models.common import ParamSpec
from repro.models.transformer import RunOpts


@dataclasses.dataclass(frozen=True)
class Model:
    """A built architecture: specs + the three pure driver functions."""

    cfg: ModelConfig
    specs: Dict[str, Any]

    def init(self, key: jax.Array) -> Any:
        return common.init_params(self.specs, key)

    def abstract_params(self) -> Any:
        return common.abstract_params(self.specs)

    def forward(self, params, batch, opts: Optional[RunOpts] = None):
        return transformer.forward_train(params, batch, self.cfg, opts or RunOpts())

    def forward_hidden(self, params, batch, opts: Optional[RunOpts] = None):
        return transformer.forward_hidden(params, batch, self.cfg, opts or RunOpts())

    def unembed_weight(self, params):
        return transformer.unembed_weight(params, self.cfg)

    def prefill(self, params, batch, cache_seq_len: int, opts: Optional[RunOpts] = None):
        return transformer.prefill(
            params, batch, self.cfg, opts or RunOpts(), cache_seq_len
        )

    def decode_step(self, params, cache, tokens, pos, opts: Optional[RunOpts] = None):
        return transformer.decode_step(
            params, cache, tokens, pos, self.cfg, opts or RunOpts()
        )

    def cache_specs(self, batch: int, seq_len: int, int8: bool = False):
        return transformer.cache_specs(self.cfg, batch, seq_len, int8=int8)

    def init_cache(self, batch: int, seq_len: int):
        return transformer.init_cache(self.cfg, batch, seq_len)

    def decode_step_paged(
        self, params, cache, tokens, seq_lens, block_table,
        opts: Optional[RunOpts] = None,
        *, use_kernel: bool = False, interpret: bool = False,
    ):
        return transformer.decode_step_paged(
            params, cache, tokens, seq_lens, block_table,
            self.cfg, opts or RunOpts(),
            use_kernel=use_kernel, interpret=interpret,
        )

    def paged_cache_specs(self, num_pages: int, page_size: int = 16,
                          int8: bool = False):
        return transformer.paged_cache_specs(
            self.cfg, num_pages, page_size, int8=int8
        )

    def init_paged_cache(self, num_pages: int, page_size: int = 16,
                         int8: bool = False):
        return transformer.init_paged_cache(
            self.cfg, num_pages, page_size, int8=int8
        )

    def param_count(self) -> int:
        return sum(
            int(np.prod(s.shape))
            for s in jax.tree_util.tree_leaves(
                self.specs, is_leaf=lambda x: isinstance(x, ParamSpec)
            )
        )


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg, specs=transformer.model_specs(cfg))


# ---------------------------------------------------------------------------
# Input specs per (arch × shape) cell
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one assigned cell, as ShapeDtypeStructs."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.mode == "train":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.mode == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode: one new token against a cache of S
        out["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    if cfg.encoder_layers:
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq_len, cfg.d_model), f
        )
    if cfg.vision_tokens and shape.mode != "decode":
        out["patches"] = jax.ShapeDtypeStruct((B, cfg.vision_tokens, cfg.vision_width), f)
    return out


def concrete_inputs(
    cfg: ModelConfig, shape: InputShape, key: jax.Array
) -> Dict[str, jax.Array]:
    """Random concrete inputs matching input_specs (smoke tests / examples)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, s.shape, 0, cfg.vocab_size, s.dtype)
        else:
            out[name] = jax.random.normal(sub, s.shape, s.dtype)
    return out
