"""Parameter-spec machinery shared by every model family.

A model is a pure-functional pair ``(param_specs, apply)``:

* ``param_specs(cfg)`` returns a pytree of :class:`ParamSpec` — shape, logical
  sharding axes, and init recipe for every parameter.  Logical axes (e.g.
  ``("embed", "ffn")``) are resolved to mesh :class:`PartitionSpec`s by
  ``repro.dist.sharding`` — models never name mesh axes directly.
* ``init_params(specs, key)`` materializes the pytree (used by smoke tests
  and real training); ``abstract_params(specs)`` yields ShapeDtypeStructs for
  the allocation-free dry-run.

Stacked (scan-over-layers) parameters carry a leading ``"layers"`` logical
axis which is never sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis names used across the model zoo. The sharding rules tables in
# repro.dist.sharding map these to mesh axes; rule tables may only name axes
# listed here (enforced at rule-table construction).
LOGICAL_AXES = (
    "layers",      # scan dim — never sharded
    "groups",      # xLSTM super-block scan dim — never sharded
    "vocab",       # embedding / lm-head vocab dim
    "embed",       # d_model (a.k.a. residual stream)
    "q_dim",       # fused num_heads * head_dim projection output
    "kv_dim",      # fused num_kv_heads * head_dim projection output
    "heads",       # attention heads (activations)
    "kv_heads",
    "head_dim",
    "ffn",         # MLP hidden
    "experts",     # MoE expert dim
    "ssm_inner",   # Mamba inner (expand * d_model)
    "ssm_state",   # Mamba state N
    "conv",        # depthwise conv width
    "dt_rank",
    "enc_embed",   # encoder width (enc-dec models)
    "vit_embed",   # stub vision encoder width (VLM)
    "seq",         # sequence dim (activations only)
    "batch",       # batch dim (activations only)
)

# lax.scan stacking dims: every device owns every layer, so these are never
# mapped to a mesh axis regardless of the rule table.
SCAN_AXES = ("layers", "groups")


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | embed | scaled
    scale: float = 1.0            # multiplier on the init std
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_one(spec: ParamSpec, key: jax.Array) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape) * spec.scale).astype(dtype)
    # fan-in scaled normal (truncation unnecessary for our purposes)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape) * std).astype(dtype)


def init_params(specs: Any, key: jax.Array) -> Any:
    """Materialize a ParamSpec pytree into arrays (deterministic in key)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [_init_one(s, k) for s, k in zip(leaves, keys)]
    )


def abstract_params(specs: Any) -> Any:
    """ShapeDtypeStruct pytree — no allocation; feeds .lower() in the dry-run."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def spec_axes(specs: Any) -> Any:
    """Pytree of logical-axis tuples, same structure as the params."""
    return jax.tree_util.tree_map(
        lambda s: s.axes, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def param_bytes(specs: Any) -> int:
    total = 0
    for s in jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    ):
        total += int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
    return total


def stack_specs(spec: ParamSpec, n: int, axis_name: str = "layers") -> ParamSpec:
    """Prepend a scan (stacking) dim to a spec."""
    return dataclasses.replace(
        spec, shape=(n,) + spec.shape, axes=(axis_name,) + spec.axes
    )


def stacked(tree: Any, n: int, axis_name: str = "layers") -> Any:
    return jax.tree_util.tree_map(
        lambda s: stack_specs(s, n, axis_name),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# numerics helpers
# ---------------------------------------------------------------------------

def cast(x: jax.Array, dtype: str) -> jax.Array:
    return x.astype(jnp.dtype(dtype))


def dense(x: jax.Array, w: jax.Array, compute_dtype: str) -> jax.Array:
    """y = x @ w with params cast to the compute dtype (bf16 matmul on MXU)."""
    return jnp.einsum(
        "...d,df->...f",
        x.astype(jnp.dtype(compute_dtype)),
        w.astype(jnp.dtype(compute_dtype)),
    )
