"""Mixture-of-Experts block: top-k routing with capacity-bounded scatter
dispatch.

Why scatter dispatch (not the Switch-Transformer one-hot einsum): at our
assigned shapes (256×4096 tokens, 8–16 experts) the (tokens, E, C) dispatch
mask is terabytes; the scatter formulation is O(tokens · d) and lowers to
a dynamic-scatter + all-to-all under GSPMD when experts are sharded over the
``model`` mesh axis — the expert-parallel schedule real MoE frameworks use.

Tokens are dispatched within *groups* (one group per sequence for training,
one global group for decode) so that dispatch never mixes tokens across the
``data``-sharded batch dim, keeping the scatter local to a data shard.
Over-capacity tokens are dropped (standard capacity-factor semantics); the
residual connection keeps dropped tokens alive downstream.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import common
from repro.models.common import ParamSpec


def moe_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    assert cfg.moe is not None
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    spec = {
        "router": ParamSpec((d, e), ("embed", "experts")),
        "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "ffn")),
        "wi_up": ParamSpec((e, d, f), ("experts", "embed", "ffn")),
        "wo": ParamSpec((e, f, d), ("experts", "ffn", "embed")),
    }
    if not cfg.gated_mlp:
        spec.pop("wi_gate")
    return spec


def _capacity(cfg: ModelConfig, group_tokens: int) -> int:
    m = cfg.moe
    c = int(m.top_k * m.capacity_factor * group_tokens / m.num_experts)
    return max(c, 1)


def moe_block(
    params: Dict, x: jax.Array, cfg: ModelConfig, constrain=None
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    if constrain is None:
        constrain = lambda t, name: t
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    ct = jnp.dtype(cfg.dtype)

    # --- grouping: per-sequence for train/prefill, one global group for decode
    if S > 1:
        G, N = B, S
        xg = x
    else:
        G, N = 1, B
        xg = x.reshape(1, B, d)
    C = _capacity(cfg, N)

    # --- routing (f32 numerics)
    logits = common.dense(xg, params["router"], "float32")  # (G, N, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)  # (G, N, K)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # --- load-balancing auxiliary loss (Switch-style)
    density = jnp.mean(
        jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = m.aux_loss_weight * E * jnp.sum(density * mean_prob)

    # --- capacity-bounded position of each assignment within its expert
    a = top_i.reshape(G, N * K)                       # expert id per assignment
    onehot = jax.nn.one_hot(a, E, dtype=jnp.int32)    # (G, N*K, E)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=1) - 1, a[..., None], axis=-1
    )[..., 0]                                          # (G, N*K)
    keep = pos < C
    dest = jnp.where(keep, a * C + pos, E * C)        # E*C = drop slot

    # --- scatter tokens into (G, E*C [+1 drop], d) expert buffers
    # token t appears K times contiguously -> order (t0k0,t0k1,t1k0,...)
    xk = jnp.broadcast_to(xg[:, :, None, :], (G, N, K, d)).reshape(G, N * K, d)
    buf = jnp.zeros((G, E * C + 1, d), ct)
    buf = jax.vmap(lambda b, i, v: b.at[i].add(v))(buf, dest, xk.astype(ct))
    expert_in = buf[:, : E * C].reshape(G, E, C, d)
    expert_in = constrain(expert_in, "moe_buffer")  # groups follow the batch

    # --- expert FFN (batched einsum over the expert dim -> EP under GSPMD)
    if cfg.gated_mlp:
        g = jnp.einsum("gecd,edf->gecf", expert_in, params["wi_gate"].astype(ct))
        u = jnp.einsum("gecd,edf->gecf", expert_in, params["wi_up"].astype(ct))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(
            jnp.einsum("gecd,edf->gecf", expert_in, params["wi_up"].astype(ct))
        )
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(ct))
    expert_out = constrain(expert_out, "moe_buffer")

    # --- gather back and combine with router weights
    flat = jnp.concatenate(
        [expert_out.reshape(G, E * C, d), jnp.zeros((G, 1, d), ct)], axis=1
    )
    picked = jnp.take_along_axis(flat, dest[..., None], axis=1)  # (G, N*K, d)
    w = (top_w.reshape(G, N * K) * keep).astype(ct)
    out = jnp.sum(picked.reshape(G, N, K, d) * w.reshape(G, N, K, 1), axis=2)
    return out.reshape(B, S, d), aux
