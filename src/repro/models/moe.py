"""Mixture-of-Experts block: top-k routing with capacity-bounded scatter
dispatch.

Why scatter dispatch (not the Switch-Transformer one-hot einsum): at our
assigned shapes (256×4096 tokens, 8–16 experts) the (tokens, E, C) dispatch
mask is terabytes; the scatter formulation is O(tokens · d) and lowers to
a dynamic-scatter + all-to-all under GSPMD when experts are sharded over the
``model`` mesh axis — the expert-parallel schedule real MoE frameworks use.

Tokens are dispatched within *groups* (one group per sequence for training,
one global group for decode) so that dispatch never mixes tokens across the
``data``-sharded batch dim, keeping the scatter local to a data shard.
Over-capacity tokens are dropped (standard capacity-factor semantics); the
residual connection keeps dropped tokens alive downstream.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.models import common
from repro.models.common import ParamSpec


def moe_spec(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    assert cfg.moe is not None
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    spec = {
        "router": ParamSpec((d, e), ("embed", "experts")),
        "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "ffn")),
        "wi_up": ParamSpec((e, d, f), ("experts", "embed", "ffn")),
        "wo": ParamSpec((e, f, d), ("experts", "ffn", "embed")),
    }
    if not cfg.gated_mlp:
        spec.pop("wi_gate")
    return spec


def _capacity(cfg: ModelConfig, group_tokens: int) -> int:
    m = cfg.moe
    c = int(m.top_k * m.capacity_factor * group_tokens / m.num_experts)
    return max(c, 1)


def _dispatch_experts(params, xk, a, onehot, keep, cap: int, cfg, constrain):
    """Shared expert-dispatch core: scatter assignments into capacity-``cap``
    per-expert buffers, run the expert FFN, gather back.

    xk: (G, A, d) one row per assignment; a: (G, A) expert ids;
    onehot: (G, A, E) int32 of ``a``; keep: (G, A) bool pre-drop decision
    (all-True for the forward, the counter comparison for decode).
    Dropped assignments consume no buffer slots. Returns
    (picked (G, A, d) expert outputs, keep after buffer-overflow drops).
    """
    G, A, d = xk.shape
    E = cfg.moe.num_experts
    ct = jnp.dtype(cfg.dtype)
    pos = jnp.take_along_axis(
        jnp.cumsum(onehot * keep[..., None], axis=1) - 1, a[..., None], axis=-1
    )[..., 0]                                         # (G, A)
    keep = keep & (pos < cap)
    dest = jnp.where(keep, a * cap + pos, E * cap)    # E*cap = drop slot
    buf = jnp.zeros((G, E * cap + 1, d), ct)
    buf = jax.vmap(lambda b, i, v: b.at[i].add(v))(buf, dest, xk.astype(ct))
    expert_in = buf[:, : E * cap].reshape(G, E, cap, d)
    expert_in = constrain(expert_in, "moe_buffer")    # groups follow the batch

    # expert FFN (batched einsum over the expert dim -> EP under GSPMD)
    if cfg.gated_mlp:
        g = jnp.einsum("gecd,edf->gecf", expert_in, params["wi_gate"].astype(ct))
        u = jnp.einsum("gecd,edf->gecf", expert_in, params["wi_up"].astype(ct))
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(
            jnp.einsum("gecd,edf->gecf", expert_in, params["wi_up"].astype(ct))
        )
    expert_out = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(ct))
    expert_out = constrain(expert_out, "moe_buffer")

    flat = jnp.concatenate(
        [expert_out.reshape(G, E * cap, d), jnp.zeros((G, 1, d), ct)], axis=1
    )
    picked = jnp.take_along_axis(flat, dest[..., None], axis=1)  # (G, A, d)
    return picked, keep


def moe_load_spec(cfg: ModelConfig, batch: int) -> ParamSpec:
    """Per-sequence expert assignment counters carried in the decode cache.

    ``load[b, e]`` counts how many assignments sequence ``b`` has routed to
    expert ``e`` so far — kept AND capacity-dropped, matching the cumsum
    positions a full forward would compute. :func:`moe_decode_block` replays
    the forward's keep/drop decision from these counters, which is what makes
    autoregressive decode consistent with the teacher-forced forward.
    """
    assert cfg.moe is not None
    return ParamSpec(
        (batch, cfg.moe.num_experts), ("batch", None), init="zeros", dtype="int32"
    )


def moe_block(
    params: Dict, x: jax.Array, cfg: ModelConfig, constrain=None
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar, load (B, E) int32)."""
    if constrain is None:
        constrain = lambda t, name: t
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    ct = jnp.dtype(cfg.dtype)

    # --- grouping: per-sequence for train/prefill, one global group for decode
    if S > 1:
        G, N = B, S
        xg = x
    else:
        G, N = 1, B
        xg = x.reshape(1, B, d)
    C = _capacity(cfg, N)

    # --- routing (f32 numerics)
    logits = common.dense(xg, params["router"], "float32")  # (G, N, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)  # (G, N, K)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # --- load-balancing auxiliary loss (Switch-style)
    density = jnp.mean(
        jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = m.aux_loss_weight * E * jnp.sum(density * mean_prob)

    # --- flatten to one row per assignment
    # token t appears K times contiguously -> order (t0k0,t0k1,t1k0,...)
    a = top_i.reshape(G, N * K)                       # expert id per assignment
    onehot = jax.nn.one_hot(a, E, dtype=jnp.int32)    # (G, N*K, E)
    xk = jnp.broadcast_to(xg[:, :, None, :], (G, N, K, d)).reshape(G, N * K, d)

    # per-sequence assignment counters (B, E) for the decode cache
    if S > 1:
        load = jnp.sum(onehot, axis=1)                       # groups ARE sequences
    else:
        load = jnp.sum(onehot.reshape(B, K, E), axis=1)      # one token per seq

    # --- dispatch with capacity C; drops come from buffer positions only
    picked, keep = _dispatch_experts(
        params, xk, a, onehot, jnp.ones_like(a, bool), C, cfg, constrain
    )
    w = (top_w.reshape(G, N * K) * keep).astype(ct)
    out = jnp.sum(picked.reshape(G, N, K, d) * w.reshape(G, N, K, 1), axis=2)
    return out.reshape(B, S, d), aux, load


def moe_decode_block(
    params: Dict,
    x: jax.Array,
    load: jax.Array,
    pos: jax.Array,
    cfg: ModelConfig,
    constrain=None,
    packing: str = "sequence",
) -> Tuple[jax.Array, jax.Array]:
    """Single-token MoE step with forward-consistent capacity routing.

    x: (B, 1, d); load: (B, E) int32 counters from :func:`moe_load_spec`;
    pos: scalar int32 absolute position. Returns (out (B, 1, d), new load).

    A full forward over a sequence of length N drops an assignment when its
    arrival position within its expert (the per-sequence cumsum) reaches
    C(N) = max(floor(k · cf · N / E), 1). The counters carry exactly that
    arrival position across steps, so decoding token ``pos`` keeps/drops the
    same assignments a length-(pos+1) forward would — without them, decode
    routes with fresh capacity and diverges from the forward whenever an
    expert overflows (the seed's phi3.5-moe prefill/decode failure).

    ``packing`` selects how assignments are packed into expert buffers:

    * ``"sequence"`` (default) — one group per sequence, mirroring the
      full forward's train/prefill grouping. Top-k experts are distinct
      within a token, so one buffer slot per (sequence, expert) can never
      overflow: keep/drop is decided by the counters ALONE, and a batched
      decode step serves exactly the tokens a per-sequence decode would.
    * ``"global"`` — legacy single global group with a static
      ``c_pack = ceil(k · cf · B / E)`` capacity over the decode batch.
      When more than ``c_pack`` sequences route a counter-kept assignment
      to the same expert in one step, the overflow IS dropped — a
      cross-sequence deviation from the teacher-forced forward, pinned as
      a regression in tests/test_moe_decode_load.py. B=1 is always exact.
    """
    if constrain is None:
        constrain = lambda t, name: t
    m = cfg.moe
    B, S, d = x.shape
    assert S == 1, "moe_decode_block handles one token per step"
    E, K = m.num_experts, m.top_k
    ct = jnp.dtype(cfg.dtype)

    # --- routing (f32 numerics, same as the full forward)
    logits = common.dense(x[:, 0], params["router"], "float32")  # (B, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_i = jax.lax.top_k(probs, K)                       # (B, K)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # forward-equivalent capacity for a sequence of length pos+1
    c_seq = jnp.maximum(
        jnp.floor(K * m.capacity_factor * (pos + 1).astype(jnp.float32) / E),
        1.0,
    ).astype(jnp.int32)
    prior = jnp.take_along_axis(load, top_i, axis=1)             # (B, K)
    keep = prior < c_seq
    onehot_seq = jax.nn.one_hot(top_i, E, dtype=jnp.int32)       # (B, K, E)
    new_load = load + jnp.sum(onehot_seq, axis=1).astype(load.dtype)

    if packing == "sequence":
        # One group per sequence (the full forward's grouping): dispatch
        # never mixes tokens across the batch, so a contended expert
        # cannot overflow the pack buffer and drop another sequence's
        # counter-kept assignment. Distinct top-k experts per token mean
        # one slot per (sequence, expert) suffices.
        xk = jnp.broadcast_to(x.reshape(B, 1, d), (B, K, d))
        picked, keep_flat = _dispatch_experts(
            params, xk, top_i, onehot_seq, keep, 1, cfg, constrain
        )
        w = (top_w * keep_flat).astype(ct)
        out = jnp.sum(picked * w[..., None], axis=1)
    elif packing == "global":
        # legacy: pack all B decode tokens into one global group with a
        # static batch-derived capacity; cross-sequence overflow drops
        c_pack = max(int(np.ceil(K * m.capacity_factor * B / E)), 1)
        a = top_i.reshape(1, B * K)
        onehot = jax.nn.one_hot(a, E, dtype=jnp.int32)           # (1, B*K, E)
        xk = jnp.broadcast_to(x.reshape(B, 1, d), (B, K, d)).reshape(1, B * K, d)
        picked, keep_flat = _dispatch_experts(
            params, xk, a, onehot, keep.reshape(1, B * K), c_pack, cfg, constrain
        )
        w = (top_w.reshape(1, B * K) * keep_flat).astype(ct)
        out = jnp.sum(picked.reshape(B, K, d) * w.reshape(B, K, 1), axis=1)
    else:
        raise ValueError(f"unknown packing {packing!r}")
    return out.reshape(B, 1, d), new_load
