"""``repro.dist`` — elastic sharding over JAX meshes.

The contract in one paragraph: models describe every parameter with
*logical* dim names (``repro.models.common.LOGICAL_AXES``) and never name
mesh axes; :mod:`repro.dist.sharding` resolves logical dims to mesh axes
through per-layout rule tables (``PARAM_RULES``) with divisibility
fallbacks (indivisible dim -> drop the axis; a mesh axis is used at most
once per tensor; ``layers``/``groups`` scan dims are never sharded;
size-1 dims replicate); :mod:`repro.dist.elastic` moves live state between
meshes when the spot provisioner shrinks or grows the device pool, so a
revocation costs a reshard — not a checkpoint restore; and
:mod:`repro.dist.meshplan` prices that claim: it turns the market's
instance menu into concrete meshes (``ElasticMeshManager``) and computes
``reshard_bytes`` (slice-overlap bytes actually moved) against
``tree_bytes`` (what a checkpoint restore would pull through storage).

Resharding and resolution are pure functions of ``(specs, mesh, layout)``:
the same call sites serve the (16, 16) production pod, the (2, 16, 16)
multi-pod mesh, the elastic subprocess meshes, and the single-CPU host
mesh in tests.
"""
from repro.dist.elastic import replicate, reshard_params, reshard_tree
from repro.dist.meshplan import (
    ElasticMeshManager,
    MeshPlan,
    leg_state_bytes,
    live_shardings,
    mesh_shape_for,
    reshard_bytes,
    serve_state_bytes,
    train_state_bytes,
    tree_bytes,
)
from repro.dist.sharding import (
    PARAM_RULES,
    batch_shardings,
    cache_shardings,
    make_activation_constrainer,
    opt_state_shardings,
    param_shardings,
    resolve_pspec,
)

__all__ = [
    "ElasticMeshManager",
    "MeshPlan",
    "PARAM_RULES",
    "batch_shardings",
    "leg_state_bytes",
    "live_shardings",
    "mesh_shape_for",
    "reshard_bytes",
    "serve_state_bytes",
    "train_state_bytes",
    "tree_bytes",
    "cache_shardings",
    "make_activation_constrainer",
    "opt_state_shardings",
    "param_shardings",
    "replicate",
    "reshard_params",
    "reshard_tree",
    "resolve_pspec",
]
