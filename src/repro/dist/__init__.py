"""``repro.dist`` — elastic sharding over JAX meshes.

The contract in one paragraph: models describe every parameter with
*logical* dim names (``repro.models.common.LOGICAL_AXES``) and never name
mesh axes; :mod:`repro.dist.sharding` resolves logical dims to mesh axes
through per-layout rule tables (``PARAM_RULES``) with divisibility
fallbacks (indivisible dim -> drop the axis; a mesh axis is used at most
once per tensor; ``layers``/``groups`` scan dims are never sharded;
size-1 dims replicate); :mod:`repro.dist.elastic` moves live state between
meshes when the spot provisioner shrinks or grows the device pool, so a
revocation costs a reshard — not a checkpoint restore.

Resharding and resolution are pure functions of ``(specs, mesh, layout)``:
the same call sites serve the (16, 16) production pod, the (2, 16, 16)
multi-pod mesh, the elastic subprocess meshes, and the single-CPU host
mesh in tests.
"""
from repro.dist.elastic import replicate, reshard_params, reshard_tree
from repro.dist.sharding import (
    PARAM_RULES,
    batch_shardings,
    cache_shardings,
    make_activation_constrainer,
    opt_state_shardings,
    param_shardings,
    resolve_pspec,
)

__all__ = [
    "PARAM_RULES",
    "batch_shardings",
    "cache_shardings",
    "make_activation_constrainer",
    "opt_state_shardings",
    "param_shardings",
    "replicate",
    "reshard_params",
    "reshard_tree",
    "resolve_pspec",
]
