"""Rule-based sharding-spec resolution: logical dims -> mesh axes.

Every :class:`~repro.models.common.ParamSpec` names its dims with *logical*
axes (``"embed"``, ``"ffn"``, ``"vocab"``, ...) drawn from
``repro.models.common.LOGICAL_AXES``.  This module owns the only place where
logical names meet mesh axis names: a rule table per
:class:`~repro.config.base.ShardingLayout` preset maps each logical dim to an
ordered tuple of candidate mesh axes, and :func:`resolve_pspec` turns one
``(shape, dim_names)`` pair into a :class:`jax.sharding.PartitionSpec` under
the fallback discipline below.

Resolution contract (enforced by ``tests/test_sharding.py``):

* **divisibility** — a mesh axis (or joint axis tuple) is only used when its
  size divides the dim exactly; otherwise axes are dropped (left-first for
  joint tuples) until the remainder divides, down to ``None`` (replicated).
* **one use per tensor** — a mesh axis appears at most once in a spec; dims
  are resolved left-to-right and later dims skip already-used axes.
* **scan dims** — ``"layers"`` / ``"groups"`` (lax.scan stacking dims) are
  never sharded: every device runs every layer.
* **degenerate dims** — a dim of size 1 (e.g. batch=1 decode) replicates.

Rule values are tuples so one logical dim can shard jointly over several
mesh axes (``"batch" -> ("pod", "data")`` on the 2-pod mesh); axes missing
from the mesh are simply ignored, which is how the same table serves the
(16, 16) production mesh, the (2, 16, 16) multi-pod mesh, and the (1, 1)
host mesh in tests.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config.base import ModelConfig, ShardingLayout
from repro.models.common import LOGICAL_AXES, SCAN_AXES, ParamSpec

# Mesh axes that carry data parallelism, outermost first.
DATA_AXES = ("pod", "data")

Rule = Dict[str, Tuple[str, ...]]


def _rule(**overrides: Tuple[str, ...]) -> Rule:
    """Baseline FSDP+TP rule set with per-logical-dim overrides."""
    base: Rule = {
        # embedding / residual width shards over the data axis (FSDP-style
        # parameter sharding: the gradient all-reduce doubles as the gather)
        "embed": ("data",),
        "enc_embed": ("data",),
        "vit_embed": ("model",),
        # big per-layer matmul dims shard over the model (TP) axis
        "vocab": ("model",),
        "q_dim": ("model",),
        "kv_dim": ("model",),
        "ffn": ("model",),
        "experts": ("model",),
        "ssm_inner": ("model",),
        "dt_rank": (),
        "ssm_state": (),
        "conv": (),
        # activation dims
        "batch": ("pod", "data"),
        "seq": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": (),
    }
    base.update(overrides)
    unknown = set(base) - set(LOGICAL_AXES)
    assert not unknown, f"rules name unknown logical dims: {unknown}"
    return base


PARAM_RULES: Dict[str, Rule] = {
    "baseline": _rule(),
    # pure tensor parallelism: params replicated across data shards
    "tp_only": _rule(embed=(), enc_embed=()),
    # shard everything possible over data first, joint data+model on ffn
    "fsdp_heavy": _rule(
        vocab=("data", "model"), ffn=("data", "model"), experts=()
    ),
    # tensor-parallel experts: replicate the expert dim, split each expert's
    # ffn over the model axis (all-reduce instead of all-to-all)
    "moe_tp": _rule(experts=(), ffn=("model",)),
}


def _mesh_sizes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def _fit_axes(dim: int, candidates, sizes: Dict[str, int], used: set):
    """The fallback discipline, shared by params and activation constraints:
    keep only mesh axes not yet used by this tensor, then drop axes
    (outermost first) until the joint size divides the dim. Marks the
    surviving axes used and returns them as a (possibly empty) tuple."""
    axes = [a for a in candidates if a in sizes and a not in used]
    while axes and dim % math.prod(sizes[a] for a in axes):
        axes = axes[1:]
    used.update(axes)
    return tuple(axes)


def _spec_entry(axes):
    return None if not axes else axes[0] if len(axes) == 1 else tuple(axes)


def resolve_pspec(
    shape: Sequence[int],
    dim_names: Sequence[Optional[str]],
    rules: Rule,
    mesh,
) -> P:
    """Resolve one tensor's logical dims to a PartitionSpec on ``mesh``."""
    assert len(shape) == len(dim_names), (shape, dim_names)
    sizes = _mesh_sizes(mesh)
    used: set = set()
    parts = []
    for dim, name in zip(shape, dim_names):
        if name is None or name in SCAN_AXES or dim <= 1:
            parts.append(None)
            continue
        cand = rules.get(name, ())
        if isinstance(cand, str):
            cand = (cand,)
        parts.append(_spec_entry(_fit_axes(dim, cand, sizes, used)))
    return P(*parts)


def _spec_shardings(specs: Any, mesh, rules: Rule) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, resolve_pspec(s.shape, s.axes, rules, mesh)),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _rules_for(layout: Union[ShardingLayout, str, Rule], key: str = "param_rules") -> Rule:
    if isinstance(layout, dict):
        return layout
    if isinstance(layout, str):
        return PARAM_RULES[layout]
    name = getattr(layout, key, "") or layout.param_rules
    return PARAM_RULES[name]


def param_shardings(specs: Any, mesh, layout: Union[ShardingLayout, str]) -> Any:
    """NamedSharding pytree (same structure as ``specs``) for the params."""
    return _spec_shardings(specs, mesh, _rules_for(layout))


def opt_state_shardings(specs: Any, mesh, layout: ShardingLayout) -> Any:
    """Shardings for one optimizer-moment tree (Adam m/v mirror the params).

    ``layout.opt_rules`` overrides the param rules — e.g. ZeRO-1 keeps
    params tp_only but moments fully sharded ("baseline").
    """
    return _spec_shardings(specs, mesh, _rules_for(layout, key="opt_rules"))


def cache_shardings(cache_specs: Any, mesh, layout: ShardingLayout) -> Any:
    """Shardings for the decode cache. Cache specs carry their own logical
    dims (``batch``/``seq``/``kv_heads``/...); the seq (slot) dim shards over
    the model axis — ``cache_len_for`` rounds it to a multiple of 16 so this
    always divides on the production mesh."""
    return _spec_shardings(cache_specs, mesh, _rules_for(layout))


def batch_shardings(inputs: Dict[str, Any], mesh) -> Dict[str, NamedSharding]:
    """Input-batch shardings: leading dim over the data axes, rest replicated.

    A batch of 1 (single-sequence decode) replicates — the divisibility
    fallback in :func:`resolve_pspec` makes that automatic.
    """
    rules = PARAM_RULES["baseline"]

    def one(x) -> NamedSharding:
        names: Tuple[Optional[str], ...] = ("batch",) + (None,) * (len(x.shape) - 1)
        return NamedSharding(mesh, resolve_pspec(x.shape, names, rules, mesh))

    return {k: one(v) for k, v in inputs.items()}


# ---------------------------------------------------------------------------
# Activation sharding constraints
# ---------------------------------------------------------------------------

def _concat_axes(*entries):
    """Merge spec entries into one PartitionSpec slot (str | tuple | None)."""
    flat = []
    for e in entries:
        if e is None:
            continue
        flat.extend(e if isinstance(e, tuple) else (e,))
    if not flat:
        return None
    return flat[0] if len(flat) == 1 else tuple(flat)


def make_activation_constrainer(mesh, layout: ShardingLayout, cfg: ModelConfig):
    """Build the ``constrain(x, name) -> x`` hook threaded through the model.

    Named sites (see ``RunOpts.constrain`` call sites):

    * ``"activation"``  — residual stream (B, S, d): batch over data axes,
      sequence over the model axis when ``sequence_shard_activations``
      (Megatron-SP): attention/MLP FLOPs then partition over BOTH mesh axes.
    * ``"attn_qkv"``    — K/V (B, S, KVH, hd): gathered over sequence when
      ``attn_gather_kv`` (one all-gather per layer instead of a ring).
    * ``"loss_input"``  — pre-unembed hiddens: sequence gathered so the
      chunked CE scan slices an unsharded dim.
    * ``"moe_buffer"``  — (G, E, C, d) expert buffers: groups follow the
      batch shards, experts follow the model axis (expert parallelism).

    Constraints silently drop mesh axes that do not divide the concrete dim
    (same fallback discipline as :func:`resolve_pspec`), so the constrainer
    is safe on the (1, 1) host mesh and reduced smoke shapes.
    """
    sizes = _mesh_sizes(mesh)
    data = tuple(a for a in DATA_AXES if a in sizes)
    data_entry = _concat_axes(data if data else None)
    model = "model" if "model" in sizes else None
    seq_entry = model if layout.sequence_shard_activations else None

    def _fit(x, parts):
        fitted, used = [], set()
        for dim, part in zip(x.shape, parts):
            cand = part if isinstance(part, tuple) else (part,) if part else ()
            fitted.append(_spec_entry(_fit_axes(dim, cand, sizes, used)))
        return P(*fitted)

    def constrain(x, name: str):
        if name == "activation" and x.ndim == 3:
            parts = (data_entry, seq_entry, None)
        elif name == "loss_input" and x.ndim == 3:
            parts = (data_entry, None, None)
        elif name == "attn_qkv" and x.ndim == 4:
            kv_seq = None if layout.attn_gather_kv else seq_entry
            parts = (data_entry, kv_seq, None, None)
        elif name == "moe_buffer" and x.ndim == 4:
            parts = (data_entry, model, None, None)
        else:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, _fit(x, parts))
        )

    return constrain
