"""Mesh planning for market-menu elastic provisioning.

The provisioner's instance menu (``repro.core.market.InstanceShape``)
describes each market as ``device_count`` accelerators behind an
interconnect; this module turns that description into something the
training stack can run on and *price*:

* :func:`mesh_shape_for` — deterministic (data, model) factorization of a
  device count (model axis = largest power of two ≤ √n that divides n, so
  1→(1,1), 2→(2,1), 4→(2,2), 8→(4,2)),
* :class:`MeshPlan` / :class:`ElasticMeshManager` — build and cache one
  concrete ``jax.sharding.Mesh`` per honored device count from the local
  device pool (menu shapes larger than the pool are capped — the local
  pool *simulates* the market's instance), and resolve the old-vs-new
  sharding trees for a migration,
* :func:`reshard_bytes` — the byte-level cost model of a live cross-mesh
  reshard: for every leaf, every destination device pays only for the
  slice elements it does not already hold under the source sharding
  (exact slice-overlap arithmetic over ``devices_indices_map``). Identical
  shardings therefore cost 0 bytes; any migration costs at most
  :func:`tree_bytes` — the full state size a checkpoint restore would pull
  through remote storage. That inequality, in bytes, is the paper's
  "no-FT is cheaper" claim made quantitative.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np


def mesh_shape_for(n_devices: int) -> Tuple[int, int]:
    """Deterministic (data, model) factorization of ``n_devices``."""
    n = max(int(n_devices), 1)
    # model axis: largest power of two m with m*m <= n and n % m == 0
    m = 1
    while (m * 2) * (m * 2) <= n and n % (m * 2) == 0:
        m *= 2
    return (n // m, m)


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """One menu shape — or one multi-leg allocation — made concrete on the
    local device pool. ``leg_spans`` maps each allocation leg to its
    contiguous range of (honored) device indices in
    ``mesh.devices.flatten()``; single-market plans have one span covering
    the whole mesh."""

    requested_devices: int          # the menu's device_count
    device_count: int               # honored (capped to the local pool)
    mesh_shape: Tuple[int, int]     # (data, model)
    axes: Tuple[str, str]
    mesh: Any                       # jax.sharding.Mesh
    leg_spans: Tuple[Tuple[int, int], ...] = ()

    def __post_init__(self):
        if not self.leg_spans:
            object.__setattr__(self, "leg_spans", ((0, self.device_count),))

    @property
    def key(self) -> Tuple[int, Tuple[int, int]]:
        """Identity of the *execution* substrate (honored count + shape).

        Deliberately leg-blind: a 4+4 split and a single 8-device market
        compile to the SAME mesh, so re-provisioning between them reuses
        the jitted step and moves zero bytes of layout — only the
        DCN-crossing leg bytes (``leg_state_bytes``) differ, and those are
        billed by the orchestrator, not the compiler."""
        return (self.device_count, self.mesh_shape)


class ElasticMeshManager:
    """Builds and caches one mesh per honored device count.

    The pool is the local accelerator set (tests/benches: host CPUs forced
    via ``XLA_FLAGS``); a menu shape asking for more devices than the pool
    holds is capped — two menu shapes that cap to the same count share one
    mesh, so re-provisioning between them is a zero-byte reshard.
    """

    def __init__(self, devices: Optional[Sequence[Any]] = None):
        self.devices: List[Any] = list(devices if devices is not None else jax.devices())
        self._plans: Dict[int, MeshPlan] = {}
        self._alloc_plans: Dict[Tuple[int, ...], MeshPlan] = {}

    @classmethod
    def from_mesh(cls, mesh) -> "ElasticMeshManager":
        return cls(devices=list(np.asarray(mesh.devices).flatten()))

    def plan_for(self, device_count: int) -> MeshPlan:
        n = max(1, min(int(device_count), len(self.devices)))
        plan = self._plans.get(n)
        if plan is None:
            shape = mesh_shape_for(n)
            devs = np.asarray(self.devices[:n], dtype=object).reshape(shape)
            mesh = jax.sharding.Mesh(devs, ("data", "model"))
            plan = MeshPlan(
                requested_devices=int(device_count),
                device_count=n,
                mesh_shape=shape,
                axes=("data", "model"),
                mesh=mesh,
            )
            self._plans[n] = plan
        return plan

    def plan_for_allocation(self, device_counts: Sequence[int]) -> MeshPlan:
        """One mesh spanning every leg of a multi-leg allocation.

        The union mesh is built over the summed device count (capped to the
        local pool — the pool *simulates* the federated instances) with
        contiguous per-leg device spans recorded in ``leg_spans``; honored
        leg sizes are the proportional split of the capped total, so an
        (8, 8) allocation on an 8-device pool simulates as (4, 4). A
        single-leg allocation delegates to :meth:`plan_for` — the identical
        cached plan object the pre-allocation orchestrator used. When the
        pool has fewer devices than the allocation has legs, trailing legs
        collapse to empty spans (a 1-device pool cannot represent a split;
        byte accounting then degenerates to zero for those legs)."""
        counts = [max(int(c), 1) for c in device_counts]
        if len(counts) == 1:
            return self.plan_for(counts[0])
        total = sum(counts)
        honored_total = max(1, min(total, len(self.devices)))
        # proportional, deterministic rounding: floor shares, then hand the
        # remainder to the widest legs first (ties: leg order)
        shares = [honored_total * c // total for c in counts]
        rest = honored_total - sum(shares)
        order = sorted(range(len(counts)), key=lambda i: (-counts[i], i))
        for i in order:
            if rest <= 0:
                break
            shares[i] += 1
            rest -= 1
        key = tuple(shares)
        plan = self._alloc_plans.get(key)
        if plan is None:
            shape = mesh_shape_for(honored_total)
            devs = np.asarray(
                self.devices[:honored_total], dtype=object
            ).reshape(shape)
            mesh = jax.sharding.Mesh(devs, ("data", "model"))
            spans, lo = [], 0
            for s in shares:
                spans.append((lo, lo + s))
                lo += s
            plan = MeshPlan(
                requested_devices=int(total),
                device_count=honored_total,
                mesh_shape=shape,
                axes=("data", "model"),
                mesh=mesh,
                leg_spans=tuple(spans),
            )
            self._alloc_plans[key] = plan
        return plan


# ---------------------------------------------------------------------------
# Measured throughput per mesh shape
# ---------------------------------------------------------------------------

class ThroughputTracker:
    """EMA of measured steps/sec per :attr:`MeshPlan.key`.

    The provisioner's menu predicts each shape's relative speed analytically
    (``repro.core.market.shape_throughput``); the orchestrator records what
    ``run_segment`` actually delivered per mesh shape here and uses
    :meth:`correction` to scale the analytic prediction by the measured
    deviation — so a shape that scales worse than the model's efficiency
    exponent stops looking cheap-per-step after one segment on it.
    """

    def __init__(self, ema: float = 0.5):
        self.ema = ema
        self._sps: Dict[Any, float] = {}

    def observe(self, key, steps: int, seconds: float) -> None:
        if steps <= 0 or seconds <= 0:
            return
        sps = steps / seconds
        prev = self._sps.get(key)
        self._sps[key] = sps if prev is None else self.ema * sps + (1 - self.ema) * prev

    def steps_per_sec(self, key) -> Optional[float]:
        return self._sps.get(key)

    @property
    def measured(self) -> Dict[Any, float]:
        return dict(self._sps)

    def correction(self, key, analytic: Dict[Any, float]) -> float:
        """Measured-vs-analytic speed ratio for ``key``, relative to the
        slowest-predicted observed shape (which anchors the scale).

        ``analytic`` maps plan keys to the model's predicted relative
        throughput. Returns 1.0 until two distinct shapes have been
        measured — a single observation fixes the anchor, not a ratio."""
        if key not in self._sps or len(self._sps) < 2:
            return 1.0
        ref = min(self._sps, key=lambda k: analytic.get(k, 1.0))
        if ref == key:
            return 1.0
        predicted = analytic.get(key, 1.0) / max(analytic.get(ref, 1.0), 1e-9)
        observed = self._sps[key] / max(self._sps[ref], 1e-9)
        return observed / max(predicted, 1e-9)


# ---------------------------------------------------------------------------
# Byte-level reshard cost
# ---------------------------------------------------------------------------

def _norm_index(idx: Tuple, shape: Tuple[int, ...]) -> Tuple[Tuple[int, int], ...]:
    """Normalize a devices_indices_map entry to ((start, stop), ...) pairs."""
    out = []
    for sl, dim in zip(idx, shape):
        start, stop, step = sl.indices(dim)
        assert step == 1, "strided shards unsupported"
        out.append((start, stop))
    return tuple(out)


def _volume(norm: Tuple[Tuple[int, int], ...]) -> int:
    v = 1
    for start, stop in norm:
        v *= max(stop - start, 0)
    return v


def _overlap(a, b) -> int:
    v = 1
    for (a0, a1), (b0, b1) in zip(a, b):
        v *= max(min(a1, b1) - max(a0, b0), 0)
    return v


def _leaf_moved_bytes(leaf, old_sharding, new_sharding) -> int:
    """Bytes a migration must move for one leaf: every destination device
    pays for the part of its new slice it does not already hold locally."""
    shape = tuple(leaf.shape)
    itemsize = np.dtype(leaf.dtype).itemsize
    if old_sharding == new_sharding:
        return 0
    old_map = {
        d: _norm_index(idx, shape)
        for d, idx in old_sharding.devices_indices_map(shape).items()
    }
    new_map = new_sharding.devices_indices_map(shape)
    moved = 0
    for dev, idx in new_map.items():
        need = _norm_index(idx, shape)
        have = old_map.get(dev)
        vol = _volume(need)
        if have is not None:
            vol -= _overlap(need, have)
        moved += max(vol, 0) * itemsize
    return moved


def reshard_bytes(tree: Any, old_shardings: Any, new_shardings: Any) -> int:
    """Bytes actually moved by resharding ``tree`` from ``old_shardings``
    to ``new_shardings`` — leaf-by-leaf slice-overlap accounting.

    Leaves of ``tree`` only need ``.shape``/``.dtype`` (live arrays,
    ShapeDtypeStructs, or ParamSpecs via ``abstract_params`` all work), so
    the cost is computable *before* committing to a migration. Compare with
    :func:`tree_bytes` — what a checkpoint restore moves through storage.
    """
    total = 0
    leaves, _ = jax.tree_util.tree_flatten(tree)
    old_leaves = jax.tree_util.tree_leaves(old_shardings)
    new_leaves = jax.tree_util.tree_leaves(new_shardings)
    assert len(leaves) == len(old_leaves) == len(new_leaves)
    for leaf, old, new in zip(leaves, old_leaves, new_leaves):
        total += _leaf_moved_bytes(leaf, old, new)
    return int(total)


def leg_state_bytes(tree: Any, shardings: Any, plan: MeshPlan, leg_index: int) -> int:
    """Bytes that must cross the DCN to rebuild ONE lost allocation leg.

    When a leg of a multi-leg allocation is revoked, the surviving legs
    still hold their shards; only the replacement leg starts empty. What
    crosses the DCN is the set of DISTINCT array slices the new leg's
    devices hold under ``shardings`` — each distinct slice is sent once
    and fanned out over the leg's own interconnect, so intra-leg replicas
    don't re-cross the wide-area link. Compare: a full checkpoint restore
    pulls :func:`tree_bytes` (every leaf in full) through remote storage,
    and a full cross-mesh reshard re-materializes every device. For any
    layout that shards state across the data axis (FSDP/ZeRO), a leg's
    distinct-slice volume is a strict fraction of the full state — the
    byte-level sense in which a one-leg revocation is cheaper than losing
    the whole allocation.
    """
    lo, hi = plan.leg_spans[leg_index]
    flat = np.asarray(plan.mesh.devices, dtype=object).flatten()
    leg_devices = {id(d): d for d in flat[lo:hi]}
    total = 0
    leaves = jax.tree_util.tree_leaves(tree)
    sh_leaves = jax.tree_util.tree_leaves(shardings)
    assert len(leaves) == len(sh_leaves)
    for leaf, sh in zip(leaves, sh_leaves):
        shape = tuple(leaf.shape)
        itemsize = np.dtype(leaf.dtype).itemsize
        seen = set()
        for dev, idx in sh.devices_indices_map(shape).items():
            if id(dev) not in leg_devices:
                continue
            norm = _norm_index(idx, shape)
            if norm not in seen:
                seen.add(norm)
                total += _volume(norm) * itemsize
    return int(total)


def live_shardings(tree: Any) -> Any:
    """The shardings a live pytree is currently laid out with."""
    return jax.tree_util.tree_map(lambda x: x.sharding, tree)


def tree_bytes(tree: Any) -> int:
    """Full byte size of a pytree — what a checkpoint restore transfers."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return int(total)


def train_state_bytes(model) -> int:
    """Param + Adam moment footprint of a model's TrainState, in bytes.

    ``3 ×`` the param bytes: the fp32 master params plus the two Adam
    moments (m, v) mirror the param tree; scalars are negligible. This is
    the number the orchestrator matches against an instance shape's
    ``memory_gb × device_count`` — replacing the seed's hard-coded 16 GB.
    """
    from repro.models.common import param_bytes

    return 3 * param_bytes(model.specs)


def serve_state_bytes(
    model, batch: int, seq_len: int, *, int8_cache: bool = False
) -> int:
    """Footprint of one INFERENCE replica, in bytes: params once plus the
    KV/decode cache at the configured batch and context length.

    No optimizer state — a serving replica never holds Adam moments, which
    is why it is strictly smaller than :func:`train_state_bytes` for the
    same model and why a replica migration is params-only. This is the
    number the fleet provisioner (``repro.serve.fleet``) matches against
    an instance shape's total memory.
    """
    from repro.models.common import param_bytes

    cache = model.cache_specs(batch, seq_len, int8=int8_cache)
    return param_bytes(model.specs) + param_bytes(cache)
