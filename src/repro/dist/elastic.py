"""Elastic resharding: move live training state between device meshes.

This is the system-level analogue of the paper's claim that spot
revocations need no fault-tolerance machinery: when the provisioner loses
(or gains) instances, the job's params/opt-state are re-laid-out onto a
mesh over the surviving device pool via :func:`reshard_params` and training
continues — nothing is checkpointed, the state never leaves device/host
memory.

``jax.device_put(x, sharding)`` performs the actual cross-mesh transfer;
it resolves source and destination shardings and issues the minimal
copies. A fallback path materializes through host RAM for backends or
mesh pairs where the direct transfer is unsupported — correct everywhere,
merely slower.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
import numpy as np

from repro.config.base import ShardingLayout
from repro.dist.sharding import param_shardings


def _put(x, sharding) -> jax.Array:
    try:
        return jax.device_put(x, sharding)
    except (ValueError, RuntimeError):
        # cross-mesh direct transfer unsupported: stage through host memory
        return jax.device_put(np.asarray(x), sharding)


def reshard_tree(tree: Any, shardings: Any) -> Any:
    """device_put every leaf of ``tree`` onto the matching sharding leaf."""
    return jax.tree_util.tree_map(_put, tree, shardings)


def replicate(tree: Any, mesh) -> Any:
    """Fully replicate a pytree across every device of ``mesh``."""
    repl = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(lambda x: _put(x, repl), tree)


def reshard_params(params: Any, specs: Any, mesh, layout: ShardingLayout) -> Any:
    """Re-resolve the param shardings on a NEW mesh and move the live params.

    The elastic shrink/grow path: ``specs`` (the model's ParamSpec tree)
    re-resolves against the new mesh's axis sizes — the divisibility
    fallbacks may pick different specs than on the old mesh (e.g. a dim
    that sharded 4-way no longer divides and replicates) — and the params
    are transferred leaf-by-leaf.
    """
    return reshard_tree(params, param_shardings(specs, mesh, layout))
