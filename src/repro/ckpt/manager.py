"""Sharded checkpoint manager: async, atomic, keep-last-k, elastic restore.

Layout on disk (one directory per step):

    <root>/step_<N>.tmp/            # written here first
        manifest.json               # step, tree structure, shapes, dtypes
        arr_<i>.npy                 # one file per leaf (host-gathered)
    <root>/step_<N>/                # atomic os.replace commit

Design points that matter at scale:

* **async** — ``save()`` snapshots the (host-transferred) arrays and hands
  them to a background thread; the training loop never blocks on storage.
* **atomic** — readers only ever see fully-written checkpoints because the
  tmp directory is renamed into place (os.replace) after fsync.
* **keep-last-k** — bounded storage; the newest k commits survive.
* **elastic restore** — ``restore()`` takes target NamedShardings, so a
  checkpoint written on mesh A device_puts straight onto mesh B (different
  pod count / data-parallel width) without a resharding pass.

On a multi-host pod each host would write only its addressable shards
(process-local npy + a shard index in the manifest); on this single-host
container the gather is a no-op, but the API and commit protocol are the
production ones.
"""
from __future__ import annotations

import json
import os
import pathlib
import queue
import shutil
import threading
import time
from typing import Any, List, Optional, Tuple

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._q: "queue.Queue[Optional[Tuple[int, Any]]]" = queue.Queue(maxsize=2)
        self._errors: List[BaseException] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, block: bool = False) -> None:
        """Snapshot to host memory now; write + commit in the background."""
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        self._q.put((int(step), host_tree))
        if block:
            self.wait()

    def wait(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[-1]

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=5.0)

    # ------------------------------------------------------------------
    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, tree = item
            try:
                self._write(step, tree)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _write(self, step: int, tree: Any) -> None:
        tmp = self.root / f"step_{step:010d}.tmp"
        final = self.root / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "leaves": [
                {"file": f"arr_{i}.npy", "shape": list(l.shape), "dtype": str(l.dtype)}
                for i, l in enumerate(leaves)
            ],
            "written_at": time.time(),
        }
        for i, leaf in enumerate(leaves):
            np.save(tmp / f"arr_{i}.npy", leaf, allow_pickle=False)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        # fsync the directory entries before the atomic publish
        fd = os.open(tmp, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s:010d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.root.iterdir():
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
                if (p / "manifest.json").exists():
                    out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: Optional[int] = None,
        shardings: Any = None,
        like: Any = None,
    ) -> Tuple[int, Any]:
        """Load a checkpoint. ``like`` is a structure template (e.g. the
        abstract TrainState) used to unflatten; when omitted the leaf list is
        returned. With ``shardings`` leaves are device_put directly onto the
        target mesh — the elastic-restart path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        import ml_dtypes  # ships with jax; restores bf16/f8 views

        leaves = []
        for rec in manifest["leaves"]:
            arr = np.load(d / rec["file"], allow_pickle=False)
            want = rec["dtype"]
            if str(arr.dtype) != want:
                arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
            leaves.append(arr)
        if like is not None:
            treedef = jax.tree_util.tree_structure(like)
            tree = jax.tree_util.tree_unflatten(treedef, leaves)
        else:
            tree = leaves
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return step, tree
