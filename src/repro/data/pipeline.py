"""Deterministic synthetic LM data pipeline.

Produces (tokens, labels) batches that are a pure function of
``(seed, step, shard)`` — restart/elastic-reshard safe: after a revocation
the pipeline resumes at any step on any data-shard split and yields the
exact same global batch. Labels are next-token targets of a synthetic
Markov-ish stream (token t+1 depends on token t), so small models show a
real decreasing loss curve in the examples.

``Prefetcher`` double-buffers batches on a background thread so host-side
data generation overlaps device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np


class SyntheticLM:
    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        seed: int = 0,
        shard: int = 0,
        num_shards: int = 1,
    ):
        assert global_batch % num_shards == 0
        self.vocab = int(vocab_size)
        self.seq_len = int(seq_len)
        self.global_batch = int(global_batch)
        self.local_batch = global_batch // num_shards
        self.seed = seed
        self.shard = shard
        self.num_shards = num_shards
        # fixed per-dataset transition structure (cheap bigram-ish generator)
        rng = np.random.default_rng(seed)
        self._mix = rng.integers(1, self.vocab, size=(257,), dtype=np.int64)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Global-batch rows [shard*local : (shard+1)*local] for this step."""
        rows = []
        base = self.shard * self.local_batch
        for r in range(self.local_batch):
            rows.append(self._row(step, base + r))
        tokens = np.stack(rows)
        labels = np.concatenate(
            [tokens[:, 1:], tokens[:, :1]], axis=1
        )  # next-token; last wraps (masked-equivalent noise)
        return {"tokens": tokens.astype(np.int32), "labels": labels.astype(np.int32)}

    def _row(self, step: int, row: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=self.seed, spawn_key=(step, row))
        )
        noise = rng.integers(0, 256, size=self.seq_len + 1, dtype=np.int64)
        seq = np.empty(self.seq_len, dtype=np.int64)
        t = noise[0]
        for i in range(self.seq_len):
            t = (self._mix[t % 257] + noise[i + 1] * (i % 7 == 0)) % self.vocab
            seq[i] = t
        return seq

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Double-buffering wrapper: generates batch(step+1) while step runs."""

    def __init__(self, dataset: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.dataset = dataset
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self.dataset.batch(step), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def next(self) -> Dict[str, np.ndarray]:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)
