from repro.data.pipeline import Prefetcher, SyntheticLM

__all__ = ["Prefetcher", "SyntheticLM"]
