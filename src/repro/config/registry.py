"""Architecture + input-shape registry.

``repro.configs`` modules call :func:`register_arch` at import; the launcher
and tests look archs up by id. The four assigned LM shapes are global.
"""
from __future__ import annotations

import importlib
import pkgutil
from typing import Dict, List, Tuple

from repro.config.base import InputShape, ModelConfig

_ARCHS: Dict[str, ModelConfig] = {}

SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", seq_len=4_096, global_batch=256, mode="train"),
    "prefill_32k": InputShape("prefill_32k", seq_len=32_768, global_batch=32, mode="prefill"),
    "decode_32k": InputShape("decode_32k", seq_len=32_768, global_batch=128, mode="decode"),
    "long_500k": InputShape("long_500k", seq_len=524_288, global_batch=1, mode="decode"),
}


def register_arch(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _ARCHS and _ARCHS[cfg.name] != cfg:
        raise ValueError(f"conflicting registration for arch {cfg.name!r}")
    _ARCHS[cfg.name] = cfg
    return cfg


def _ensure_loaded() -> None:
    """Import every module under repro.configs exactly once."""
    import repro.configs as pkg

    for mod in pkgutil.iter_modules(pkg.__path__):
        importlib.import_module(f"repro.configs.{mod.name}")


def get_arch(name: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_ARCHS)}") from None


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_ARCHS)


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


def list_shapes() -> List[str]:
    return list(SHAPES)


def runnable_cells() -> List[Tuple[str, str]]:
    """All (arch, shape) pairs minus the documented long_500k skips.

    long_500k needs sub-quadratic decode state; pure full-attention archs are
    skipped (see DESIGN.md §4).
    """
    _ensure_loaded()
    cells: List[Tuple[str, str]] = []
    for arch in sorted(_ARCHS):
        cfg = _ARCHS[arch]
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.sub_quadratic:
                continue
            cells.append((arch, shape.name))
    return cells
