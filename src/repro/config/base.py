"""Core config dataclasses.

Design notes
------------
* ``ModelConfig`` is a superset config covering every architecture family in
  the assigned pool (dense / MoE / enc-dec / hybrid attn+SSM / xLSTM / VLM).
  Family-specific knobs live in optional sub-configs (``MoEConfig``,
  ``SSMConfig``) so a dense transformer config stays small.
* Configs are frozen: derived quantities are exposed as properties, never
  mutated in.
* ``reduced()`` produces the family-preserving smoke-test config used by the
  per-arch CPU smoke tests (small depth/width/vocab, same block structure).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Tuple


class AttentionKind(str, enum.Enum):
    FULL = "full"                 # full causal attention
    SLIDING = "sliding"           # sliding-window attention (sub-quadratic)
    NONE = "none"                 # no attention (pure recurrent arch)


class BlockKind(str, enum.Enum):
    """Which residual-block family a layer stack uses."""

    DENSE = "dense"               # attn + MLP
    MOE = "moe"                   # attn + mixture-of-experts MLP
    MAMBA = "mamba"               # SSM block
    HYBRID_PARALLEL = "hybrid"    # parallel attention + SSM heads (Hymba)
    MLSTM = "mlstm"               # xLSTM matrix-memory block
    SLSTM = "slstm"               # xLSTM scalar-memory block
    ENCDEC = "encdec"             # encoder-decoder transformer (Whisper)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16           # N: per-channel state size
    conv_width: int = 4           # depthwise conv width in the Mamba block
    expand: int = 2               # inner dim = expand * d_model
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)
    chunk: int = 128              # chunk length for the chunked scan kernel


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | audio | hybrid | ssm | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 -> d_model // num_heads
    attention: AttentionKind = AttentionKind.FULL
    window: int = 0               # sliding-window size when attention == SLIDING
    block: BlockKind = BlockKind.DENSE
    qkv_bias: bool = False
    qk_norm: bool = False
    gated_mlp: bool = True        # SwiGLU/GeGLU two-matrix up-projection
    mlp_activation: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    tie_embeddings: bool = False
    embed_scale: bool = False     # multiply embeddings by sqrt(d_model) (gemma)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # enc-dec (Whisper): encoder depth/width mirror the decoder unless set.
    encoder_layers: int = 0
    encoder_seq_len: int = 0      # frames after the (stubbed) conv frontend
    # xLSTM: 1 sLSTM block every `slstm_every` blocks (0 = mLSTM only)
    slstm_every: int = 0
    # VLM: number of (stubbed) vision patch embeddings prepended to the text
    vision_tokens: int = 0
    vision_width: int = 0         # width of stub patch embeds (projected to d_model)
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # ---- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can decode with O(1)/O(window) state per token."""
        return self.attention in (AttentionKind.SLIDING, AttentionKind.NONE) or (
            self.block in (BlockKind.MAMBA, BlockKind.MLSTM, BlockKind.SLSTM)
        )

    def param_count(self) -> int:
        """Analytic parameter count (matches init within embedding ties)."""
        hd = self.resolved_head_dim
        d = self.d_model
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) + (
            self.num_heads * hd
        ) * d
        if self.qkv_bias:
            attn += self.num_heads * hd + 2 * self.num_kv_heads * hd
        if self.block == BlockKind.MOE:
            assert self.moe is not None
            n_mat = 3 if self.gated_mlp else 2
            mlp = self.moe.num_experts * n_mat * d * self.d_ff + d * self.moe.num_experts
        elif self.block in (BlockKind.MAMBA, BlockKind.MLSTM, BlockKind.SLSTM):
            mlp = 0  # folded into block_params below
        else:
            n_mat = 3 if self.gated_mlp else 2
            mlp = n_mat * d * self.d_ff
        block_params = attn + mlp + 2 * d  # two RMSNorm scales
        if self.block == BlockKind.HYBRID_PARALLEL:
            assert self.ssm is not None
            inner = self.ssm.expand * d
            block_params += (
                2 * d * inner                      # in_proj (x and z)
                + inner * self.ssm.conv_width      # depthwise conv
                + inner * (2 * self.ssm.state_dim + self._dt_rank())
                + self._dt_rank() * inner          # dt proj
                + inner * self.ssm.state_dim       # A_log
                + inner                            # D
                + inner * d                        # out proj
            )
        if self.block in (BlockKind.MLSTM, BlockKind.SLSTM):
            inner = 2 * d
            block_params = 2 * d + (
                3 * d * inner + inner * d + 3 * inner  # up/gate/out + i,f,o gates
            )
        total = self.num_layers * block_params
        total += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        total += d  # final norm
        if self.encoder_layers:
            enc_block = attn + (3 if self.gated_mlp else 2) * d * self.d_ff + 2 * d
            total += self.encoder_layers * (enc_block + attn + d)  # + cross-attn
        if self.vision_tokens:
            total += self.vision_width * d  # projector
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts count)."""
        if self.block != BlockKind.MOE:
            return self.param_count()
        assert self.moe is not None
        n_mat = 3 if self.gated_mlp else 2
        per_expert = n_mat * self.d_model * self.d_ff
        inactive = (self.moe.num_experts - self.moe.top_k) * per_expert
        return int(self.param_count() - self.num_layers * inactive)

    def _dt_rank(self) -> int:
        assert self.ssm is not None
        return self.ssm.dt_rank or math.ceil(self.d_model / 16)

    def reduced(self) -> "ModelConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 4) if self.num_kv_heads < self.num_heads else 4,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=256,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq_len=16 if self.encoder_seq_len else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            vision_width=64 if self.vision_width else 0,
        )
        if self.slstm_every:
            kw["slstm_every"] = 2
            kw["num_layers"] = 4
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(self.moe, num_experts=min(self.moe.num_experts, 4))
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, state_dim=8, chunk=8)
        # keep GQA structure: kv strictly divides q heads
        if self.num_kv_heads < self.num_heads:
            kw["num_kv_heads"] = 2
        if self.window:
            kw["window"] = 8
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned input-shape row. ``mode`` decides which step is lowered."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        return int(math.prod(self.shape))

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))


@dataclasses.dataclass(frozen=True)
class ShardingLayout:
    """Named sharding-rule preset; hillclimbing swaps these."""

    name: str = "baseline"
    param_rules: str = "baseline"     # key into dist.sharding.PARAM_RULES
    opt_rules: str = ""               # optimizer-state rules ("" = same as params)
    sequence_shard_activations: bool = True   # Megatron-SP residual sharding
    attn_gather_kv: bool = False      # gather KV once per layer (vs ring-per-chunk)
    fused_ce: bool = True             # chunked CE — never materialize (B,S,V)
    ce_chunk: int = 256               # sequence chunk for the fused CE
    gradient_allreduce_dtype: str = "float32"  # "bfloat16" = compressed all-reduce
    remat: str = "full"               # none | full | dots
    scan_layers: bool = True
    attn_impl: str = "masked"         # masked | triangular (causal chunk schedule)
    q_chunk: int = 512
    kv_chunk: int = 1024
    decode_unroll: bool = False       # unroll decode layer loop (vs scan)
    int8_kv_cache: bool = False


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatches: int = 1             # gradient accumulation factor
    seed: int = 0
    label_smoothing: float = 0.0
