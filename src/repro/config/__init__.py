"""Typed configuration system for the spotax framework.

Everything the launcher, dry-run, and tests consume is a frozen dataclass
defined here; architecture files under ``repro.configs`` register instances
into the global registry.
"""
from repro.config.base import (
    AttentionKind,
    BlockKind,
    InputShape,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    TrainConfig,
    ShardingLayout,
)
from repro.config.registry import (
    get_arch,
    get_shape,
    list_archs,
    list_shapes,
    register_arch,
    runnable_cells,
    SHAPES,
)

__all__ = [
    "AttentionKind",
    "BlockKind",
    "InputShape",
    "MeshConfig",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "TrainConfig",
    "ShardingLayout",
    "get_arch",
    "get_shape",
    "list_archs",
    "list_shapes",
    "register_arch",
    "runnable_cells",
    "SHAPES",
]
