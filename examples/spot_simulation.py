"""The paper, end to end: generate spot markets, compute the three market
features, run Algorithm 1 against the FT baselines, print Fig. 1-style
stacked breakdowns.

    PYTHONPATH=src python examples/spot_simulation.py [--job-hours 24]
        [--memory-gb 16] [--revocations 4] [--seed 0]
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import (
    CheckpointPolicy,
    Job,
    MigrationPolicy,
    OnDemandPolicy,
    ReplicationPolicy,
    Simulator,
    SiwoftPolicy,
    generate_markets,
    split_history_future,
)
from repro.core import provisioner as alg
from repro.core.portfolio import PortfolioPolicy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--job-hours", type=float, default=24.0)
    ap.add_argument("--memory-gb", type=float, default=16.0)
    ap.add_argument("--revocations", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    ms = generate_markets(seed=args.seed, n_hours=24 * 90 + 24 * 60)
    hist, fut = split_history_future(ms, 24 * 90)
    sim = Simulator(hist, fut, seed=args.seed)
    job = Job(args.job_hours, args.memory_gb)

    # --- show the three §III-A features for the chosen market -------------
    feats = sim.feats
    suitable = alg.find_suitable_servers(job, feats)
    lifetimes = alg.compute_lifetime(feats, suitable)
    S = alg.server_based_lifetime(job, lifetimes, SiwoftPolicy(), feats)
    pick = alg.highest(S)
    m = hist.markets[pick]
    from repro.core.market import revocation_probability

    print(f"job: {job.length_hours}h, {job.memory_gb} GB -> suitable type "
          f"{m.instance_type} across {len(suitable)} markets")
    print(f"Alg.1 picks market #{pick} ({m.zone}): MTTR={feats.mttr[pick]:.0f}h, "
          f"revocation probability="
          f"{revocation_probability(job.length_hours, feats.mttr[pick]):.4f}")
    low_corr = alg.find_low_correlation(feats, pick, SiwoftPolicy())
    print(f"low-correlation fallback set: {len(low_corr & set(suitable))} "
          f"of {len(suitable)} suitable markets\n")

    # --- run every policy --------------------------------------------------
    header = f"{'policy':13s} {'wall_h':>8s} {'cost_$':>8s} {'revs':>4s}  components"
    print(header + "\n" + "-" * len(header))
    for policy, nrev in (
        (SiwoftPolicy(), 0),
        (SiwoftPolicy(name="hybrid", ckpt_interval_hours=2.0), 0),
        (PortfolioPolicy(), 0),
        (CheckpointPolicy(), args.revocations),
        (MigrationPolicy(), args.revocations),
        (ReplicationPolicy(degree=2), args.revocations),
        (OnDemandPolicy(), 0),
    ):
        bd = sim.run_job(job, policy, n_revocations=nrev)
        comps = " ".join(
            f"{k}={v:.2f}h" for k, v in bd.time.items() if v > 1e-9
        )
        print(f"{policy.name:13s} {bd.wall_time:8.2f} "
              f"{bd.total_cost:8.3f} {bd.revocations:4d}  {comps}")


if __name__ == "__main__":
    main()
