"""Batched serving: prefill a batch of prompts, then decode greedily with
the ring-buffer KV cache — the serve path the decode_* dry-run cells lower.

    PYTHONPATH=src python examples/serve_batch.py --arch mixtral-8x7b --new-tokens 16
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.config import get_arch
from repro.models import build_model
from repro.models.transformer import RunOpts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opts = RunOpts()

    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": prompts}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16
        )
    if cfg.vision_tokens:
        batch["patches"] = jax.random.normal(
            jax.random.key(3), (B, cfg.vision_tokens, cfg.vision_width), jnp.bfloat16
        )

    total = S + args.new_tokens
    t0 = time.perf_counter()
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, total, opts)
    )(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(lambda p, c, t, pos: model.decode_step(p, c, t, pos, opts))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.new_tokens - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(S + i))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={S} new={args.new_tokens}")
    print(f"prefill: {t_prefill*1e3:.1f} ms   decode: "
          f"{t_decode/max(args.new_tokens-1,1)*1e3:.1f} ms/token")
    print("generated token ids (first row):", gen[0].tolist())


if __name__ == "__main__":
    main()
