"""Live revocation + restore demo: the SpotTrainingOrchestrator drives a
real (reduced) training run in all three modes and prints the goodput/cost
ledger — the paper's provisioning layer on top of this framework's
execution layer.

    PYTHONPATH=src python examples/elastic_training.py [--steps 60]
"""
import argparse
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))


from repro.config import TrainConfig, get_arch
from repro.core import generate_markets, split_history_future
from repro.core.orchestrator import SpotTrainingOrchestrator
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    ds = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=4, seed=args.seed)
    mesh = make_host_mesh()
    ms = generate_markets(seed=3, n_hours=24 * 90 + 24 * 30)
    hist, fut = split_history_future(ms, 24 * 90)
    tc = TrainConfig(total_steps=args.steps * 2, warmup_steps=5)

    print(f"{'mode':12s} {'useful':>6s} {'wasted':>6s} {'revs':>4s} {'goodput':>7s} "
          f"{'cost_$':>8s} {'markets'}")
    for mode in ("siwoft", "checkpoint", "hybrid"):
        with tempfile.TemporaryDirectory() as d:
            orch = SpotTrainingOrchestrator(
                model, ds, mesh, hist, fut, mode=mode, tc=tc,
                segment_steps=10, steps_per_trace_hour=200,
                ckpt_dir=d, ckpt_every=5, ft_revocations=2, seed=args.seed,
            )
            rep = orch.run(args.steps)
        print(f"{mode:12s} {rep.useful_steps:6d} {rep.wasted_steps:6d} "
              f"{rep.revocations:4d} {rep.goodput:7.2f} {rep.cost_dollars:8.4f} "
              f"{rep.markets_used}")
        print(f"{'':12s} reshard={rep.reshard_bytes}B restore={rep.restore_bytes}B "
              f"mesh_shapes={sorted(set(rep.mesh_shapes))}")
    print("\nsiwoft re-provisions uncorrelated high-MTTR markets (no FT overhead);")
    print("a revocation is a live cross-mesh reshard (bytes moved, not restored);")
    print("checkpoint pays ckpt+restore+re-execution; hybrid combines both wins.")


if __name__ == "__main__":
    main()
