"""Quickstart: train a ~100M-param LM for a few hundred steps on CPU.

    PYTHONPATH=src python examples/quickstart.py --steps 300 --arch qwen3-4b

Uses a width/depth-reduced (but family-faithful) config scaled up to ~100M
params, the real sharded train step (host mesh), the synthetic data
pipeline, checkpointing, and the straggler watchdog. Writes a loss-curve
CSV next to this script.
"""
import argparse
import dataclasses
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.ckpt import CheckpointManager
from repro.config import ShardingLayout, TrainConfig, get_arch
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.train.loop import run_segment
from repro.train.steps import init_train_state
from repro.train.watchdog import StragglerWatchdog


def hundred_m_config(arch: str):
    """Family-preserving ~100M-param variant of an assigned arch."""
    cfg = get_arch(arch)
    return dataclasses.replace(
        cfg.reduced(),
        name=cfg.name + "-100m",
        num_layers=8,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        head_dim=64,
        d_ff=2048 if cfg.d_ff else 0,
        vocab_size=32000,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/quickstart_ckpt")
    args = ap.parse_args()

    cfg = hundred_m_config(args.arch)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.param_count()/1e6:.1f}M")

    mesh = make_host_mesh()
    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)
    tc = TrainConfig(total_steps=args.steps, warmup_steps=20, learning_rate=3e-4)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    wd = StragglerWatchdog(
        on_straggler=lambda s, dt, mean: print(
            f"  [watchdog] step {s} straggled: {dt:.2f}s vs mean {mean:.2f}s"
        )
    )

    state = init_train_state(model, jax.random.key(0))
    res = run_segment(
        model, state, ds, mesh, tc, ShardingLayout(),
        num_steps=args.steps, ckpt=ckpt, ckpt_every=100, watchdog=wd,
    )
    ckpt.wait()

    out = pathlib.Path(__file__).parent / "quickstart_loss.csv"
    out.write_text("step,loss\n" + "\n".join(f"{i},{l:.5f}" for i, l in enumerate(res.losses)))
    n = args.steps
    print(f"loss: first10={sum(res.losses[:10])/10:.4f}  last10={sum(res.losses[-10:])/10:.4f}")
    print(f"step time: mean={sum(res.step_seconds)/n*1e3:.1f}ms  stragglers={res.stragglers}")
    print(f"checkpoints kept: {ckpt.all_steps()}  loss curve -> {out}")
    ckpt.close()


if __name__ == "__main__":
    main()
