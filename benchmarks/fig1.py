"""Paper Fig. 1 reproduction: completion time (a–c) and deployment cost
(d–f) for P-SIWOFT (P), the fault-tolerance approach (F, checkpointing),
and on-demand (O), swept over job length / memory footprint / revocation
count — stacked into the paper's overhead components.

Runs on the LEGACY single-device menu (``legacy_menu()``): the paper
models instances as memory sizes only, so every shape has throughput 1.0
and the C1/C2 orderings are evaluated in the paper's own homogeneous
setting. The heterogeneous price-vs-speed menu is exercised by
``benchmarks/orchestrator_bench.py``.

Usage:
    python -m benchmarks.fig1 [--axis length|memory|revocations|all]
                              [--seeds 5] [--ratio-sweep]

Output: CSV rows  axis,value,policy,component,kind,amount
plus a validation summary of the paper's C1/C2 orderings.
"""
from __future__ import annotations

import argparse
import sys
from typing import List

import numpy as np

from repro.core import (
    CheckpointPolicy,
    Job,
    OnDemandPolicy,
    Simulator,
    SiwoftPolicy,
    generate_markets,
    legacy_menu,
    split_history_future,
)
from repro.core.accounting import COST_COMPONENTS, TIME_COMPONENTS
from repro.core.units import HOURS_PER_DAY

LENGTHS = [6, 12, 24, 48, 96]            # hours (Fig 1a/1d x-axis)
MEMORIES = [8, 16, 32, 64]               # GB    (Fig 1b/1e)
REVOCATIONS = [1, 2, 4, 8, 16]           # count (Fig 1c/1f)
DEFAULT_JOB = dict(length_hours=24.0, memory_gb=16.0)
REV_PER_DAY = 4                          # FT injected revocations per day


def make_sims(n_seeds: int, **market_kw):
    sims = []
    market_kw.setdefault("menu", legacy_menu())
    for seed in range(n_seeds):
        # 90 days of history to plan from + 60 days of future to run into
        ms = generate_markets(
            seed=seed, n_hours=(90 + 60) * HOURS_PER_DAY, **market_kw
        )
        hist, fut = split_history_future(ms, 24 * 90)
        sims.append(Simulator(hist, fut, seed=seed))
    return sims


def run_point(sims, job: Job, policy, nrev: int):
    """Mean component breakdown over seeds."""
    time_acc = {k: 0.0 for k in TIME_COMPONENTS}
    cost_acc = {k: 0.0 for k in COST_COMPONENTS}
    wall = 0.0
    for s in sims:
        bd = s.run_job(job, policy, n_revocations=nrev)
        for k in time_acc:
            time_acc[k] += bd.time[k] / len(sims)
        for k in cost_acc:
            cost_acc[k] += bd.cost[k] / len(sims)
        wall += bd.wall_time / len(sims)
    return time_acc, cost_acc, wall


def sweep(axis: str, sims, out: List[str]):
    points = {
        "length": [(Job(l, DEFAULT_JOB["memory_gb"]), int(REV_PER_DAY * l / 24)) for l in LENGTHS],
        "memory": [(Job(DEFAULT_JOB["length_hours"], m), REV_PER_DAY) for m in MEMORIES],
        "revocations": [(Job(**DEFAULT_JOB), n) for n in REVOCATIONS],
    }[axis]
    xs = {"length": LENGTHS, "memory": MEMORIES, "revocations": REVOCATIONS}[axis]

    summary = {}
    for x, (job, nrev) in zip(xs, points):
        for tag, policy, n in (
            ("P", SiwoftPolicy(), 0),
            ("F", CheckpointPolicy(), max(nrev, 1)),
            ("O", OnDemandPolicy(), 0),
        ):
            t, c, wall = run_point(sims, job, policy, n)
            for comp, v in t.items():
                out.append(f"{axis},{x},{tag},{comp},time_hours,{v:.4f}")
            for comp, v in c.items():
                out.append(f"{axis},{x},{tag},{comp},cost_usd,{v:.4f}")
            summary[(x, tag)] = (wall, sum(c.values()))
    return summary


def validate(axis, summary, xs) -> List[str]:
    """Check the paper's C1/C2 orderings at every swept point."""
    notes = []
    for x in xs:
        tP, cP = summary[(x, "P")]
        tF, cF = summary[(x, "F")]
        tO, cO = summary[(x, "O")]
        c1_time = tP <= tF * 1.02
        c1_near_od = abs(tP - tO) / tO < 0.12
        c2_cost = cP < cF and cP < cO
        notes.append(
            f"# {axis}={x}: C1 P<F time {'OK' if c1_time else 'VIOLATED'} "
            f"(P={tP:.1f}h F={tF:.1f}h O={tO:.1f}h near-OD {'OK' if c1_near_od else 'no'}); "
            f"C2 P cheapest {'OK' if c2_cost else 'VIOLATED'} "
            f"(P=${cP:.2f} F=${cF:.2f} O=${cO:.2f})"
        )
    return notes


def portfolio_sweep(n_seeds: int, out: List[str]):
    """Beyond-paper: portfolio vs siwoft in the volatile regime (no rare
    markets — the premise of Alg. 1 deliberately broken)."""
    from repro.core.portfolio import PortfolioPolicy

    job = Job(48, 16)
    cs, cp, rs, rp = [], [], [], []
    for seed in range(n_seeds * 2):
        ms = generate_markets(
            seed=100 + seed, n_hours=24 * 150, rare_market_fraction=0.0,
            menu=legacy_menu(),
        )
        hist, fut = split_history_future(ms, 24 * 90)
        sim = Simulator(hist, fut, seed=seed)
        a = sim.run_job(job, SiwoftPolicy())
        b = sim.run_job(job, PortfolioPolicy())
        cs.append(a.total_cost); cp.append(b.total_cost)
        rs.append(a.revocations); rp.append(b.revocations)
    out.append(
        f"portfolio_volatile,48h,summary,cost_siwoft,{np.mean(cs):.3f},"
        f"cost_portfolio,{np.mean(cp):.3f},revs,{np.mean(rs):.2f}/{np.mean(rp):.2f}"
    )


def ratio_sweep(n_seeds: int, out: List[str]):
    """Threats-to-validity: where do the orderings flip with the spot/
    on-demand price ratio? (the paper flags this but doesn't measure it)"""
    job = Job(**DEFAULT_JOB)
    for lo, hi in [(0.1, 0.3), (0.3, 0.5), (0.55, 0.8), (0.8, 0.95)]:
        sims = []
        for seed in range(n_seeds):
            ms = generate_markets(seed=100 + seed, n_hours=24 * 150, menu=legacy_menu())
            # rescale the non-spike base ratio into [lo, hi]
            od = np.array([m.on_demand_price for m in ms.markets])[:, None]
            ratio = ms.prices / od
            spikes = ratio > 1.0
            rescaled = lo + (hi - lo) * np.clip((ratio - 0.05) / 0.9, 0, 1)
            ms.prices = np.where(spikes, ms.prices, rescaled * od)
            hist, fut = split_history_future(ms, 24 * 90)
            sims.append(Simulator(hist, fut, seed=seed))
        tP, cP_, _ = run_point(sims, job, SiwoftPolicy(), 0)
        tF, cF_, _ = run_point(sims, job, CheckpointPolicy(), 8)
        tO, cO_, _ = run_point(sims, job, OnDemandPolicy(), 0)
        out.append(
            f"ratio,{lo}-{hi},summary,F_over_O,{sum(cF_.values())/max(sum(cO_.values()),1e-9):.3f},"
            f"P_over_O,{sum(cP_.values())/max(sum(cO_.values()),1e-9):.3f}"
        )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--axis", default="all", choices=["length", "memory", "revocations", "all"])
    ap.add_argument("--seeds", type=int, default=5)
    ap.add_argument("--ratio-sweep", action="store_true")
    args = ap.parse_args(argv)

    sims = make_sims(args.seeds)
    out: List[str] = ["axis,x,policy,component,kind,amount"]
    axes = ["length", "memory", "revocations"] if args.axis == "all" else [args.axis]
    notes = []
    for axis in axes:
        xs = {"length": LENGTHS, "memory": MEMORIES, "revocations": REVOCATIONS}[axis]
        summary = sweep(axis, sims, out)
        notes += validate(axis, summary, xs)
    if args.ratio_sweep:
        ratio_sweep(args.seeds, out)
        portfolio_sweep(args.seeds, out)
    print("\n".join(out))
    print("\n".join(notes), file=sys.stderr)
    violated = sum("VIOLATED" in n for n in notes)
    print(f"# {len(notes)} points checked, {violated} ordering violations", file=sys.stderr)
    return 0 if violated == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
