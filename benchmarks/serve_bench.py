"""Serving-fleet economics: SLO-aware spot provisioning vs on-demand and
static over-replication, on the same replayable price traces.

The serving analogue of ``orchestrator_bench.py``'s thesis check. Three
policies serve identical open-loop token traces (a steady floor and a
diurnal swing) over the same future price window:

* **fleet**  — the ``repro.serve`` subsystem: replicas admitted by MTTR
  against a rolling SLO horizon, spread across low-correlation markets,
  revocations repaired by PARAMS-ONLY migration over the DCN (KV cache
  dropped + re-prefilled);
* **on_demand** — replicas on the best $-per-token on-demand shape; never
  revoked; the availability bar at sticker price;
* **static** — spot with no market intelligence: over-replicated capacity
  (×1.5) on the cheapest suitable markets; a revocation pulls the FULL
  serving state (params + cache) back through remote storage;
* **autoscale** — the fleet policy with demand-driven sizing
  (``FleetSimulator(sizing="auto")``): forecast-ahead scale-up,
  low-water scale-down under a cooldown, demand-driven repair. The
  peak-sized fleet's night-time headroom is the money on the table.

Asserted, not narrated (the run aborts on violation):

* fleet SLO-violation seconds ≤ on-demand's, at < its cost (both
  scenarios),
* on the diurnal trace the autoscaled fleet is STRICTLY cheaper than the
  static-peak fleet at 0 SLO-violation seconds (and sheds idle
  headroom); on every scenario it meets the fleet's violation bar,
* every fleet migration moves strictly fewer bytes than the same
  revocation's full restore — and strictly fewer than the TRAINING
  path's restore (opt state never moves for serving).

``--kernels`` adds a hot-path microbench to the same JSON: tokens/sec for
dense prefill and for single-token decode against the paged KV pool
(block-table gather over OCCUPIED pages only) vs the dense max-context
cache, at batch 1 and 4. Asserted: paged ≥ dense-jnp at batch ≥ 4 — the
paged layout must pay for its gather with real throughput, not just
memory. ``tools/check_bench.py`` re-checks the committed numbers.

Besides the CSV on stdout, writes machine-readable ``BENCH_serve.json``
(monotonic scenario ids, schema enforced by ``tools/check_bench.py``) so
the serving perf trajectory is tracked across PRs like the orchestrator's.

    python benchmarks/serve_bench.py [--quick] [--kernels]
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib

import numpy as np

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_JSON = REPO_ROOT / "BENCH_serve.json"

CSV_HEADER = (
    "scenario,policy,cost_usd,slo_violation_s,served_mtok,shed_tokens,"
    "queued_tok_h,revocations,repairs,migrated_bytes,restored_bytes,replicas,"
    "p50_delay_s,p99_delay_s,scale_ups,scale_downs,idle_headroom_mtok"
)


def build_workload():
    """Serving footprint from the real reduced model: params + KV cache at
    batch 4 × 256 context (no optimizer state), plus the migration byte
    quantities the fleet bills."""
    from repro.config import get_arch
    from repro.core.units import BYTES_PER_GIB
    from repro.dist import serve_state_bytes
    from repro.models import build_model
    from repro.models.common import param_bytes
    from repro.serve import ServingWorkload

    model = build_model(get_arch("qwen3-4b").reduced())
    pb = param_bytes(model.specs)
    sb = serve_state_bytes(model, batch=4, seq_len=256)
    return ServingWorkload(
        target_tokens_per_sec=480.0,
        replica_tokens_per_sec=100.0,
        state_gb=sb / BYTES_PER_GIB,
        param_bytes=pb,
        cache_bytes=sb - pb,
        inflight_context_tokens=4 * 256.0,
    )


def kernel_bench(quick: bool = False) -> dict:
    """Serving hot-path microbench on the real reduced model: dense prefill
    tokens/sec plus single-token decode tokens/sec for the paged KV pool
    (``decode_step_paged``: attention over occupied pages via block-table
    gather) vs the dense max-context cache (``decode_step``: attention
    over all ``max_context`` slots). Both decode paths are the pure-jnp
    reference implementations, so the comparison isolates the cache
    LAYOUT, not Pallas codegen (kernel≡ref identity is pinned separately
    in tests/test_kernels.py). Timings are best-of-``repeats`` over
    ``steps`` jitted decode calls, measured after warmup."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.config import ShardingLayout, get_arch
    from repro.models import build_model
    from repro.models.layers import PAGE_SIZE
    from repro.train.steps import (
        build_decode_step,
        build_paged_decode_step,
        build_prefill_step,
    )

    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    layout = ShardingLayout()
    params = jax.device_put(model.init(jax.random.key(0)))

    S, total = 32, 256
    steps = 8 if quick else 32
    repeats = 2 if quick else 3

    def _time_decode(step, cache, tok, extra):
        """Best-of-``repeats`` wall time for ``steps`` decode calls; the
        donated cache threads through so every call is a real step."""
        best = math.inf
        pos = S
        for _ in range(repeats):
            t0 = time.perf_counter()
            for _ in range(steps):
                logits, cache = step(params, cache, tok, *extra(pos))
                pos += 1
            jax.block_until_ready(logits)
            best = min(best, time.perf_counter() - t0)
        return best

    rows = []
    for B in (1, 4):
        rng = np.random.RandomState(0)
        batch = {
            "tokens": jnp.asarray(
                rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32
            )
        }
        prefill = jax.jit(build_prefill_step(model, layout, total))
        logits, cache = jax.block_until_ready(prefill(params, batch))  # warmup
        t0 = time.perf_counter()
        logits, cache = jax.block_until_ready(prefill(params, batch))
        t_prefill = time.perf_counter() - t0
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]

        # dense: every step attends over all `total` cache slots
        decode = jax.jit(build_decode_step(model, layout), donate_argnums=(1,))
        for i in range(2):  # warmup (compile + donation steady state)
            logits, cache = decode(params, cache, tok, jnp.int32(S + i))
        jax.block_until_ready(logits)
        t_dense = _time_decode(
            decode, cache, tok, lambda pos: (jnp.int32(pos),)
        )

        # paged: every step attends over ceil(len/PAGE_SIZE) occupied pages
        per = math.ceil((S + steps + 4) / PAGE_SIZE)
        pcache = model.init_paged_cache(B * per + 1)
        table = jnp.asarray(
            np.arange(B * per, dtype=np.int32).reshape(B, per)
        )
        pdecode = jax.jit(
            build_paged_decode_step(model, layout), donate_argnums=(1,)
        )
        lens = np.full((B,), S, np.int32)
        for _ in range(2):  # warmup
            logits, pcache = pdecode(
                params, pcache, tok, jnp.asarray(lens), table
            )
            lens += 1
        jax.block_until_ready(logits)
        pos_lens = {"v": lens}

        def _paged_extra(pos, _pl=pos_lens, _table=table):
            out = (jnp.asarray(_pl["v"]), _table)
            _pl["v"] = _pl["v"] + 1
            return out

        t_paged = _time_decode(pdecode, pcache, tok, _paged_extra)

        row = {
            "batch": B,
            "prefill_tokens_per_sec": round(B * S / t_prefill, 1),
            "decode_dense_tokens_per_sec": round(B * steps / t_dense, 1),
            "decode_paged_tokens_per_sec": round(B * steps / t_paged, 1),
        }
        rows.append(row)
        print(
            f"# kernel_bench batch {B}: prefill "
            f"{row['prefill_tokens_per_sec']:.0f} tok/s, decode dense "
            f"{row['decode_dense_tokens_per_sec']:.0f} vs paged "
            f"{row['decode_paged_tokens_per_sec']:.0f} tok/s"
        )
        # the acceptance inequality: at serving batch sizes the paged pool
        # must beat attending over the dense max-context over-allocation
        if B >= 4:
            assert (
                row["decode_paged_tokens_per_sec"]
                >= row["decode_dense_tokens_per_sec"]
            ), row

    return {
        "prompt_len": S,
        "max_context": total,
        "decode_steps": steps,
        "page_size": PAGE_SIZE,
        "backend": jax.default_backend(),
        "batches": rows,
    }


def traces(hours: int):
    """Two deterministic offered-rate traces (tokens/sec per hour). Hour 0
    is demand-free in both — the fleet and the baselines boot on equal
    terms, so SLO comparisons measure provisioning quality, not warmup."""
    steady = np.full(hours, 350.0)
    steady[0] = 0.0
    t = np.arange(hours, dtype=float)
    diurnal = 300.0 - 180.0 * np.cos(2 * math.pi * ((t % 24) / 24.0))
    diurnal[0] = 0.0
    return [("steady", steady), ("diurnal", diurnal)]


def run_policies(hist, fut, wl, hours, rate):
    from repro.core import provisioner as alg
    from repro.serve import FleetSimulator, ServePolicy, on_demand_reference

    feats = alg.MarketFeatures.from_history(hist)
    fleet_policy = ServePolicy(
        slo_horizon_hours=24.0, capacity_headroom=1.25, cache_policy="drop"
    )
    static_policy = ServePolicy(slo_horizon_hours=24.0, capacity_headroom=1.5)
    return {
        "fleet": FleetSimulator(hist, fut, wl, fleet_policy).run(hours, rate),
        "autoscale": FleetSimulator(
            hist, fut, wl, fleet_policy, sizing="auto"
        ).run(hours, rate),
        "on_demand": on_demand_reference(wl, feats, fut, hours, rate, fleet_policy),
        "static": FleetSimulator(hist, fut, wl, static_policy, mode="static").run(
            hours, rate
        ),
    }


def report_row(scenario, policy, rep):
    from repro.core.units import SECONDS_PER_HOUR, TOKENS_PER_MEGATOKEN

    return (
        f"{scenario},{policy},{rep.cost_dollars:.4f},"
        f"{rep.slo_violation_seconds:.1f},"
        f"{rep.router.served_tokens / TOKENS_PER_MEGATOKEN:.3f},{rep.router.shed_tokens:.1f},"
        f"{rep.router.queued_token_seconds / SECONDS_PER_HOUR:.1f},"
        f"{rep.revocations},{rep.repairs},"
        f"{rep.migrated_bytes},{rep.restored_bytes},{rep.replicas_provisioned},"
        f"{rep.p50_delay_seconds:.3f},{rep.p99_delay_seconds:.3f},"
        f"{rep.scale_ups},{rep.scale_downs},"
        f"{rep.idle_headroom_tokens / TOKENS_PER_MEGATOKEN:.3f}"
    )


def rep_json(rep):
    return {
        "cost_usd": round(rep.cost_dollars, 6),
        "slo_violation_seconds": round(rep.slo_violation_seconds, 3),
        "served_tokens": round(rep.router.served_tokens, 1),
        "shed_tokens": round(rep.router.shed_tokens, 1),
        "queued_token_seconds": round(rep.router.queued_token_seconds, 1),
        "p50_delay_seconds": round(rep.p50_delay_seconds, 4),
        "p99_delay_seconds": round(rep.p99_delay_seconds, 4),
        "revocations": rep.revocations,
        "repairs": rep.repairs,
        "migrated_bytes": rep.migrated_bytes,
        "restored_bytes": rep.restored_bytes,
        "replicas_provisioned": rep.replicas_provisioned,
        "scale_ups": rep.scale_ups,
        "scale_downs": rep.scale_downs,
        "idle_headroom_tokens": round(rep.idle_headroom_tokens, 1),
        "capacity_tokens_per_sec": round(rep.capacity_tokens_per_sec, 3),
        "billing_buffer_usd": round(rep.breakdown.cost["billing_buffer"], 6),
    }


def main(quick: bool = False, kernels: bool = False, trace: str = "") -> None:
    if trace:
        from repro.obs.export import write_jsonl
        from repro.obs.recorder import recording

        with recording() as rec:
            _main(quick, kernels)
        print(f"# trace: {trace} ({write_jsonl(trace, rec.events)} events)")
        return
    _main(quick, kernels)


def _main(quick: bool = False, kernels: bool = False) -> None:
    from repro.core import generate_markets, split_history_future

    kb = kernel_bench(quick) if kernels else None
    wl = build_workload()
    days = 3 if quick else 13
    hours = 24 * days
    ms = generate_markets(seed=4, n_hours=24 * 90 + hours + 24)
    hist, fut = split_history_future(ms, 24 * 90)

    print(CSV_HEADER)
    scenarios = []
    for sid, (name, rate) in enumerate(traces(hours)):
        reps = run_policies(hist, fut, wl, float(hours), rate)
        for policy, rep in reps.items():
            print(report_row(name, policy, rep))

        fleet, od, static = reps["fleet"], reps["on_demand"], reps["static"]
        auto = reps["autoscale"]
        # --- the acceptance inequalities, enforced -----------------------
        assert fleet.slo_violation_seconds <= od.slo_violation_seconds, (
            name, fleet.slo_violation_seconds, od.slo_violation_seconds)
        assert fleet.cost_dollars < od.cost_dollars, (
            name, fleet.cost_dollars, od.cost_dollars)
        # the autoscaler may never buy its savings with SLO violations
        assert auto.slo_violation_seconds <= fleet.slo_violation_seconds, (
            name, auto.slo_violation_seconds, fleet.slo_violation_seconds)
        if name == "diurnal":
            # the tentpole inequality: tracking the diurnal trace beats
            # peak-sizing strictly, at ZERO violation seconds
            assert auto.slo_violation_seconds == 0.0, auto.slo_violation_seconds
            assert auto.cost_dollars < fleet.cost_dollars, (
                auto.cost_dollars, fleet.cost_dollars)
            assert auto.idle_headroom_tokens < fleet.idle_headroom_tokens, (
                auto.idle_headroom_tokens, fleet.idle_headroom_tokens)
            assert auto.scale_downs > 0, "diurnal trace must trigger downs"
        per_restore = wl.param_bytes + wl.cache_bytes  # full serving state
        if fleet.repairs:
            per_migration = fleet.migrated_bytes / fleet.repairs
            assert per_migration < per_restore, (per_migration, per_restore)
            assert per_migration < 3 * wl.param_bytes  # training path
        scenarios.append({
            "id": sid,
            "name": name,
            "hours": hours,
            "policies": {p: rep_json(r) for p, r in reps.items()},
        })
        print(
            f"# {name}: fleet ${fleet.cost_dollars:.2f} @ "
            f"{fleet.slo_violation_seconds:.0f}s viol vs on-demand "
            f"${od.cost_dollars:.2f} @ {od.slo_violation_seconds:.0f}s; "
            f"autoscale ${auto.cost_dollars:.2f} "
            f"({auto.scale_ups}↑/{auto.scale_downs}↓, p99 "
            f"{auto.p99_delay_seconds:.1f}s); "
            f"static ${static.cost_dollars:.2f} restored "
            f"{static.restored_bytes} B"
        )

    payload = {
        "bench": "serve",
        "quick": quick,
        "workload": {
            "target_tokens_per_sec": wl.target_tokens_per_sec,
            "state_gb": round(wl.state_gb, 6),
            "param_bytes": wl.param_bytes,
            "cache_bytes": wl.cache_bytes,
        },
        "scenarios": scenarios,
    }
    if kb is not None:
        payload["kernel_bench"] = kb
    BENCH_JSON.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"# wrote {BENCH_JSON.relative_to(REPO_ROOT)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="3-day smoke run")
    ap.add_argument("--kernels", action="store_true",
                    help="also run the paged-vs-dense decode microbench")
    ap.add_argument("--trace", default="", dest="trace",
                    help="record the structured event timeline to this JSONL "
                         "path (validate with python -m repro.obs.replay)")
    main(**vars(ap.parse_args()))
