"""Orchestrated spot-training goodput: P-SIWOFT vs checkpoint-FT vs hybrid
driving a REAL (reduced) JAX training run under market revocations.

Byte-level thesis check (paper: "no FT mechanism needed"): the CSV carries
``reshard_bytes`` (bytes a live cross-mesh reshard actually moved on
revocation, siwoft/hybrid) next to ``restore_bytes`` (bytes the checkpoint
baseline pulled through remote storage) — siwoft must move strictly fewer
bytes than checkpoint restores, and the run aborts if it doesn't.

CSV: mode,useful_steps,wasted_steps,revocations,goodput,cost_usd,
    reshard_bytes,restore_bytes,reshard_usd,recovery_usd,final_loss

    python benchmarks/orchestrator_bench.py [--quick] [--steps N]
"""
from __future__ import annotations

import argparse
import tempfile

import jax

from repro.config import TrainConfig, get_arch
from repro.core import generate_markets, split_history_future
from repro.core.orchestrator import SpotTrainingOrchestrator
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import build_model


def main(quick: bool = False, steps: int = 0) -> None:
    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    ds = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=4, seed=0)
    mesh = make_host_mesh()
    ms = generate_markets(seed=3, n_hours=24 * 90 + 24 * 30)
    hist, fut = split_history_future(ms, 24 * 90)
    custom_steps = bool(steps)
    steps = steps or (30 if quick else 60)
    tc = TrainConfig(total_steps=steps * 2, warmup_steps=5)

    print(
        "mode,useful_steps,wasted_steps,revocations,goodput,cost_usd,"
        "reshard_bytes,restore_bytes,reshard_usd,recovery_usd,final_loss"
    )
    reports = {}
    for mode in ("siwoft", "checkpoint", "hybrid"):
        with tempfile.TemporaryDirectory() as d:
            orch = SpotTrainingOrchestrator(
                model, ds, mesh, hist, fut, mode=mode, tc=tc,
                segment_steps=10, steps_per_trace_hour=200, ckpt_dir=d,
                ckpt_every=5, ft_revocations=2, seed=0,
            )
            rep = orch.run(steps)
        reports[mode] = rep
        print(
            f"{mode},{rep.useful_steps},{rep.wasted_steps},{rep.revocations},"
            f"{rep.goodput:.3f},{rep.cost_dollars:.4f},"
            f"{rep.reshard_bytes},{rep.restore_bytes},"
            f"{rep.breakdown.cost['reshard']:.6f},"
            f"{rep.breakdown.cost['recovery']:.6f},"
            f"{rep.losses[-1]:.4f}"
        )

    # the paper's thesis, in bytes: a live reshard moves less than a
    # checkpoint restore pulls through storage. A custom --steps can be so
    # short that the injected revocations precede the first checkpoint
    # (nothing to restore) — skip the degenerate comparison with a note
    # instead of asserting; default/quick runs always enforce it.
    if not custom_steps or reports["checkpoint"].restore_bytes > 0:
        assert reports["siwoft"].reshard_bytes < reports["checkpoint"].restore_bytes, (
            reports["siwoft"].reshard_bytes,
            reports["checkpoint"].restore_bytes,
        )
        assert reports["checkpoint"].restore_bytes > 0
    else:
        print("# note: no checkpoint restore at this step count; "
              "byte comparison skipped")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="30-step smoke run")
    ap.add_argument("--steps", type=int, default=0, help="override step count")
    args = ap.parse_args()
    main(quick=args.quick, steps=args.steps)
