"""Orchestrated spot-training goodput: P-SIWOFT vs checkpoint-FT vs hybrid
driving a REAL (reduced) JAX training run under market revocations.

CSV: mode,useful_steps,wasted_steps,revocations,goodput,cost_usd,final_loss
"""
from __future__ import annotations

import tempfile

import jax

from repro.config import TrainConfig, get_arch
from repro.core import generate_markets, split_history_future
from repro.core.orchestrator import SpotTrainingOrchestrator
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import build_model


def main(quick: bool = False) -> None:
    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    ds = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=4, seed=0)
    mesh = make_host_mesh()
    ms = generate_markets(seed=3, n_hours=24 * 90 + 24 * 30)
    hist, fut = split_history_future(ms, 24 * 90)
    steps = 30 if quick else 60
    tc = TrainConfig(total_steps=steps * 2, warmup_steps=5)

    print("mode,useful_steps,wasted_steps,revocations,goodput,cost_usd,final_loss")
    for mode in ("siwoft", "checkpoint", "hybrid"):
        with tempfile.TemporaryDirectory() as d:
            orch = SpotTrainingOrchestrator(
                model, ds, mesh, hist, fut, mode=mode, tc=tc,
                segment_steps=10, steps_per_trace_hour=200, ckpt_dir=d,
                ckpt_every=5, ft_revocations=2, seed=0,
            )
            rep = orch.run(steps)
        print(
            f"{mode},{rep.useful_steps},{rep.wasted_steps},{rep.revocations},"
            f"{rep.goodput:.3f},{rep.cost_dollars:.4f},{rep.losses[-1]:.4f}"
        )


if __name__ == "__main__":
    main()
