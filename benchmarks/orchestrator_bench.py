"""Orchestrated spot-training goodput: P-SIWOFT vs checkpoint-FT vs hybrid
driving a REAL (reduced) JAX training run under market revocations.

Byte-level thesis check (paper: "no FT mechanism needed"): the CSV carries
``reshard_bytes`` (bytes a live cross-mesh reshard actually moved on
revocation, siwoft/hybrid) next to ``restore_bytes`` (bytes the checkpoint
baseline pulled through remote storage) — siwoft must move strictly fewer
bytes than checkpoint restores, and the run aborts if it doesn't.

Throughput check (beyond the paper): the CSV carries ``steps_per_hour``
(measured per-mesh-shape step rates, ``DxM:steps/h`` joined by ``;``) and
``cost_to_complete`` (the expected $ for the whole job on the first
provisioned market — price integrated over the shape's wall time,
risk-adjusted). The run asserts siwoft's first pick demonstrates
price-vs-speed provisioning: the chosen shape is NOT the cheapest $/h
suitable market, but has the lowest expected cost-to-complete among the
top-lifetime candidates Algorithm 1 admits.

CSV: mode,useful_steps,wasted_steps,revocations,goodput,cost_usd,
    reshard_bytes,restore_bytes,reshard_usd,recovery_usd,
    steps_per_hour,cost_to_complete,final_loss

    python benchmarks/orchestrator_bench.py [--quick] [--steps N]
"""
from __future__ import annotations

import argparse
import tempfile

import jax

from repro.config import TrainConfig, get_arch
from repro.core import SiwoftPolicy, generate_markets, split_history_future
from repro.core import provisioner as alg
from repro.core.orchestrator import SpotTrainingOrchestrator
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import build_model

CSV_HEADER = (
    "mode,useful_steps,wasted_steps,revocations,goodput,cost_usd,"
    "reshard_bytes,restore_bytes,reshard_usd,recovery_usd,"
    "steps_per_hour,cost_to_complete,final_loss"
)


def check_price_vs_speed(orch: SpotTrainingOrchestrator, rep, total_steps: int) -> str:
    """Assert the siwoft run provisions by cost-to-complete, not raw $/h:
    its first market must be pricier per hour than the cheapest suitable
    market yet the cheapest per unit of work among the admitted
    top-lifetime candidates."""
    job = orch._segment_job(total_steps)
    feats = orch.feats
    chosen = rep.markets_used[0]
    suitable = alg.find_suitable_servers(job, feats)
    assert chosen in suitable
    cheapest = min(suitable, key=lambda i: float(feats.avg_price[i]))
    lifetimes = alg.compute_lifetime(feats, suitable)
    S = alg.server_based_lifetime(job, lifetimes, SiwoftPolicy(), feats)
    top = [i for i in S if lifetimes[i] == lifetimes[S[0]]]
    ecc = {i: alg.expected_cost_to_complete(job.length_hours, feats, i) for i in top}
    assert chosen != cheapest, (
        "expected the chosen shape to beat the cheapest $/h market on "
        "cost-to-complete, but siwoft picked the cheapest market itself"
    )
    assert ecc[chosen] == min(ecc.values()), (chosen, ecc)
    ch, cc = orch.future.markets[chosen], orch.future.markets[cheapest]
    return (
        f"# price-vs-speed: chose {ch.instance_type} ({ch.device_count} dev, "
        f"${feats.avg_price[chosen]:.3f}/h, ecc ${ecc[chosen]:.4f}) over cheapest "
        f"{cc.instance_type} ({cc.device_count} dev, ${feats.avg_price[cheapest]:.3f}/h, "
        f"ecc ${alg.expected_cost_to_complete(job.length_hours, feats, cheapest):.4f})"
    )


def main(quick: bool = False, steps: int = 0) -> None:
    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    ds = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=4, seed=0)
    mesh = make_host_mesh()
    # seed 4: a market set where the lowest cost-to-complete suitable market
    # is a 4-device g5.12xlarge at ~2.9x the $/h of the cheapest m5.xlarge —
    # the price-vs-speed flip this bench asserts on
    ms = generate_markets(seed=4, n_hours=24 * 90 + 24 * 30)
    hist, fut = split_history_future(ms, 24 * 90)
    custom_steps = bool(steps)
    steps = steps or (30 if quick else 60)
    tc = TrainConfig(total_steps=steps * 2, warmup_steps=5)

    print(CSV_HEADER)
    reports = {}
    orchs = {}
    rows = {}
    for mode in ("siwoft", "checkpoint", "hybrid"):
        with tempfile.TemporaryDirectory() as d:
            orch = SpotTrainingOrchestrator(
                model, ds, mesh, hist, fut, mode=mode, tc=tc,
                segment_steps=10, steps_per_trace_hour=200, ckpt_dir=d,
                ckpt_every=5, ft_revocations=2, seed=0,
            )
            rep = orch.run(steps)
        reports[mode] = rep
        orchs[mode] = orch
        sph = ";".join(
            f"{shape}:{rate:.1f}" for shape, rate in sorted(rep.shape_steps_per_hour.items())
        )
        rows[mode] = (
            f"{mode},{rep.useful_steps},{rep.wasted_steps},{rep.revocations},"
            f"{rep.goodput:.3f},{rep.cost_dollars:.4f},"
            f"{rep.reshard_bytes},{rep.restore_bytes},"
            f"{rep.breakdown.cost['reshard']:.6f},"
            f"{rep.breakdown.cost['recovery']:.6f},"
            f"{sph},{rep.cost_to_complete:.4f},"
            f"{rep.losses[-1]:.4f}"
        )
        print(rows[mode])

    # the report must carry the throughput columns, populated: a measured
    # steps/hour entry per mesh shape used, and a positive expected
    # cost-to-complete for the first provisioned market
    for mode, row in rows.items():
        cells = row.split(",")
        assert len(cells) == len(CSV_HEADER.split(",")), (mode, row)
        assert ":" in cells[10], f"{mode}: no measured per-shape steps_per_hour"
        assert float(cells[11]) > 0, f"{mode}: missing cost_to_complete"
    # the flip is tuned to the default/quick job length on market seed 4; a
    # custom --steps changes the admission set, so report instead of abort
    if custom_steps:
        try:
            print(check_price_vs_speed(orchs["siwoft"], reports["siwoft"], steps))
        except AssertionError as e:
            print(f"# note: price-vs-speed flip not exhibited at --steps {steps}: {e}")
    else:
        print(check_price_vs_speed(orchs["siwoft"], reports["siwoft"], steps))

    # the paper's thesis, in bytes: a live reshard moves less than a
    # checkpoint restore pulls through storage. A custom --steps can be so
    # short that the injected revocations precede the first checkpoint
    # (nothing to restore) — skip the degenerate comparison with a note
    # instead of asserting; default/quick runs always enforce it.
    if not custom_steps or reports["checkpoint"].restore_bytes > 0:
        assert reports["siwoft"].reshard_bytes < reports["checkpoint"].restore_bytes, (
            reports["siwoft"].reshard_bytes,
            reports["checkpoint"].restore_bytes,
        )
        assert reports["checkpoint"].restore_bytes > 0
    else:
        print("# note: no checkpoint restore at this step count; "
              "byte comparison skipped")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="30-step smoke run")
    ap.add_argument("--steps", type=int, default=0, help="override step count")
    args = ap.parse_args()
    main(quick=args.quick, steps=args.steps)
