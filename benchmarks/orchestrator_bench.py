"""Orchestrated spot-training goodput: P-SIWOFT vs checkpoint-FT vs hybrid
driving a REAL (reduced) JAX training run under market revocations.

Byte-level thesis check (paper: "no FT mechanism needed"): the CSV carries
``reshard_bytes`` (bytes a live cross-mesh reshard actually moved on
revocation, siwoft/hybrid) next to ``restore_bytes`` (bytes the checkpoint
baseline pulled through remote storage) — siwoft must move strictly fewer
bytes than checkpoint restores, and the run aborts if it doesn't.

Throughput check (beyond the paper): the CSV carries ``steps_per_hour``
(measured per-mesh-shape step rates, ``DxM:steps/h`` joined by ``;``) and
``cost_to_complete`` (the expected $ for the whole job on the first
provisioned market — price integrated over the shape's wall time,
risk-adjusted). The run asserts siwoft's first pick demonstrates
price-vs-speed provisioning: the chosen shape is NOT the cheapest $/h
suitable market, but has the lowest expected cost-to-complete among the
top-lifetime candidates Algorithm 1 admits.

Allocation check (beyond the paper, ISSUE 4): a separate split scenario —
run in a subprocess with 8 forced host devices — provisions a job whose
footprint fits NO single menu shape as a 2-leg allocation over DCN, loses
one leg to a trace revocation mid-run, repairs only that leg (the lost
leg's distinct state slices cross DCN; the surviving leg keeps its
shards), and completes. Asserted: per-leg costs sum to the total bill and
the one-leg rebuild moves strictly fewer bytes than a full restore.

Besides the CSV on stdout, the bench writes machine-readable results to
``BENCH_orchestrator.json`` at the repo root (cost, completion time,
reshard/restore bytes per policy + the split scenario) so the perf
trajectory is tracked across PRs.

CSV: mode,useful_steps,wasted_steps,revocations,goodput,cost_usd,
    reshard_bytes,restore_bytes,reshard_usd,recovery_usd,
    steps_per_hour,cost_to_complete,final_loss

    python benchmarks/orchestrator_bench.py [--quick] [--steps N]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import tempfile

if "--split-only" in sys.argv:
    # the split scenario needs a multi-device pool to mean anything; force
    # it BEFORE jax initializes (the parent process re-execs us this way).
    # Appended AFTER any inherited XLA_FLAGS: duplicate flags resolve
    # last-wins, so an environment-set device count cannot override ours.
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()


from repro.config import TrainConfig, get_arch
from repro.core import SiwoftPolicy, generate_markets, split_history_future
from repro.core import provisioner as alg
from repro.core.orchestrator import SpotTrainingOrchestrator
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import build_model

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
BENCH_JSON = REPO_ROOT / "BENCH_orchestrator.json"

CSV_HEADER = (
    "mode,useful_steps,wasted_steps,revocations,goodput,cost_usd,"
    "reshard_bytes,restore_bytes,reshard_usd,recovery_usd,"
    "steps_per_hour,cost_to_complete,final_loss"
)


def check_price_vs_speed(orch: SpotTrainingOrchestrator, rep, total_steps: int) -> str:
    """Assert the siwoft run provisions by cost-to-complete, not raw $/h:
    its first market must be pricier per hour than the cheapest suitable
    market yet the cheapest per unit of work among the admitted
    top-lifetime candidates."""
    job = orch._segment_job(total_steps)
    feats = orch.feats
    chosen = rep.markets_used[0]
    suitable = alg.find_suitable_servers(job, feats)
    assert chosen in suitable
    cheapest = min(suitable, key=lambda i: float(feats.avg_price[i]))
    lifetimes = alg.compute_lifetime(feats, suitable)
    S = alg.server_based_lifetime(job, lifetimes, SiwoftPolicy(), feats)
    top = [i for i in S if lifetimes[i] == lifetimes[S[0]]]
    ecc = {i: alg.expected_cost_to_complete(job.length_hours, feats, i) for i in top}
    assert chosen != cheapest, (
        "expected the chosen shape to beat the cheapest $/h market on "
        "cost-to-complete, but siwoft picked the cheapest market itself"
    )
    assert ecc[chosen] == min(ecc.values()), (chosen, ecc)
    ch, cc = orch.future.markets[chosen], orch.future.markets[cheapest]
    return (
        f"# price-vs-speed: chose {ch.instance_type} ({ch.device_count} dev, "
        f"${feats.avg_price[chosen]:.3f}/h, ecc ${ecc[chosen]:.4f}) over cheapest "
        f"{cc.instance_type} ({cc.device_count} dev, ${feats.avg_price[cheapest]:.3f}/h, "
        f"ecc ${alg.expected_cost_to_complete(job.length_hours, feats, cheapest):.4f})"
    )


def split_scenario(quick: bool = False) -> dict:
    """A job too big for every menu shape completes as a 2-leg allocation.

    Hand-built market set (8 forced host devices simulate the instances):
    three 8-device/40 GB markets in distinct regions — A and B calm over
    the whole history (so the (A, B) pair has the max min-MTTR and wins
    the split ranking), C with a mildly revoking history — plus a small
    1-device market that can never fit the job. The planner footprint
    (``job_memory_gb``) is 400 GB: more than any single 320 GB shape,
    within any 8+8 pair. In the future window B revokes at hour 2 (the
    trace-driven surprise history could not predict). The run must (1)
    provision the 2-leg (A, B) allocation, (2) lose leg B to the trace
    revocation, (3) repair ONLY that leg with C — billing the lost leg's
    distinct state slices over DCN, strictly fewer bytes than the
    full-state restore a checkpoint baseline would pull — and (4) finish,
    with the per-leg cost split summing to the total bill.
    """
    import numpy as np

    from repro.core.market import Market, MarketSet
    from repro.dist.meshplan import train_state_bytes

    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    markets = [
        Market(0, "big8.a", "us-east-1", "us-east-1a", 40, 1.2,
               device_count=8, interconnect_gbps=60.0),
        Market(1, "big8.b", "eu-west-1", "eu-west-1a", 40, 1.2,
               device_count=8, interconnect_gbps=60.0),
        Market(2, "big8.c", "ap-southeast-1", "ap-southeast-1a", 40, 1.2,
               device_count=8, interconnect_gbps=60.0),
        Market(3, "small1", "us-east-1", "us-east-1b", 64, 0.4,
               device_count=1, interconnect_gbps=10.0),
    ]
    H = 90
    hp = np.full((4, H), 0.35)
    hp[2, ::45] = 1.5   # C: MTTR 45 h (admits, but ranks below calm A/B)
    hp[3, ::5] = 0.6    # small market: volatile (0.6 > its 0.4 on-demand ->
    #                     revokes every 5 h); irrelevant either way — one
    #                     device can never fit the 400 GB job
    hist = MarketSet(markets, hp)
    F = 24
    fp = np.full((4, F), 0.35)
    fp[1, 2:4] = 1.5    # B revokes at future hour 2 — mid-run
    fut = MarketSet(markets, fp, start_hour=H)

    ds = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=4, seed=0)
    steps = 20 if quick else 40
    tc = TrainConfig(total_steps=steps * 2, warmup_steps=2)
    orch = SpotTrainingOrchestrator(
        model, ds, make_host_mesh(), hist, fut, mode="siwoft", tc=tc,
        segment_steps=10, steps_per_trace_hour=1, seed=0,
        job_memory_gb=400.0,
    )
    rep = orch.run(steps)

    full_restore_bytes = train_state_bytes(model)
    leg_cost_sum = sum(rep.leg_costs.values())
    assert len(rep.allocations_used[0]) == 2, rep.allocations_used
    assert rep.useful_steps == steps, (rep.useful_steps, steps)
    assert rep.revocations >= 1 and rep.leg_repairs >= 1, (
        rep.revocations, rep.leg_repairs)
    assert 1 in [m for a in rep.allocations_used for m in a]  # B was used
    assert 0 < rep.reshard_bytes < full_restore_bytes, (
        rep.reshard_bytes, full_restore_bytes)
    assert abs(leg_cost_sum - rep.cost_dollars) < 1e-6 * max(rep.cost_dollars, 1.0)
    assert len(rep.leg_costs) >= 3  # A, B and the replacement leg all billed
    return {
        "steps": steps,
        "allocations_used": [list(a) for a in rep.allocations_used],
        "revocations": rep.revocations,
        "leg_repairs": rep.leg_repairs,
        "reshard_bytes": rep.reshard_bytes,
        "full_restore_bytes": full_restore_bytes,
        "cost_usd": rep.cost_dollars,
        "leg_costs": {str(k): v for k, v in sorted(rep.leg_costs.items())},
        "completion_trace_hours": rep.breakdown.total_time,
        "final_loss": rep.losses[-1],
    }


def run_split_subprocess(quick: bool) -> dict:
    """Re-exec this script with 8 forced host devices for the split
    scenario (the parent process is pinned to the real 1-CPU pool, which
    cannot represent a 2-leg mesh)."""
    cmd = [sys.executable, __file__, "--split-only"]
    if quick:
        cmd.append("--quick")
    pythonpath = os.pathsep.join(
        p for p in (str(REPO_ROOT / "src"), os.environ.get("PYTHONPATH")) if p
    )
    env = {**os.environ, "PYTHONPATH": pythonpath}
    res = subprocess.run(
        cmd, capture_output=True, text=True, timeout=1200, env=env,
        cwd=str(REPO_ROOT),
    )
    for line in res.stdout.splitlines():
        if line.startswith("SPLIT_JSON "):
            return json.loads(line[len("SPLIT_JSON "):])
    raise RuntimeError(
        f"split scenario failed (exit {res.returncode}):\n{res.stdout}\n{res.stderr}"
    )


def main(quick: bool = False, steps: int = 0, trace: str = "") -> None:
    if trace:
        from repro.obs.export import write_jsonl
        from repro.obs.recorder import recording

        # NOTE: the 2-leg split scenario runs in a re-exec'd subprocess
        # (8 forced host devices), so its events are not in this trace.
        with recording() as rec:
            _main(quick, steps)
        print(f"# trace: {trace} ({write_jsonl(trace, rec.events)} events)")
        return
    _main(quick, steps)


def _main(quick: bool = False, steps: int = 0) -> None:
    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    ds = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=4, seed=0)
    mesh = make_host_mesh()
    # seed 4: a market set where the lowest cost-to-complete suitable market
    # is a 4-device g5.12xlarge at ~2.9x the $/h of the cheapest m5.xlarge —
    # the price-vs-speed flip this bench asserts on
    ms = generate_markets(seed=4, n_hours=24 * 90 + 24 * 30)
    hist, fut = split_history_future(ms, 24 * 90)
    custom_steps = bool(steps)
    steps = steps or (30 if quick else 60)
    tc = TrainConfig(total_steps=steps * 2, warmup_steps=5)

    print(CSV_HEADER)
    reports = {}
    orchs = {}
    rows = {}
    for mode in ("siwoft", "checkpoint", "hybrid"):
        with tempfile.TemporaryDirectory() as d:
            orch = SpotTrainingOrchestrator(
                model, ds, mesh, hist, fut, mode=mode, tc=tc,
                segment_steps=10, steps_per_trace_hour=200, ckpt_dir=d,
                ckpt_every=5, ft_revocations=2, seed=0,
            )
            rep = orch.run(steps)
        reports[mode] = rep
        orchs[mode] = orch
        sph = ";".join(
            f"{shape}:{rate:.1f}" for shape, rate in sorted(rep.shape_steps_per_hour.items())
        )
        rows[mode] = (
            f"{mode},{rep.useful_steps},{rep.wasted_steps},{rep.revocations},"
            f"{rep.goodput:.3f},{rep.cost_dollars:.4f},"
            f"{rep.reshard_bytes},{rep.restore_bytes},"
            f"{rep.breakdown.cost['reshard']:.6f},"
            f"{rep.breakdown.cost['recovery']:.6f},"
            f"{sph},{rep.cost_to_complete:.4f},"
            f"{rep.losses[-1]:.4f}"
        )
        print(rows[mode])

    # the report must carry the throughput columns, populated: a measured
    # steps/hour entry per mesh shape used, and a positive expected
    # cost-to-complete for the first provisioned market
    for mode, row in rows.items():
        cells = row.split(",")
        assert len(cells) == len(CSV_HEADER.split(",")), (mode, row)
        assert ":" in cells[10], f"{mode}: no measured per-shape steps_per_hour"
        assert float(cells[11]) > 0, f"{mode}: missing cost_to_complete"
    # the flip is tuned to the default/quick job length on market seed 4; a
    # custom --steps changes the admission set, so report instead of abort
    if custom_steps:
        try:
            print(check_price_vs_speed(orchs["siwoft"], reports["siwoft"], steps))
        except AssertionError as e:
            print(f"# note: price-vs-speed flip not exhibited at --steps {steps}: {e}")
    else:
        print(check_price_vs_speed(orchs["siwoft"], reports["siwoft"], steps))

    # the paper's thesis, in bytes: a live reshard moves less than a
    # checkpoint restore pulls through storage. A custom --steps can be so
    # short that the injected revocations precede the first checkpoint
    # (nothing to restore) — skip the degenerate comparison with a note
    # instead of asserting; default/quick runs always enforce it.
    if not custom_steps or reports["checkpoint"].restore_bytes > 0:
        assert reports["siwoft"].reshard_bytes < reports["checkpoint"].restore_bytes, (
            reports["siwoft"].reshard_bytes,
            reports["checkpoint"].restore_bytes,
        )
        assert reports["checkpoint"].restore_bytes > 0
    else:
        print("# note: no checkpoint restore at this step count; "
              "byte comparison skipped")

    # multi-leg allocation check: a job that fits no single shape completes
    # as a 2-leg split with one-leg repair (subprocess: 8 forced devices)
    split = run_split_subprocess(quick)
    print(
        f"# split: allocs={split['allocations_used']} "
        f"leg_repairs={split['leg_repairs']} "
        f"reshard={split['reshard_bytes']}B < restore={split['full_restore_bytes']}B"
    )

    # machine-readable perf trajectory, tracked across PRs
    BENCH_JSON.write_text(json.dumps({
        "steps": steps,
        "quick": quick,
        "modes": {
            mode: {
                "useful_steps": rep.useful_steps,
                "wasted_steps": rep.wasted_steps,
                "revocations": rep.revocations,
                "goodput": round(rep.goodput, 4),
                "cost_usd": round(rep.cost_dollars, 6),
                "completion_trace_hours": round(rep.breakdown.total_time, 6),
                "reshard_bytes": rep.reshard_bytes,
                "restore_bytes": rep.restore_bytes,
                "reshard_usd": round(rep.breakdown.cost["reshard"], 8),
                "recovery_usd": round(rep.breakdown.cost["recovery"], 8),
                "cost_to_complete": round(rep.cost_to_complete, 6),
                "final_loss": round(rep.losses[-1], 6),
                "leg_costs": {
                    str(k): round(v, 6) for k, v in sorted(rep.leg_costs.items())
                },
            }
            for mode, rep in reports.items()
        },
        "split_scenario": split,
    }, indent=1) + "\n")
    print(f"# wrote {BENCH_JSON.relative_to(REPO_ROOT)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="30-step smoke run")
    ap.add_argument("--steps", type=int, default=0, help="override step count")
    ap.add_argument("--split-only", action="store_true",
                    help="internal: run just the 2-leg split scenario "
                         "(re-execed with 8 forced host devices)")
    ap.add_argument("--trace", default="",
                    help="record the structured event timeline to this JSONL "
                         "path (validate with python -m repro.obs.replay)")
    args = ap.parse_args()
    if args.split_only:
        print("SPLIT_JSON " + json.dumps(split_scenario(quick=args.quick)))
    else:
        main(quick=args.quick, steps=args.steps, trace=args.trace)
