"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python —
NOT indicative of TPU speed), so wall-times are reported for the pure-jnp
XLA paths (the lowering actually used on CPU) and the kernels are verified
for correctness; per-kernel analytic FLOPs are derived for the roofline.

CSV: name,us_per_call,derived
"""
from __future__ import annotations

import time
from typing import Callable, List

import jax
import jax.numpy as jnp


def timeit(fn: Callable, *args, iters: int = 5) -> float:
    from repro.core.units import MICROSECONDS_PER_SECOND

    out = fn(*args)
    if isinstance(out, tuple):
        out[0].block_until_ready()
    else:
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * MICROSECONDS_PER_SECOND


def bench_blockwise_attention(rows: List[str]):
    from repro.models.layers import blockwise_attention

    for (B, S, H, KVH, hd, window) in [
        (1, 1024, 8, 8, 64, 0),
        (1, 2048, 8, 2, 64, 0),
        (1, 2048, 8, 2, 64, 512),
    ]:
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, KVH, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, KVH, hd), jnp.float32)
        for impl in ("masked", "triangular"):
            f = jax.jit(
                lambda q, k, v, impl=impl, window=window: blockwise_attention(
                    q, k, v, causal=True, window=window,
                    q_chunk=256, kv_chunk=256, impl=impl,
                )
            )
            us = timeit(f, q, k, v)
            flops = 4 * B * H * S * S * hd * (0.5 if impl == "triangular" or window else 1.0)
            rows.append(f"attn_{impl}_S{S}_w{window},{us:.1f},flops={flops:.3e}")


def bench_moe(rows: List[str]):
    from repro.config import get_arch
    from repro.models.moe import moe_block, moe_spec
    from repro.models.common import init_params

    cfg = get_arch("mixtral-8x7b").reduced()
    params = init_params(moe_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (4, 128, cfg.d_model), jnp.float32)
    f = jax.jit(lambda p, x: moe_block(p, x, cfg))
    us = timeit(f, params, x)
    rows.append(f"moe_dispatch_tiny,{us:.1f},experts={cfg.moe.num_experts}")


def bench_kernels_interpret(rows: List[str]):
    """Correctness-scale interpret runs (documents the kernels exist & agree)."""
    from repro.kernels.flash_attention import attention_ref, flash_attention
    from repro.kernels.mlstm import mlstm_chunkwise, mlstm_ref
    from repro.kernels.ssm_scan import ssm_scan, ssm_scan_ref

    ks = jax.random.split(jax.random.key(2), 4)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, True, 0, 0, 128, 128, True)
    err = float(jnp.max(jnp.abs(out - attention_ref(q, k, v, causal=True))))
    rows.append(f"flash_attention_interpret_err,{0:.1f},max_err={err:.2e}")

    u = jax.random.normal(ks[0], (1, 128, 256), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 128, 256))) * 0.1
    B_ = jax.random.normal(ks[2], (1, 128, 16))
    C_ = jax.random.normal(ks[3], (1, 128, 16))
    A = -jnp.exp(jax.random.normal(jax.random.key(5), (256, 16)) * 0.5)
    D = jnp.ones((256,))
    y, _ = ssm_scan(u, dt, B_, C_, A, D, chunk=32, interpret=True)
    yr, _ = ssm_scan_ref(u, dt, B_, C_, A, D)
    rows.append(f"ssm_scan_interpret_err,{0:.1f},max_err={float(jnp.max(jnp.abs(y-yr))):.2e}")

    qm = jax.random.normal(ks[0], (1, 2, 128, 64), jnp.float32)
    g = jax.random.normal(ks[3], (1, 2, 128, 2), jnp.float32)
    h, _ = mlstm_chunkwise(qm, qm, qm, g, chunk=32, interpret=True)
    hr, _ = mlstm_ref(qm, qm, qm, g)
    rows.append(f"mlstm_interpret_err,{0:.1f},max_err={float(jnp.max(jnp.abs(h-hr))):.2e}")


def main() -> None:
    rows: List[str] = ["name,us_per_call,derived"]
    bench_blockwise_attention(rows)
    bench_moe(rows)
    bench_kernels_interpret(rows)
    print("\n".join(rows))


if __name__ == "__main__":
    main()
