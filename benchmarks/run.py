"""Benchmark aggregator: one section per paper table/figure + framework
micro-benches. ``python -m benchmarks.run [--quick]``"""
from __future__ import annotations

import argparse
import time


def section(title: str):
    print(f"\n{'='*70}\n== {title}\n{'='*70}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer seeds")
    args = ap.parse_args()
    seeds = 2 if args.quick else 5
    t0 = time.time()

    from benchmarks import fig1

    section("Fig. 1a/1d — completion time & cost vs JOB LENGTH (P/F/O)")
    rc = fig1.main(["--axis", "length", "--seeds", str(seeds)])

    section("Fig. 1b/1e — vs MEMORY FOOTPRINT")
    rc |= fig1.main(["--axis", "memory", "--seeds", str(seeds)])

    section("Fig. 1c/1f — vs REVOCATION COUNT")
    rc |= fig1.main(["--axis", "revocations", "--seeds", str(seeds)])

    section("Price-ratio sensitivity (threats-to-validity, beyond paper)")
    fig1.main(["--axis", "revocations", "--seeds", str(seeds), "--ratio-sweep"])

    section("Kernel micro-benchmarks (XLA paths + interpret-mode checks)")
    from benchmarks import kernels_bench

    kernels_bench.main()

    section("Spot-training orchestrator goodput (real JAX training)")
    from benchmarks import orchestrator_bench

    orchestrator_bench.main(quick=args.quick)

    print(f"\n# benchmarks done in {time.time()-t0:.0f}s, fig1 orderings rc={rc}")
    if rc:
        raise SystemExit(rc)


if __name__ == "__main__":
    main()
