#!/usr/bin/env python
"""Simulator-core sweep bench: vectorized vs scalar-oracle, bit-exact.

The ISSUE-9 tentpole: the simulator hot path (trace generation, next-
revocation queries, per-hour billing, the full policy simulator) moved
from per-market-per-hour Python loops to numpy over markets × hours. This
bench runs a thousand-market, year-long (8760 h), multi-seed sweep through
BOTH paths, asserts the vectorized results equal the retained scalar
references BIT-FOR-BIT, asserts the wall-clock speedup floor, and writes
``BENCH_sim.json`` (wall seconds + markets×hours/sec per stage) so
``tools/check_bench.py`` can re-assert the committed floor in CI.

Stages (each timed separately; the floor is asserted on the totals):

* ``trace_generation`` — ``generate_markets`` vs ``generate_markets_scalar``
  (same ``default_rng`` draw order; ``np.array_equal`` on prices),
* ``next_revocation`` — suffix-scan table build + O(1) lookups vs the
  scalar per-query suffix scan, on a deterministic query set,
* ``billing`` — ``bill_session`` with a :class:`PriceTable` vs the scalar
  per-hour-cell biller, one year-long session per market (exact
  ``Breakdown`` dict equality),
* ``simulate`` — ``Simulator(engine="vectorized")`` vs
  ``engine="reference")`` over a mixed siwoft/checkpoint job set, sharing
  precomputed features so only the engine difference is timed.

Usage:
    python benchmarks/sim_bench.py            # full sweep (committed run)
    python benchmarks/sim_bench.py --quick    # CI smoke (writes quick:true)
    python benchmarks/sim_bench.py --profile  # cProfile the vectorized pass
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core.accounting import Breakdown, PriceTable, Session, bill_session
from repro.core.market import (
    generate_markets,
    generate_markets_scalar,
    next_revocation_scalar,
    next_revocation_table,
    split_history_future,
)
from repro.core.policies import CheckpointPolicy, Job, SiwoftPolicy
from repro.core.provisioner import MarketFeatures
from repro.core.simulator import Simulator

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_sim.json"

# 42 regions × 4 zones × 6 menu shapes = 1008 markets — the thousand-market
# scale the CloudSim-Plus-style generative sweeps need; a year of hours.
FULL = dict(
    regions=tuple(f"r{i:02d}" for i in range(42)),
    n_hours=8760,
    seeds=(0, 1),
    queries=200_000,
    n_jobs=24,
    speedup_floor=10.0,
)
QUICK = dict(
    regions=None,  # the default 6-region menu (144 markets)
    n_hours=1464,
    seeds=(0,),
    queries=20_000,
    n_jobs=8,
    speedup_floor=2.0,
)


def _gen_kwargs(cfg, seed):
    kw = dict(seed=seed, n_hours=cfg["n_hours"])
    if cfg["regions"] is not None:
        kw["regions"] = cfg["regions"]
    return kw


def _stage(scalar_s, vector_s, exact, **extra):
    rep = {
        "scalar_seconds": round(scalar_s, 4),
        "vectorized_seconds": round(vector_s, 4),
        "speedup": round(scalar_s / max(vector_s, 1e-9), 2),
        **extra,
    }
    return rep, exact


def stage_trace_generation(cfg):
    t_s = t_v = 0.0
    exact = True
    cells = 0
    market_sets = []
    for seed in cfg["seeds"]:
        t0 = time.perf_counter()
        ms_s = generate_markets_scalar(**_gen_kwargs(cfg, seed))
        t_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        ms_v = generate_markets(**_gen_kwargs(cfg, seed))
        t_v += time.perf_counter() - t0
        exact = exact and np.array_equal(ms_s.prices, ms_v.prices)
        cells += ms_v.prices.size
        market_sets.append(ms_v)
    rep, exact = _stage(
        t_s, t_v, exact,
        markets_hours_per_sec_scalar=round(cells / max(t_s, 1e-9)),
        markets_hours_per_sec_vectorized=round(cells / max(t_v, 1e-9)),
    )
    return rep, exact, market_sets


def stage_next_revocation(cfg, market_sets):
    t_s = t_v = 0.0
    exact = True
    n_queries = 0
    for ms in market_sets:
        rev = ms.revocation_matrix()
        n, n_hours = rev.shape
        # deterministic query set touching every market and the whole range
        # (incl. past-the-end, which must answer None on both paths)
        q = cfg["queries"]
        q_m = [(7 * i) % n for i in range(q)]
        q_h = [(13 * i) % (n_hours + 2) for i in range(q)]
        t0 = time.perf_counter()
        got_s = [next_revocation_scalar(rev[m], h) for m, h in zip(q_m, q_h)]
        t_s += time.perf_counter() - t0
        qm, qh = np.asarray(q_m), np.asarray(q_h)
        t0 = time.perf_counter()
        table = next_revocation_table(rev)
        # the sweep-shaped access pattern: the whole query batch in one
        # gather (past-the-end queries answer -1/None on both paths)
        ans = np.where(qh >= n_hours, -1, table[qm, np.minimum(qh, n_hours - 1)])
        t_v += time.perf_counter() - t0
        got_v = [None if a < 0 else int(a) for a in ans]  # untimed unpack
        exact = exact and got_s == got_v
        n_queries += q
    rep, exact = _stage(t_s, t_v, exact, queries=n_queries)
    return rep, exact


def _year_long_sessions(fut):
    """One session per market spanning (almost) the whole future window,
    with a fractional start so partial billing cells are exercised."""
    dur = fut.n_hours - 0.5
    return [
        Session(m.market_id, 0.25, intervals=[("execution", dur)])
        for m in fut.markets
    ]


def stage_billing(cfg, market_sets):
    t_s = t_v = 0.0
    exact = True
    cells = 0
    for ms in market_sets:
        _, fut = split_history_future(ms, ms.n_hours // 2)
        prices, n_last = fut.prices, fut.n_hours - 1
        closure = lambda m, h: float(prices[m, min(int(h), n_last)])  # noqa: E731
        table = PriceTable(fut.prices)
        bd_s, bd_v = Breakdown(), Breakdown()
        t0 = time.perf_counter()
        for s in _year_long_sessions(fut):
            bill_session(s, closure, bd_s)  # callable -> scalar biller
        t_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        for s in _year_long_sessions(fut):
            bill_session(s, table, bd_v)  # PriceTable -> vectorized biller
        t_v += time.perf_counter() - t0
        exact = exact and (
            bd_s.time == bd_v.time
            and bd_s.cost == bd_v.cost
            and bd_s.leg_cost == bd_v.leg_cost
            and bd_s.sessions == bd_v.sessions
        )
        cells += len(fut.markets) * fut.n_hours
    rep, exact = _stage(
        t_s, t_v, exact,
        cells=cells,
        markets_hours_per_sec_scalar=round(cells / max(t_s, 1e-9)),
        markets_hours_per_sec_vectorized=round(cells / max(t_v, 1e-9)),
    )
    return rep, exact


def _job_set(n_jobs):
    lengths = (60.0, 140.0, 260.0, 380.0)
    mems = (16.0, 30.0, 64.0, 120.0)
    return [
        Job(
            length_hours=lengths[i % len(lengths)],
            memory_gb=mems[i % len(mems)],
            job_id=i,
        )
        for i in range(n_jobs)
    ]


def stage_simulate(cfg, market_sets):
    """Full-policy runs on the first seed's markets. Features (the O(n²)
    correlation matrix) are shared across engines so the timing isolates
    the engine difference: next-revocation tables, PriceTable billing,
    suitable-set memoization."""
    ms = market_sets[0]
    hist, fut = split_history_future(ms, ms.n_hours // 2)
    feats = MarketFeatures.from_history(hist)
    jobs = _job_set(cfg["n_jobs"])

    def run(engine):
        sim = Simulator(hist, fut, seed=0, engine=engine, feats=feats)
        out = Breakdown()
        out.add(sim.run_jobs(jobs, SiwoftPolicy()))
        out.add(sim.run_jobs(jobs, CheckpointPolicy(), n_revocations=2))
        return out

    t0 = time.perf_counter()
    bd_s = run("reference")
    t_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    bd_v = run("vectorized")
    t_v = time.perf_counter() - t0
    exact = (
        bd_s.time == bd_v.time
        and bd_s.cost == bd_v.cost
        and bd_s.leg_cost == bd_v.leg_cost
        and bd_s.revocations == bd_v.revocations
        and bd_s.sessions == bd_v.sessions
    )
    rep, exact = _stage(t_s, t_v, exact, jobs=len(jobs) * 2)
    return rep, exact


def _progress(name, rep):
    print(
        f"  {name}: scalar {rep['scalar_seconds']}s, "
        f"vectorized {rep['vectorized_seconds']}s ({rep['speedup']}×)"
    )


def run_bench(cfg, quick: bool) -> dict:
    stages = {}
    exact = {}
    stages["trace_generation"], exact["trace_bitexact"], market_sets = (
        stage_trace_generation(cfg)
    )
    _progress("trace_generation", stages["trace_generation"])
    stages["next_revocation"], exact["next_revocation_equal"] = (
        stage_next_revocation(cfg, market_sets)
    )
    _progress("next_revocation", stages["next_revocation"])
    stages["billing"], exact["billing_bitexact"] = stage_billing(cfg, market_sets)
    _progress("billing", stages["billing"])
    stages["simulate"], exact["simulate_bitexact"] = stage_simulate(cfg, market_sets)
    _progress("simulate", stages["simulate"])

    scalar_total = sum(s["scalar_seconds"] for s in stages.values())
    vector_total = sum(s["vectorized_seconds"] for s in stages.values())
    n_markets = len(market_sets[0].markets)
    payload = {
        "bench": "sim",
        "quick": quick,
        "markets": n_markets,
        "hours": cfg["n_hours"],
        "seeds": list(cfg["seeds"]),
        "speedup_floor": cfg["speedup_floor"],
        "stages": stages,
        "total": {
            "scalar_seconds": round(scalar_total, 4),
            "vectorized_seconds": round(vector_total, 4),
            "speedup": round(scalar_total / max(vector_total, 1e-9), 2),
        },
        "exact": exact,
    }

    # the two acceptance gates, asserted AT MEASUREMENT TIME (check_bench
    # re-asserts the committed numbers on every CI run)
    assert all(exact.values()), f"vectorized path diverged from oracle: {exact}"
    floor = cfg["speedup_floor"]
    assert payload["total"]["speedup"] >= floor, (
        f"vectorized core only {payload['total']['speedup']}× faster than the "
        f"scalar oracle (floor {floor}×)"
    )
    return payload


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="small CI smoke (144 markets, 61 days, 1 seed)")
    ap.add_argument("--profile", action="store_true",
                    help="cProfile the vectorized sweep and print hot spots")
    args = ap.parse_args()
    cfg = QUICK if args.quick else FULL

    if args.profile:
        import cProfile
        import pstats

        prof = cProfile.Profile()
        prof.enable()
        payload = run_bench(cfg, quick=args.quick)
        prof.disable()
        pstats.Stats(prof).sort_stats("cumulative").print_stats(15)
    else:
        payload = run_bench(cfg, quick=args.quick)

    BENCH_JSON.write_text(json.dumps(payload, indent=1) + "\n")
    total = payload["total"]
    print(
        f"sim_bench: {payload['markets']} markets × {payload['hours']} h × "
        f"{len(payload['seeds'])} seed(s): scalar {total['scalar_seconds']}s, "
        f"vectorized {total['vectorized_seconds']}s ({total['speedup']}×, "
        f"floor {payload['speedup_floor']}×); all stages bit-exact"
    )
    print(f"wrote {BENCH_JSON}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
