#!/usr/bin/env python
"""Schema-validate every committed ``BENCH_*.json`` (stdlib only; CI).

The machine-readable perf trajectory started in PR 4 only works if the
files keep their shape: a bench that silently drops a key or reorders its
scenario ids rots the trajectory without failing anything. This gate
checks, per file:

* ``BENCH_orchestrator.json`` — the three orchestrator modes are present
  with their full metric set, plus the split scenario;
* ``BENCH_serve.json`` — the serving scenarios carry every policy with
  the full metric set, and scenario ids are 0..n-1 (monotonic, dense);
* any OTHER ``BENCH_*.json`` — must at least be a JSON object, and if it
  has a ``scenarios`` list, the ids must be monotonic.

An unknown ``BENCH_*.json`` (no dedicated checker) only gets the generic
shape check — effectively unvalidated. That used to pass silently, which
is exactly how a new bench's trajectory starts rotting; now every such
file is warned about, and ``--strict`` turns the warning into a failure
so CI can insist that each committed bench has a real schema.

Exit 0 on success; prints each violation and exits 1 otherwise.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
import sys

REPO = Path(__file__).resolve().parents[1]

# Mirror of the Breakdown component registry in src/repro/core/accounting.py
# (TIME_COMPONENTS / COST_COMPONENTS). repro-lint's conservation pass (C003)
# fails if the code-side registry grows a component this gate does not know.
KNOWN_TIME_COMPONENTS = (
    "execution", "re_execution", "checkpointing", "recovery",
    "reshard", "startup", "slo_violation",
)
KNOWN_COST_COMPONENTS = KNOWN_TIME_COMPONENTS + ("billing_buffer",)

ORCH_MODE_KEYS = {
    "useful_steps", "wasted_steps", "revocations", "goodput", "cost_usd",
    "completion_trace_hours", "reshard_bytes", "restore_bytes",
    "reshard_usd", "recovery_usd", "cost_to_complete", "final_loss",
    "leg_costs",
}
ORCH_SPLIT_KEYS = {
    "steps", "allocations_used", "revocations", "leg_repairs",
    "reshard_bytes", "full_restore_bytes", "cost_usd", "leg_costs",
    "completion_trace_hours", "final_loss",
}
SERVE_POLICY_KEYS = {
    "cost_usd", "slo_violation_seconds", "served_tokens", "shed_tokens",
    "queued_token_seconds", "p50_delay_seconds", "p99_delay_seconds",
    "revocations", "repairs", "migrated_bytes",
    "restored_bytes", "replicas_provisioned", "scale_ups", "scale_downs",
    "idle_headroom_tokens", "capacity_tokens_per_sec",
    "billing_buffer_usd",
}
SERVE_POLICIES = {"fleet", "autoscale", "on_demand", "static"}
KERNEL_BENCH_KEYS = {
    "prompt_len", "max_context", "decode_steps", "page_size", "backend",
    "batches",
}
KERNEL_ROW_KEYS = {
    "batch", "prefill_tokens_per_sec", "decode_dense_tokens_per_sec",
    "decode_paged_tokens_per_sec",
}


def _require(errors, cond, msg):
    if not cond:
        errors.append(msg)


def check_scenario_ids(errors, name, scenarios):
    ids = [s.get("id") for s in scenarios]
    _require(
        errors,
        ids == list(range(len(ids))),
        f"{name}: scenario ids must be dense and monotonic from 0, got {ids}",
    )


def check_not_quick(errors, name, data):
    """The committed trajectory must be the FULL run: a --quick smoke that
    overwrites the repo-root JSON and gets committed silently degrades the
    whole series (this is the rot this tool exists to catch)."""
    _require(errors, data.get("quick") is False,
             f"{name}: committed bench data must be a full run "
             f"(quick: {data.get('quick')!r})")


def check_orchestrator(errors, name, data):
    _require(errors, set(data) >= {"steps", "modes", "split_scenario"},
             f"{name}: missing top-level keys")
    check_not_quick(errors, name, data)
    modes = data.get("modes", {})
    _require(errors, set(modes) == {"siwoft", "checkpoint", "hybrid"},
             f"{name}: modes must be siwoft/checkpoint/hybrid, got {sorted(modes)}")
    for mode, rep in modes.items():
        missing = ORCH_MODE_KEYS - set(rep)
        _require(errors, not missing, f"{name}: modes.{mode} missing {sorted(missing)}")
    split = data.get("split_scenario", {})
    missing = ORCH_SPLIT_KEYS - set(split)
    _require(errors, not missing, f"{name}: split_scenario missing {sorted(missing)}")


def check_serve(errors, name, data):
    _require(errors, set(data) >= {"bench", "workload", "scenarios"},
             f"{name}: missing top-level keys")
    _require(errors, data.get("bench") == "serve", f"{name}: bench != 'serve'")
    check_not_quick(errors, name, data)
    scenarios = data.get("scenarios", [])
    _require(errors, scenarios, f"{name}: no scenarios")
    check_scenario_ids(errors, name, scenarios)
    for s in scenarios:
        sid = s.get("id")
        _require(errors, set(s) >= {"id", "name", "hours", "policies"},
                 f"{name}: scenario {sid} missing keys")
        pols = s.get("policies", {})
        _require(errors, set(pols) == SERVE_POLICIES,
                 f"{name}: scenario {sid} policies {sorted(pols)} != {sorted(SERVE_POLICIES)}")
        for p, rep in pols.items():
            missing = SERVE_POLICY_KEYS - set(rep)
            _require(errors, not missing,
                     f"{name}: scenario {sid}.{p} missing {sorted(missing)}")
        check_autoscale_inequality(errors, name, s)
    check_kernel_bench(errors, name, data)


def check_autoscale_inequality(errors, name, scenario):
    """The committed diurnal numbers must still show the tentpole result
    the bench asserted at measurement time: the demand-driven autoscaler
    STRICTLY cheaper than the static-peak fleet at ZERO SLO-violation
    seconds (and with real night-time headroom shed). A regenerated
    BENCH_serve.json where autoscaling stopped paying fails CI here, not
    in a human's diff review."""
    if scenario.get("name") != "diurnal":
        return
    pols = scenario.get("policies", {})
    auto, fleet = pols.get("autoscale"), pols.get("fleet")
    if not isinstance(auto, dict) or not isinstance(fleet, dict):
        return  # missing-policy error already recorded
    sid = scenario.get("id")
    _require(errors, auto.get("slo_violation_seconds") == 0.0,
             f"{name}: scenario {sid} autoscale violates the SLO "
             f"({auto.get('slo_violation_seconds')}s)")
    _require(errors, auto.get("cost_usd", 1e18) < fleet.get("cost_usd", 0),
             f"{name}: scenario {sid} autoscale (${auto.get('cost_usd')}) not "
             f"strictly cheaper than static-peak fleet (${fleet.get('cost_usd')})")
    _require(
        errors,
        auto.get("idle_headroom_tokens", 1e18)
        < fleet.get("idle_headroom_tokens", 0),
        f"{name}: scenario {sid} autoscale shed no idle headroom",
    )


def check_kernel_bench(errors, name, data):
    """The committed serve bench must carry the hot-path microbench, and
    its numbers must still satisfy the acceptance inequality the bench
    asserted at measurement time: the paged KV pool beats decoding against
    the dense max-context cache at serving batch sizes (batch ≥ 4)."""
    kb = data.get("kernel_bench")
    _require(errors, isinstance(kb, dict),
             f"{name}: missing kernel_bench (run serve_bench.py --kernels)")
    if not isinstance(kb, dict):
        return
    missing = KERNEL_BENCH_KEYS - set(kb)
    _require(errors, not missing, f"{name}: kernel_bench missing {sorted(missing)}")
    rows = kb.get("batches", [])
    _require(errors, isinstance(rows, list) and rows,
             f"{name}: kernel_bench.batches must be a non-empty list")
    batches = set()
    for row in rows if isinstance(rows, list) else []:
        if not isinstance(row, dict):
            errors.append(f"{name}: kernel_bench batch row must be an object")
            continue
        missing = KERNEL_ROW_KEYS - set(row)
        _require(errors, not missing,
                 f"{name}: kernel_bench batch row missing {sorted(missing)}")
        if missing:
            continue
        batches.add(row["batch"])
        if row["batch"] >= 4:
            _require(
                errors,
                row["decode_paged_tokens_per_sec"]
                >= row["decode_dense_tokens_per_sec"],
                f"{name}: kernel_bench batch {row['batch']}: paged decode "
                f"({row['decode_paged_tokens_per_sec']} tok/s) slower than "
                f"dense ({row['decode_dense_tokens_per_sec']} tok/s)",
            )
    _require(errors, 4 in batches,
             f"{name}: kernel_bench must include a batch-4 row, got {sorted(batches)}")


def check_breakdowns(errors, name, data, path="", depth=0):
    """Any ``time_breakdown``/``cost_breakdown`` dict a bench report carries
    must use only registry component names — the same conservation law
    repro-lint's C-rules enforce on the code side."""
    if depth > 6 or not isinstance(data, dict):
        return
    for key, val in data.items():
        here = f"{path}.{key}" if path else key
        if key in ("time_breakdown", "cost_breakdown") and isinstance(val, dict):
            known = (
                KNOWN_TIME_COMPONENTS
                if key == "time_breakdown"
                else KNOWN_COST_COMPONENTS
            )
            unknown = set(val) - set(known)
            _require(errors, not unknown,
                     f"{name}: {here} has unknown components {sorted(unknown)}")
        if isinstance(val, dict):
            check_breakdowns(errors, name, val, here, depth + 1)
        elif isinstance(val, list):
            for i, item in enumerate(val):
                check_breakdowns(errors, name, item, f"{here}[{i}]", depth + 1)


SIM_STAGES = {"trace_generation", "next_revocation", "billing", "simulate"}
SIM_STAGE_KEYS = {"scalar_seconds", "vectorized_seconds", "speedup"}
SIM_EXACT_FLAGS = {
    "trace_bitexact", "next_revocation_equal", "billing_bitexact",
    "simulate_bitexact",
}
SIM_SPEEDUP_FLOOR = 10.0  # ISSUE 9 acceptance: ≥10× on the committed sweep


def check_sim(errors, name, data):
    """``benchmarks/sim_bench.py`` output: the vectorized-core sweep.

    Beyond the schema, re-assert the two acceptance gates on the COMMITTED
    numbers: every bit-exactness flag is true, and the total speedup of
    the full 1000-market year-long sweep clears the 10× floor. sim_bench
    asserts both at measurement time; this gate catches a regressed or
    hand-edited JSON landing in the tree."""
    _require(errors, set(data) >= {"bench", "markets", "hours", "seeds",
                                   "speedup_floor", "stages", "total", "exact"},
             f"{name}: missing top-level keys")
    _require(errors, data.get("bench") == "sim", f"{name}: bench != 'sim'")
    check_not_quick(errors, name, data)
    _require(errors, data.get("markets", 0) >= 1000,
             f"{name}: committed sweep must cover >= 1000 markets "
             f"(got {data.get('markets')})")
    _require(errors, data.get("hours", 0) >= 8760,
             f"{name}: committed sweep must cover >= 8760 hours "
             f"(got {data.get('hours')})")
    stages = data.get("stages", {})
    _require(errors, set(stages) == SIM_STAGES,
             f"{name}: stages {sorted(stages)} != {sorted(SIM_STAGES)}")
    for stage, rep in stages.items():
        missing = SIM_STAGE_KEYS - set(rep)
        _require(errors, not missing,
                 f"{name}: stages.{stage} missing {sorted(missing)}")
    total = data.get("total", {})
    missing = SIM_STAGE_KEYS - set(total)
    _require(errors, not missing, f"{name}: total missing {sorted(missing)}")
    _require(errors, data.get("speedup_floor") == SIM_SPEEDUP_FLOOR,
             f"{name}: speedup_floor must be {SIM_SPEEDUP_FLOOR} "
             f"(got {data.get('speedup_floor')!r})")
    _require(errors, total.get("speedup", 0.0) >= SIM_SPEEDUP_FLOOR,
             f"{name}: total.speedup {total.get('speedup')} below the "
             f"{SIM_SPEEDUP_FLOOR}x floor")
    exact = data.get("exact", {})
    _require(errors, set(exact) == SIM_EXACT_FLAGS,
             f"{name}: exact flags {sorted(exact)} != {sorted(SIM_EXACT_FLAGS)}")
    for flag, val in exact.items():
        _require(errors, val is True,
                 f"{name}: exact.{flag} must be true (vectorized path must "
                 f"match the scalar oracle bit-for-bit), got {val!r}")


def check_generic(errors, name, data):
    _require(errors, isinstance(data, dict), f"{name}: top level must be an object")
    if isinstance(data, dict) and isinstance(data.get("scenarios"), list):
        check_scenario_ids(errors, name, data["scenarios"])


CHECKERS = {
    "BENCH_orchestrator.json": check_orchestrator,
    "BENCH_serve.json": check_serve,
    "BENCH_sim.json": check_sim,
}


def main(root: Path = REPO, strict: bool = False) -> int:
    errors: list = []
    warnings: list = []
    found = sorted(root.glob("BENCH_*.json"))
    if not found:
        errors.append("no BENCH_*.json found at the repo root")
    for path in found:
        name = path.name
        try:
            data = json.loads(path.read_text(encoding="utf-8"))
        except json.JSONDecodeError as e:
            errors.append(f"{name}: invalid JSON ({e})")
            continue
        if name not in CHECKERS:
            warnings.append(
                f"{name}: unvalidated bench (no schema checker registered — "
                f"add one to tools/check_bench.py CHECKERS)"
            )
        CHECKERS.get(name, check_generic)(errors, name, data)
        if isinstance(data, dict):
            check_breakdowns(errors, name, data)

    if strict:
        errors.extend(warnings)
    else:
        for w in warnings:
            print(f"WARNING: {w}", file=sys.stderr)
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"checked {len(found)} bench file(s); {len(errors)} problem(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--strict", action="store_true",
                    help="fail on unvalidated BENCH_*.json files (no "
                         "registered schema checker), not just warn")
    sys.exit(main(strict=ap.parse_args().strict))
