#!/usr/bin/env python
"""Docs presence + markdown link check (stdlib only; used by CI).

* asserts the documentation set exists (README.md, docs/trace-format.md,
  docs/accounting.md),
* extracts every markdown link from every tracked *.md file and verifies
  relative targets resolve to real files (anchors stripped; external
  http(s)/mailto links are not fetched).

Exit code 0 on success; prints each broken link and exits 1 otherwise.
"""
from __future__ import annotations

from pathlib import Path
import re
import sys

REPO = Path(__file__).resolve().parents[1]
REQUIRED = [
    "README.md",
    "docs/trace-format.md",
    "docs/accounting.md",
    "docs/serving.md",
    "docs/invariants.md",
    "docs/kernels.md",
    "docs/simulator-perf.md",
    "docs/observability.md",
]
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", "results", ".claude"}
# quoted exemplar material from OTHER repos — its links point into those
# repos' trees, not ours
SKIP_FILES = {"SNIPPETS.md"}


def md_files(root: Path = REPO):
    for p in sorted(root.rglob("*.md")):
        if p.name in SKIP_FILES:
            continue
        if not any(part in SKIP_DIRS for part in p.parts):
            yield p


def main(root: Path = REPO) -> int:
    errors = []
    for rel in REQUIRED:
        if not (root / rel).is_file():
            errors.append(f"missing required doc: {rel}")

    n_links = 0
    for md in md_files(root):
        for target in LINK_RE.findall(md.read_text(encoding="utf-8")):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            n_links += 1
            path = target.split("#", 1)[0]
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(
                    f"{md.relative_to(root)}: broken link -> {target}"
                )

    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    print(f"checked {n_links} relative links across "
          f"{len(list(md_files(root)))} markdown files; "
          f"{len(errors)} problem(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
