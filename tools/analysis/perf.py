"""Pass 6 — vectorized-core perf lint (V001).

ISSUE 9 moved the simulator's hot path (trace generation, next-revocation
queries, billing, fleet/router hour stepping) from per-market-per-hour
Python loops to numpy over markets × hours — a ~10× end-to-end speedup
pinned by ``BENCH_sim.json``. This pass keeps the hot modules from
quietly regressing back to interpreter-bound iteration:

* **V001** — a ``for ... in range(...)`` loop in a hot module that either
  ranges over an hour count (an identifier containing ``hour`` appears in
  the ``range`` arguments) or indexes a per-hour trace array
  (``prices``/``rev``/``eps``/... subscripted by the loop variable in the
  body). Hot modules are the six the vectorized core spans:
  ``core/{market,simulator,accounting,provisioner}.py`` and
  ``serve/{fleet,router}.py``.

Sanctioned hour loops exist — the scalar oracles kept as bit-exactness
references (``generate_markets_scalar``, ``_bill_session_scalar``, ...)
and the fleet's per-hour DECISION loop (each hour consumes the previous
hour's scaling choice, an inherently sequential recurrence). Those are
suppressed inline with ``# repro-lint: disable=V001`` plus the reason, so
every surviving diagnostic is an unreviewed hot-path loop.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Sequence

from tools.analysis.core import Diagnostic, Pass, SourceFile

# the modules the ISSUE-9 vectorization spans; everything else (tests,
# benches, the orchestrator's real-run bookkeeping) may loop freely
_HOT_MODULES = {
    ("core", "market.py"),
    ("core", "simulator.py"),
    ("core", "accounting.py"),
    ("core", "provisioner.py"),
    ("serve", "fleet.py"),
    ("serve", "router.py"),
}

# per-hour trace arrays of the simulator core: subscripting one of these
# with the loop variable is the signature of a scalar hot loop
_TRACE_NAMES = {
    "prices", "rev", "_rev", "rev_matrix", "eps", "noise", "spikes",
    "spike_mult", "rate_tokens_per_sec", "trace",
}


def _identifiers(node: ast.AST) -> List[str]:
    out: List[str] = []
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.append(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.append(sub.attr)
    return out


def _subscript_base(node: ast.Subscript) -> Optional[str]:
    """``prices[...]`` / ``self._rev[...]`` -> the trailing identifier."""
    v = node.value
    if isinstance(v, ast.Name):
        return v.id
    if isinstance(v, ast.Attribute):
        return v.attr
    return None


class PerfPass(Pass):
    name = "perf"
    rules = {
        "V001": "per-hour Python loop in a vectorized-core hot module "
                "(range over an hour count, or a trace array indexed by "
                "the loop variable)",
    }

    def applies_to(self, path: Path) -> bool:
        parts = path.parts
        if "analysis_fixtures" in parts:
            return "perf" in parts
        return (
            len(parts) >= 4
            and parts[:2] == ("src", "repro")
            and (parts[2], parts[3]) in _HOT_MODULES
        )

    def run(self, files: Sequence[SourceFile], root: Path) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for f in files:
            for node in ast.walk(f.tree):
                if isinstance(node, ast.For):
                    d = self._check_loop(f, node)
                    if d is not None:
                        diags.append(d)
        return diags

    def _check_loop(self, f: SourceFile, node: ast.For) -> Optional[Diagnostic]:
        it = node.iter
        if not (
            isinstance(it, ast.Call)
            and isinstance(it.func, ast.Name)
            and it.func.id == "range"
        ):
            return None
        # signature 1: the range bound is an hour count
        if any("hour" in ident.lower() for arg in it.args
               for ident in _identifiers(arg)):
            return self.diag(
                f, node, "V001",
                "Python loop over an hour range in a vectorized-core hot "
                "module",
                "vectorize over the hour axis (suffix scans, add.accumulate, "
                "PriceTable gathers); if the loop is a sanctioned scalar "
                "oracle or a sequential decision recurrence, suppress with "
                "the reason named",
            )
        # signature 2: the body subscripts a trace array with the loop var
        if not isinstance(node.target, ast.Name):
            return None
        loop_var = node.target.id
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Subscript):
                continue
            base = _subscript_base(sub)
            if base in _TRACE_NAMES and loop_var in _identifiers(sub.slice):
                return self.diag(
                    f, node, "V001",
                    f"Python loop indexing trace array '{base}' per "
                    f"iteration in a vectorized-core hot module",
                    "gather the whole axis in one numpy indexing op; if "
                    "scalar access is intentional (oracle/decision loop), "
                    "suppress with the reason named",
                )
        return None
