"""Pass 4 — Pallas kernel checker (P001–P004).

Static sanity over ``kernels/*/kernel*.py`` (anything the repo lowers
through ``pl.pallas_call``). TPU Pallas failures here surface as silent
garbage or compile-time shape errors far from the kernel, so the checker
pins the contracts at the source:

* **P001** — a ``BlockSpec`` block shape that does not divide the
  declared ``out_shape`` ref shape (checked where both are integer
  literals; symbolic dims are skipped — the runtime asserts cover those).
* **P002** — an ``index_map`` whose arity differs from the grid rank:
  every grid axis indexes every BlockSpec map, so a missing lambda
  parameter silently reuses the wrong block. Kernels built through a
  ``grid_spec=`` kwarg (``GridSpec`` / ``pltpu.PrefetchScalarGridSpec``)
  are parsed too: with scalar prefetch the maps take
  ``grid_rank + num_scalar_prefetch`` parameters, because every
  prefetched operand (e.g. a paged-attention block table) is appended to
  the index-map signature after the grid axes.
* **P003** — Python side effects in a kernel body: ``print``, mutation
  of closure state (``.append``/``.extend``/``.update`` on names defined
  outside the kernel), ``global``/``nonlocal``, or ``.at[...]`` on a
  closure value — the kernel trace runs once at lowering time, so none of
  these do what they appear to do per grid step.
* **P004** — a kernel module without its ``ref.py`` counterpart, or whose
  package is never exercised by ``tests/test_kernels.py``: every
  ``pallas_call`` needs a pure-XLA reference implementation and a test
  that diffs against it.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from tools.analysis.core import Diagnostic, Pass, SourceFile


def _attr_tail(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _int_tuple(node: ast.expr) -> Optional[List[Optional[int]]]:
    """Tuple literal -> per-dim int (None for symbolic dims)."""
    if not isinstance(node, ast.Tuple):
        return None
    out: List[Optional[int]] = []
    for e in node.elts:
        if isinstance(e, ast.Constant) and isinstance(e.value, int):
            out.append(e.value)
        else:
            out.append(None)
    return out


def _as_list(node: Optional[ast.expr]) -> List[ast.expr]:
    if node is None:
        return []
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    return [node]


class _FnScope(ast.NodeVisitor):
    """Assignment map (name -> value expr) per enclosing function body."""

    def __init__(self):
        self.assigns: Dict[str, ast.expr] = {}

    def visit_Assign(self, node: ast.Assign):
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            self.assigns[node.targets[0].id] = node.value
        self.generic_visit(node)


class PallasPass(Pass):
    name = "pallas"
    rules = {
        "P001": "BlockSpec block shape does not divide the declared ref "
                "shape",
        "P002": "index_map arity differs from the grid rank",
        "P003": "Python side effect in a Pallas kernel body",
        "P004": "pallas_call kernel without a ref.py counterpart exercised "
                "by tests/test_kernels.py",
    }

    def applies_to(self, path: Path) -> bool:
        parts = path.parts
        if "analysis_fixtures" in parts:
            return "pallas" in parts or "kernels" in parts
        return "kernels" in parts and path.name.startswith("kernel")

    def run(self, files: Sequence[SourceFile], root: Path) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for f in files:
            assigns = self._module_assigns(f)
            calls = [
                n
                for n in ast.walk(f.tree)
                if isinstance(n, ast.Call)
                and _attr_tail(n.func) == "pallas_call"
            ]
            for call in calls:
                scope = self._enclosing_assigns(f, call, assigns)
                diags.extend(self._check_call(f, call, scope))
            if calls:
                diags.extend(self._check_ref_counterpart(f, root))
        return diags

    # -- resolution helpers -------------------------------------------------

    def _module_assigns(self, f: SourceFile) -> Dict[str, ast.expr]:
        sc = _FnScope()
        sc.visit(f.tree)
        return sc.assigns

    def _enclosing_assigns(
        self, f: SourceFile, call: ast.Call, fallback: Dict[str, ast.expr]
    ) -> Dict[str, ast.expr]:
        # nearest FunctionDef containing the call, by line span
        best: Optional[ast.FunctionDef] = None
        for node in ast.walk(f.tree):
            if isinstance(node, ast.FunctionDef):
                end = getattr(node, "end_lineno", node.lineno)
                if node.lineno <= call.lineno <= end:
                    if best is None or node.lineno > best.lineno:
                        best = node
        if best is None:
            return fallback
        sc = _FnScope()
        sc.visit(best)
        merged = dict(fallback)
        merged.update(sc.assigns)
        return merged

    def _resolve(
        self, node: Optional[ast.expr], scope: Dict[str, ast.expr]
    ) -> Optional[ast.expr]:
        seen = 0
        while isinstance(node, ast.Name) and node.id in scope and seen < 5:
            node = scope[node.id]
            seen += 1
        return node

    # -- checks -------------------------------------------------------------

    def _check_call(
        self, f: SourceFile, call: ast.Call, scope: Dict[str, ast.expr]
    ) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        kw = {k.arg: k.value for k in call.keywords if k.arg}

        grid_node = kw.get("grid")
        in_specs_node = kw.get("in_specs")
        out_specs_node = kw.get("out_specs")
        n_prefetch = 0
        gs = self._resolve(kw.get("grid_spec"), scope)
        if isinstance(gs, ast.Call) and _attr_tail(gs.func) in (
            "GridSpec", "PrefetchScalarGridSpec"
        ):
            gkw = {k.arg: k.value for k in gs.keywords if k.arg}
            grid_node = gkw.get("grid", grid_node)
            in_specs_node = gkw.get("in_specs", in_specs_node)
            out_specs_node = gkw.get("out_specs", out_specs_node)
            npre = self._resolve(gkw.get("num_scalar_prefetch"), scope)
            if isinstance(npre, ast.Constant) and isinstance(npre.value, int):
                n_prefetch = npre.value

        grid = self._resolve(grid_node, scope)
        grid_rank: Optional[int] = None
        if isinstance(grid, ast.Tuple):
            grid_rank = len(grid.elts)
        elif isinstance(grid, ast.Constant) and isinstance(grid.value, int):
            grid_rank = 1

        in_specs = _as_list(self._resolve(in_specs_node, scope))
        out_specs = _as_list(self._resolve(out_specs_node, scope))
        out_shapes = _as_list(self._resolve(kw.get("out_shape"), scope))

        # P002: every BlockSpec index_map takes one arg per grid axis, plus
        # one per scalar-prefetched operand when a PrefetchScalarGridSpec is
        # in play (the prefetch refs ride after the grid indices)
        if grid_rank is not None:
            want = grid_rank + n_prefetch
            for spec in in_specs + out_specs:
                spec = self._resolve(spec, scope)
                lam = self._blockspec_index_map(spec, scope)
                if lam is not None:
                    arity = len(lam.args.args)
                    if arity != want:
                        detail = (
                            f"grid rank {grid_rank} + {n_prefetch} scalar-"
                            f"prefetch operand(s)"
                            if n_prefetch
                            else f"the grid has rank {grid_rank}"
                        )
                        diags.append(
                            self.diag(
                                f, lam, "P002",
                                f"index_map takes {arity} args but {detail}",
                                "one index_map parameter per grid axis, then "
                                "one per prefetched ref",
                            )
                        )

        # P001: literal block dims must divide literal ref dims
        for spec, shape in zip(out_specs, out_shapes):
            spec = self._resolve(spec, scope)
            shape = self._resolve(shape, scope)
            block = self._blockspec_shape(spec, scope)
            ref = self._shapedtype_shape(shape, scope)
            if block is None or ref is None:
                continue
            for i, (b, r) in enumerate(zip(block, ref)):
                if b is not None and r is not None and b > 0 and r % b != 0:
                    diags.append(
                        self.diag(
                            f, spec if spec is not None else call, "P001",
                            f"block dim {i} = {b} does not divide ref dim "
                            f"{r}",
                            "block shapes must tile the ref exactly (pad in "
                            "ops.py, not in the kernel)",
                        )
                    )

        # P003: kernel body side effects
        kernel_fn = self._kernel_function(f, call, scope)
        if kernel_fn is not None:
            diags.extend(self._check_kernel_body(f, kernel_fn))
        return diags

    def _blockspec_index_map(
        self, spec: Optional[ast.expr], scope: Dict[str, ast.expr]
    ) -> Optional[ast.Lambda]:
        if not (isinstance(spec, ast.Call) and _attr_tail(spec.func) == "BlockSpec"):
            return None
        cand: Optional[ast.expr] = None
        if len(spec.args) >= 2:
            cand = spec.args[1]
        for k in spec.keywords:
            if k.arg == "index_map":
                cand = k.value
        cand = self._resolve(cand, scope)
        return cand if isinstance(cand, ast.Lambda) else None

    def _blockspec_shape(
        self, spec: Optional[ast.expr], scope: Dict[str, ast.expr]
    ) -> Optional[List[Optional[int]]]:
        if not (isinstance(spec, ast.Call) and _attr_tail(spec.func) == "BlockSpec"):
            return None
        cand: Optional[ast.expr] = spec.args[0] if spec.args else None
        for k in spec.keywords:
            if k.arg == "block_shape":
                cand = k.value
        return _int_tuple(self._resolve(cand, scope))

    def _shapedtype_shape(
        self, node: Optional[ast.expr], scope: Dict[str, ast.expr]
    ) -> Optional[List[Optional[int]]]:
        if not (
            isinstance(node, ast.Call)
            and _attr_tail(node.func) == "ShapeDtypeStruct"
        ):
            return None
        cand: Optional[ast.expr] = node.args[0] if node.args else None
        for k in node.keywords:
            if k.arg == "shape":
                cand = k.value
        return _int_tuple(self._resolve(cand, scope))

    def _kernel_function(
        self, f: SourceFile, call: ast.Call, scope: Dict[str, ast.expr]
    ) -> Optional[ast.FunctionDef]:
        if not call.args:
            return None
        target = self._resolve(call.args[0], scope)
        # functools.partial(kernel_fn, ...)
        if isinstance(target, ast.Call) and _attr_tail(target.func) == "partial":
            target = self._resolve(target.args[0] if target.args else None, scope)
        name: Optional[str] = None
        if isinstance(target, ast.Name):
            name = target.id
        elif isinstance(target, ast.Attribute):
            name = target.attr
        if name is None:
            return None
        for node in ast.walk(f.tree):
            if isinstance(node, ast.FunctionDef) and node.name == name:
                return node
        return None

    def _check_kernel_body(
        self, f: SourceFile, fn: ast.FunctionDef
    ) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        params = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
        if fn.args.vararg:
            params.add(fn.args.vararg.arg)
        local: set = set(params)
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            local.add(n.id)
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                if isinstance(node.target, ast.Name):
                    local.add(node.target.id)
            elif isinstance(node, ast.FunctionDef) and node is not fn:
                local.add(node.name)

        for node in ast.walk(fn):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                diags.append(
                    self.diag(
                        f, node, "P003",
                        "global/nonlocal mutation inside a kernel body",
                        "kernel tracing runs once — carry state in VMEM "
                        "scratch refs",
                    )
                )
            elif isinstance(node, ast.Call):
                tail = _attr_tail(node.func)
                if tail == "print":
                    diags.append(
                        self.diag(
                            f, node, "P003",
                            "print() inside a kernel body",
                            "use pl.debug_print, or drop the side effect",
                        )
                    )
                elif tail in ("append", "extend", "update") and isinstance(
                    node.func, ast.Attribute
                ):
                    base = node.func.value
                    if isinstance(base, ast.Name) and base.id not in local:
                        diags.append(
                            self.diag(
                                f, node, "P003",
                                f"mutates closure '{base.id}.{tail}' inside "
                                f"a kernel body",
                                "trace-time mutation runs once, not per grid "
                                "step",
                            )
                        )
            elif isinstance(node, ast.Subscript):
                v = node.value
                if (
                    isinstance(v, ast.Attribute)
                    and v.attr == "at"
                    and isinstance(v.value, ast.Name)
                    and v.value.id not in local
                ):
                    diags.append(
                        self.diag(
                            f, node, "P003",
                            f"functional .at[] update on closure value "
                            f"'{v.value.id}' inside a kernel body",
                            "write through the output/scratch ref instead",
                        )
                    )
        return diags

    def _check_ref_counterpart(
        self, f: SourceFile, root: Path
    ) -> List[Diagnostic]:
        """P004 — only for files living in a kernels/<pkg>/ package."""
        diags: List[Diagnostic] = []
        parts = f.path.parts
        if "kernels" not in parts[:-1]:
            return diags
        pkg_dir = f.path.parent
        if pkg_dir.parent.name != "kernels":
            return diags
        if not (pkg_dir / "ref.py").is_file():
            diags.append(
                Diagnostic(
                    f.path, 1, 0, "P004",
                    f"kernel package '{pkg_dir.name}' has no ref.py "
                    f"reference implementation",
                    "every pallas_call needs a pure-XLA reference to diff "
                    "against",
                )
            )
        tests = root / "tests" / "test_kernels.py"
        if not tests.is_file() or pkg_dir.name not in tests.read_text(
            encoding="utf-8"
        ):
            diags.append(
                Diagnostic(
                    f.path, 1, 0, "P004",
                    f"kernel package '{pkg_dir.name}' is not exercised by "
                    f"tests/test_kernels.py",
                    "add a kernel-vs-ref equivalence test",
                )
            )
        return diags
