"""Pass 5 — sharding-spec checker (S001–S003).

``PartitionSpec`` axis names are stringly-typed: a typo (``"poda"``) or an
axis the mesh never declares fails only at runtime, deep inside jit, with
an error that names neither the rule table nor the spec site. This pass
cross-references every axis-name literal against the axes the scoped tree
actually declares:

* **S001** — an axis name used in a ``PartitionSpec``/``P`` call or a
  rule-table entry that no mesh declaration (``jax.make_mesh``, ``Mesh``,
  ``axis_names=``) in the scanned tree declares.
* **S002** — the same axis repeated inside one spec or one joint-axes
  tuple: a mesh axis may partition a tensor at most once.
* **S003** — a rule-table entry that maps a scan axis (``"layers"``,
  ``"groups"`` — lax.scan stacking dims) to a non-empty axes tuple: scan
  dims are never sharded (every device runs every layer).

Declarations and uses are collected repo-wide across the scoped files
(``src/repro/{dist,launch}``), so the mesh built in ``launch/mesh.py``
legitimises the rule tables in ``dist/sharding.py``. When the scanned set
declares no axes at all, S001 stays silent (nothing to enforce against).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from tools.analysis.core import Diagnostic, Pass, SourceFile

_MESH_BUILDERS = {"make_mesh", "Mesh", "make_production_mesh"}
_SPEC_NAMES = {"P", "PartitionSpec"}
_SCAN_AXES = {"layers", "groups"}


def _tail(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _str_tuples(node: ast.expr) -> List[Tuple[str, ...]]:
    """All all-string tuple literals reachable through IfExp branches."""
    if isinstance(node, ast.Tuple) and all(
        isinstance(e, ast.Constant) and isinstance(e.value, str)
        for e in node.elts
    ):
        return [tuple(e.value for e in node.elts)]
    if isinstance(node, ast.IfExp):
        return _str_tuples(node.body) + _str_tuples(node.orelse)
    return []


class ShardSpecPass(Pass):
    name = "shardspec"
    rules = {
        "S001": "PartitionSpec axis name not declared by any mesh in the "
                "scanned tree",
        "S002": "axis repeated within one spec / joint-axes tuple",
        "S003": "scan axis (layers/groups) mapped to a non-empty sharding "
                "tuple",
    }

    def applies_to(self, path: Path) -> bool:
        parts = path.parts
        if "analysis_fixtures" in parts:
            return "shardspec" in parts or "sharding" in parts
        return (
            len(parts) >= 3
            and parts[:2] == ("src", "repro")
            and parts[2] in ("dist", "launch")
        )

    # -- declarations --------------------------------------------------------

    def _declared_axes(self, files: Sequence[SourceFile]) -> Set[str]:
        declared: Set[str] = set()
        for f in files:
            # module-wide name -> candidate axis tuples, for the
            # ``axes = (...) if flag else (...); jax.make_mesh(shape, axes)``
            # idiom
            name_tuples: Dict[str, List[Tuple[str, ...]]] = {}
            for node in ast.walk(f.tree):
                if isinstance(node, ast.Assign) and len(node.targets) == 1:
                    tgt = node.targets[0]
                    if isinstance(tgt, ast.Name):
                        tups = _str_tuples(node.value)
                        if tups:
                            name_tuples[tgt.id] = tups
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Call):
                    continue
                if _tail(node.func) in _MESH_BUILDERS:
                    for arg in node.args:
                        for t in _str_tuples(arg):
                            declared.update(t)
                        if isinstance(arg, ast.Name):
                            for t in name_tuples.get(arg.id, []):
                                declared.update(t)
                for kw in node.keywords:
                    if kw.arg == "axis_names":
                        for t in _str_tuples(kw.value):
                            declared.update(t)
                        if isinstance(kw.value, ast.Name):
                            for t in name_tuples.get(kw.value.id, []):
                                declared.update(t)
        return declared

    # -- uses ----------------------------------------------------------------

    def run(self, files: Sequence[SourceFile], root: Path) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        declared = self._declared_axes(files)
        for f in files:
            diags.extend(self._check_file(f, declared))
        return diags

    def _check_file(self, f: SourceFile, declared: Set[str]) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Call):
                tail = _tail(node.func)
                if tail in _SPEC_NAMES:
                    diags.extend(self._check_spec_call(f, node, declared))
                elif tail == "_rule" or (
                    tail is not None and "rule" in tail.lower() and node.keywords
                ):
                    diags.extend(self._check_rule_kwargs(f, node, declared))
            elif isinstance(node, (ast.Assign, ast.AnnAssign)):
                value = node.value
                if value is not None and isinstance(value, ast.Dict):
                    if self._is_rule_table(node, value):
                        diags.extend(
                            self._check_rule_dict(f, value, declared)
                        )
        return diags

    def _is_rule_table(self, assign, d: ast.Dict) -> bool:
        """A rule table: string keys, every value a (possibly empty) tuple
        of strings — plus either a ``Rule`` annotation or a *RULES*/rule
        target name."""
        if not d.keys or not all(
            isinstance(k, ast.Constant) and isinstance(k.value, str)
            for k in d.keys
            if k is not None
        ):
            return False
        values_ok = all(
            isinstance(v, ast.Tuple)
            and all(
                isinstance(e, ast.Constant) and isinstance(e.value, str)
                for e in v.elts
            )
            for v in d.values
        )
        if not values_ok:
            return False
        if isinstance(assign, ast.AnnAssign):
            ann = assign.annotation
            if _tail(ann) == "Rule":
                return True
            tgt = assign.target
            return isinstance(tgt, ast.Name) and "rule" in tgt.id.lower()
        for tgt in assign.targets:
            if isinstance(tgt, ast.Name) and "rule" in tgt.id.lower():
                return True
        return False

    def _check_axes(
        self,
        f: SourceFile,
        node: ast.expr,
        axes: Sequence[str],
        declared: Set[str],
        context: str,
    ) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        seen: Set[str] = set()
        for a in axes:
            if a in seen:
                diags.append(
                    self.diag(
                        f, node, "S002",
                        f"axis '{a}' repeated in {context}",
                        "a mesh axis may partition a tensor at most once",
                    )
                )
            seen.add(a)
            if declared and a not in declared:
                diags.append(
                    self.diag(
                        f, node, "S001",
                        f"axis '{a}' in {context} is not declared by any "
                        f"mesh ({', '.join(sorted(declared))})",
                        "declare it in the mesh builder or fix the name",
                    )
                )
        return diags

    def _check_spec_call(
        self, f: SourceFile, call: ast.Call, declared: Set[str]
    ) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        flat: List[str] = []
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                flat.append(arg.value)
            else:
                for t in _str_tuples(arg):
                    # duplicate inside a joint tuple checked per-tuple too
                    diags.extend(
                        self._check_axes(
                            f, arg, t, declared, "a joint-axes tuple"
                        )
                    )
                    flat.extend(t)
        # cross-slot duplicates (e.g. P("data", ("data", "model")))
        seen: Set[str] = set()
        for a in flat:
            if a in seen:
                diags.append(
                    self.diag(
                        f, call, "S002",
                        f"axis '{a}' used twice within one PartitionSpec",
                        "a mesh axis may partition a tensor at most once",
                    )
                )
            seen.add(a)
            if declared and a not in declared:
                diags.append(
                    self.diag(
                        f, call, "S001",
                        f"PartitionSpec names axis '{a}' but the mesh "
                        f"declares ({', '.join(sorted(declared))})",
                        "declare it in the mesh builder or fix the name",
                    )
                )
        # dedupe: joint-tuple loop may double-report the same S001
        uniq = []
        keys = set()
        for d in diags:
            k = (d.line, d.col, d.rule, d.message)
            if k not in keys:
                keys.add(k)
                uniq.append(d)
        return uniq

    def _check_rule_kwargs(
        self, f: SourceFile, call: ast.Call, declared: Set[str]
    ) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for kw in call.keywords:
            if kw.arg is None:
                continue
            for t in _str_tuples(kw.value):
                if kw.arg in _SCAN_AXES and t:
                    diags.append(
                        self.diag(
                            f, kw.value, "S003",
                            f"scan axis '{kw.arg}' mapped to {t!r}",
                            "lax.scan stacking dims are never sharded — map "
                            "to ()",
                        )
                    )
                diags.extend(
                    self._check_axes(
                        f, kw.value, t, declared, f"rule entry '{kw.arg}'"
                    )
                )
        return diags

    def _check_rule_dict(
        self, f: SourceFile, d: ast.Dict, declared: Set[str]
    ) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for k, v in zip(d.keys, d.values):
            if k is None:
                continue
            key = k.value  # string-keyed by _is_rule_table
            for t in _str_tuples(v):
                if key in _SCAN_AXES and t:
                    diags.append(
                        self.diag(
                            f, v, "S003",
                            f"scan axis '{key}' mapped to {t!r}",
                            "lax.scan stacking dims are never sharded — map "
                            "to ()",
                        )
                    )
                diags.extend(
                    self._check_axes(
                        f, v, t, declared, f"rule entry '{key}'"
                    )
                )
        return diags
