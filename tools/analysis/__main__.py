"""CLI entry point: ``python -m tools.analysis src/ benchmarks/ launch/``."""
from __future__ import annotations

import argparse
from pathlib import Path

from tools.analysis.core import REPO, all_passes, render, run_analysis


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.analysis",
        description="repro-lint: invariant-enforcing static analysis "
        "(units, conservation, determinism, Pallas, sharding, perf).",
    )
    parser.add_argument(
        "paths",
        nargs="*",
        type=Path,
        help="files or directories to scan (default: src/ benchmarks/)",
    )
    parser.add_argument(
        "--format",
        choices=("text", "json"),
        default="text",
        help="diagnostic output format",
    )
    parser.add_argument(
        "--pass",
        dest="passes",
        action="append",
        choices=[p.name for p in all_passes()],
        help="run only the named pass (repeatable)",
    )
    parser.add_argument(
        "--root",
        type=Path,
        default=REPO,
        help="repo root for repo-level checks and relative paths",
    )
    args = parser.parse_args(argv)

    root = args.root.resolve()
    paths = [p if p.is_absolute() else root / p for p in args.paths] or None
    diags = run_analysis(paths=paths, root=root, only_passes=args.passes)
    out = render(diags, root, fmt=args.format)
    print(out)
    return 1 if diags else 0


if __name__ == "__main__":
    raise SystemExit(main())
