"""repro-lint shared core: diagnostics, suppression, source loading, runner.

Every pass is a small ``ast`` visitor that returns :class:`Diagnostic`
objects through one reporting pipeline:

* ``file:line:col RULE message (hint)`` text output, or ``--format=json``;
* per-line suppression — append ``# repro-lint: disable=U002`` (comma-
  separate several rule ids) to the offending line; the comment should
  also say WHY (which invariant makes the violation intentional);
* file-level suppression — ``# repro-lint: disable-file=D001`` anywhere
  in the first 20 lines.

Passes implement the :class:`Pass` protocol: a ``name``, a ``rules``
catalogue (id -> one-line meaning, mirrored in docs/invariants.md), an
``applies_to(path)`` scope predicate over repo-relative paths, and
``run(files, root)``. ``root`` matters for the repo-level passes
(conservation, pallas P004): tests point it at mini-tree fixtures.
"""
from __future__ import annotations

import ast
import dataclasses
import json
from pathlib import Path
import re
from typing import Dict, Iterable, List, Optional, Sequence

REPO = Path(__file__).resolve().parents[2]

_SUPPRESS_LINE_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Z]\d{3}(?:\s*,\s*[A-Z]\d{3})*)"
)
_SUPPRESS_FILE_RE = re.compile(
    r"#\s*repro-lint:\s*disable-file=([A-Z]\d{3}(?:\s*,\s*[A-Z]\d{3})*)"
)
_FILE_SUPPRESS_SCAN_LINES = 20


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    path: Path
    line: int
    col: int
    rule: str
    message: str
    hint: str = ""

    def as_dict(self, root: Path) -> Dict[str, object]:
        try:
            rel = str(self.path.relative_to(root))
        except ValueError:
            rel = str(self.path)
        return {
            "path": rel,
            "line": self.line,
            "col": self.col,
            "rule": self.rule,
            "message": self.message,
            "hint": self.hint,
        }

    def format_text(self, root: Path) -> str:
        d = self.as_dict(root)
        hint = f"  [{self.hint}]" if self.hint else ""
        return f"{d['path']}:{d['line']}:{d['col']} {self.rule} {self.message}{hint}"


class SourceFile:
    """One parsed source file plus its suppression comments."""

    def __init__(self, path: Path, text: str):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=str(path))
        self._file_suppressed: set = set()
        for raw in self.lines[:_FILE_SUPPRESS_SCAN_LINES]:
            m = _SUPPRESS_FILE_RE.search(raw)
            if m:
                self._file_suppressed.update(
                    r.strip() for r in m.group(1).split(",")
                )

    @classmethod
    def load(cls, path: Path) -> "SourceFile":
        return cls(path, path.read_text(encoding="utf-8"))

    def suppressed(self, line: int, rule: str) -> bool:
        if rule in self._file_suppressed:
            return True
        if 1 <= line <= len(self.lines):
            m = _SUPPRESS_LINE_RE.search(self.lines[line - 1])
            if m and rule in {r.strip() for r in m.group(1).split(",")}:
                return True
        return False


class Pass:
    """Base class for the repro-lint passes."""

    name: str = ""
    rules: Dict[str, str] = {}

    def applies_to(self, path: Path) -> bool:  # repo-relative path
        raise NotImplementedError

    def run(self, files: Sequence[SourceFile], root: Path) -> List[Diagnostic]:
        raise NotImplementedError

    def diag(
        self, file: SourceFile, node, rule: str, message: str, hint: str = ""
    ) -> Diagnostic:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        return Diagnostic(file.path, line, col, rule, message, hint)


def rel_path(path: Path, root: Path) -> Path:
    try:
        return path.resolve().relative_to(root.resolve())
    except ValueError:
        return path


def collect_py_files(paths: Iterable[Path]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            out.append(p)
        elif p.is_dir():
            out.extend(
                f for f in sorted(p.rglob("*.py")) if "__pycache__" not in f.parts
            )
    return out


def all_passes() -> List[Pass]:
    from tools.analysis.conservation import ConservationPass
    from tools.analysis.determinism import DeterminismPass
    from tools.analysis.obs import ObsPass
    from tools.analysis.pallas import PallasPass
    from tools.analysis.perf import PerfPass
    from tools.analysis.shardspec import ShardSpecPass
    from tools.analysis.units import UnitsPass

    return [
        UnitsPass(),
        ConservationPass(),
        DeterminismPass(),
        PallasPass(),
        ShardSpecPass(),
        PerfPass(),
        ObsPass(),
    ]


def run_analysis(
    paths: Optional[Sequence[Path]] = None,
    root: Path = REPO,
    only_passes: Optional[Sequence[str]] = None,
) -> List[Diagnostic]:
    """Run every (selected) pass over ``paths`` (default: src/ and
    benchmarks/ under ``root``); returns unsuppressed diagnostics."""
    if paths is None:
        paths = [root / "src", root / "benchmarks"]
    files: List[SourceFile] = []
    for fp in collect_py_files(paths):
        files.append(SourceFile.load(fp))

    diags: List[Diagnostic] = []
    by_path = {f.path.resolve(): f for f in files}
    for p in all_passes():
        if only_passes and p.name not in only_passes:
            continue
        scoped = [f for f in files if p.applies_to(rel_path(f.path, root))]
        for d in p.run(scoped, root):
            src = by_path.get(d.path.resolve())
            if src is not None and src.suppressed(d.line, d.rule):
                continue
            diags.append(d)
    diags.sort(key=lambda d: (str(d.path), d.line, d.col, d.rule))
    return diags


def render(diags: Sequence[Diagnostic], root: Path, fmt: str = "text") -> str:
    if fmt == "json":
        payload = {
            "tool": "repro-lint",
            "problems": len(diags),
            "diagnostics": [d.as_dict(root) for d in diags],
        }
        return json.dumps(payload, indent=2, sort_keys=True)
    lines = [d.format_text(root) for d in diags]
    lines.append(f"repro-lint: {len(diags)} problem(s)")
    return "\n".join(lines)
