"""Pass 7 — observability lint (O001–O002).

The event telemetry layer (``src/repro/obs``) only stays replayable if
the instrumented subsystems keep two disciplines. Under
``src/repro/{core,serve,dist}``:

* **O001** — ad-hoc dict events: ``emit({...})`` / ``emit(dict(...))``.
  Every emitted event must be a registry-typed dataclass from
  ``repro.obs.events`` (a ``CamelCase`` constructor or one of the
  module's snake_case factory helpers) — an untyped dict bypasses the
  frozen schema, breaks the JSONL round trip, and is invisible to the
  replay oracle.
* **O002** — bare ``print(`` in the instrumented core: stdout belongs to
  machine contracts (CSV rows, ``PLAN_JSON``/``SPLIT_JSON`` lines) and
  human status belongs to the structured stderr logger
  (``repro.obs.log.get_logger``). A stray print in core/serve/dist is
  either debugging residue or an event that should be in the timeline.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Sequence

from tools.analysis.core import Diagnostic, Pass, SourceFile


def _is_dictish(node: ast.expr) -> bool:
    """A dict literal, a ``dict(...)`` call, or a dict comprehension."""
    if isinstance(node, (ast.Dict, ast.DictComp)):
        return True
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id == "dict"
    )


class ObsPass(Pass):
    name = "obs"
    rules = {
        "O001": "ad-hoc dict event passed to emit() — events must be "
                "registry-typed dataclasses from repro.obs.events",
        "O002": "bare print() in instrumented core — use the structured "
                "stderr logger (repro.obs.log) or a typed event",
    }

    def applies_to(self, path: Path) -> bool:
        parts = path.parts
        if "analysis_fixtures" in parts:
            return "obs" in parts
        return (
            len(parts) >= 3
            and parts[:2] == ("src", "repro")
            and parts[2] in ("core", "serve", "dist")
        )

    def run(self, files: Sequence[SourceFile], root: Path) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for f in files:
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Call):
                    continue
                func = node.func
                if (
                    isinstance(func, ast.Attribute)
                    and func.attr == "emit"
                    and node.args
                    and _is_dictish(node.args[0])
                ):
                    diags.append(
                        self.diag(
                            f, node, "O001",
                            "ad-hoc dict event passed to emit()",
                            "construct a typed event from repro.obs.events "
                            "so the frozen schema and the replay oracle "
                            "see it",
                        )
                    )
                elif isinstance(func, ast.Name) and func.id == "print":
                    diags.append(
                        self.diag(
                            f, node, "O002",
                            "bare print() in instrumented core",
                            "route human status through "
                            "repro.obs.log.get_logger(...) (stderr); "
                            "stdout is machine-owned",
                        )
                    )
        return diags
