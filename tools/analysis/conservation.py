"""Pass 2 — conservation / exhaustiveness checker (C001–C004).

The ``Breakdown`` TIME and COST component names are the ledger's schema:
every simulated hour and dollar lands under exactly one of them, and the
totals (``total_time``/``total_cost``) sum the whole registry — that is
the conservation law the bit-exact bench pins rely on. This pass keeps
the registry authoritative everywhere it is mirrored:

* **C001** — a component name used in code (``bd.time["x"]``,
  ``bd.cost["x"]``, ``session.add("x", h)``) that is not in the declared
  ``TIME_COMPONENTS``/``COST_COMPONENTS`` registry. A typo here silently
  grows the dict and breaks ``Breakdown.add`` merging.
* **C002** — a registry component undocumented in ``docs/accounting.md``.
* **C003** — a registry component absent from ``tools/check_bench.py``:
  the bench schema gate must know every component the code can emit.
* **C004** — ``total_time``/``total_cost`` enumerate explicit component
  keys but miss part of the registry (non-exhaustive total: conservation
  silently broken). Summing the whole dict is always exhaustive.

Repo-level pass: the registry is parsed from the scanned file that
declares ``TIME_COMPONENTS`` (``src/repro/core/accounting.py`` in this
tree); doc/bench mirrors are read from ``root``.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

from tools.analysis.core import Diagnostic, Pass, SourceFile


def _literal_str_tuple(node: ast.expr) -> Optional[Tuple[str, ...]]:
    if isinstance(node, ast.Tuple) and all(
        isinstance(e, ast.Constant) and isinstance(e.value, str) for e in node.elts
    ):
        return tuple(e.value for e in node.elts)
    return None


def _component_literals(node: ast.expr) -> List[ast.Constant]:
    """String constants used as a component key (handles the
    ``"a" if cond else "b"`` idiom)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node]
    if isinstance(node, ast.IfExp):
        return _component_literals(node.body) + _component_literals(node.orelse)
    return []


class ConservationPass(Pass):
    name = "conservation"
    rules = {
        "C001": "component name not in the declared "
                "TIME_COMPONENTS/COST_COMPONENTS registry",
        "C002": "registry component missing from docs/accounting.md",
        "C003": "registry component missing from tools/check_bench.py "
                "schema gate",
        "C004": "total_time/total_cost enumerate components "
                "non-exhaustively",
    }

    def applies_to(self, path: Path) -> bool:
        parts = path.parts
        if "analysis_fixtures" in parts:
            return "conservation" in parts or any(
                p.startswith("conservation") for p in parts
            )
        if len(parts) >= 3 and parts[:2] == ("src", "repro"):
            return parts[2] in ("core", "serve", "dist")
        return len(parts) >= 1 and parts[0] == "benchmarks"

    # -- registry -----------------------------------------------------------

    def _find_registry(
        self, files: Sequence[SourceFile]
    ) -> Tuple[Optional[SourceFile], Optional[ast.Assign], Tuple[str, ...], Tuple[str, ...]]:
        for f in files:
            time_comps: Optional[Tuple[str, ...]] = None
            cost_extra: Tuple[str, ...] = ()
            anchor: Optional[ast.Assign] = None
            for node in f.tree.body:
                if not isinstance(node, ast.Assign) or len(node.targets) != 1:
                    continue
                tgt = node.targets[0]
                if not isinstance(tgt, ast.Name):
                    continue
                if tgt.id == "TIME_COMPONENTS":
                    time_comps = _literal_str_tuple(node.value)
                    anchor = node
                elif tgt.id == "COST_COMPONENTS":
                    v = node.value
                    if (
                        isinstance(v, ast.BinOp)
                        and isinstance(v.op, ast.Add)
                        and isinstance(v.left, ast.Name)
                        and v.left.id == "TIME_COMPONENTS"
                    ):
                        cost_extra = _literal_str_tuple(v.right) or ()
                    else:
                        cost_extra = _literal_str_tuple(v) or ()
            if time_comps is not None:
                return f, anchor, time_comps, time_comps + cost_extra
        return None, None, (), ()

    # -- run ----------------------------------------------------------------

    def run(self, files: Sequence[SourceFile], root: Path) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        reg_file, anchor, time_comps, cost_comps = self._find_registry(files)
        if reg_file is None:
            return diags  # nothing to enforce against
        known = set(time_comps) | set(cost_comps)

        for f in files:
            diags.extend(self._check_usage(f, known))

        diags.extend(self._check_totals(reg_file, time_comps, cost_comps))

        docs = root / "docs" / "accounting.md"
        if docs.is_file():
            text = docs.read_text(encoding="utf-8")
            for comp in cost_comps:
                if comp not in text:
                    diags.append(
                        self.diag(
                            reg_file,
                            anchor,
                            "C002",
                            f"component '{comp}' is not documented in "
                            f"docs/accounting.md",
                            "every ledger component needs its formula in the "
                            "accounting doc",
                        )
                    )

        bench_gate = root / "tools" / "check_bench.py"
        if bench_gate.is_file():
            text = bench_gate.read_text(encoding="utf-8")
            for comp in cost_comps:
                if comp not in text:
                    diags.append(
                        self.diag(
                            reg_file,
                            anchor,
                            "C003",
                            f"component '{comp}' is unknown to "
                            f"tools/check_bench.py",
                            "mirror the registry in check_bench.py so bench "
                            "JSON breakdowns are schema-checked",
                        )
                    )
        return diags

    def _check_usage(self, f: SourceFile, known: set) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Subscript):
                v = node.value
                if (
                    isinstance(v, ast.Attribute)
                    and v.attr in ("time", "cost")
                    and isinstance(node.slice, ast.Constant)
                    and isinstance(node.slice.value, str)
                ):
                    comp = node.slice.value
                    if comp not in known:
                        diags.append(
                            self.diag(
                                f,
                                node,
                                "C001",
                                f"unknown breakdown component '{comp}'",
                                "declare it in TIME_COMPONENTS/COST_COMPONENTS "
                                "(and document it) before use",
                            )
                        )
            elif isinstance(node, ast.Call):
                func = node.func
                if (
                    isinstance(func, ast.Attribute)
                    and func.attr == "add"
                    and len(node.args) >= 2
                ):
                    for lit in _component_literals(node.args[0]):
                        if lit.value not in known:
                            diags.append(
                                self.diag(
                                    f,
                                    lit,
                                    "C001",
                                    f"unknown breakdown component "
                                    f"'{lit.value}' in .add() call",
                                    "declare it in TIME_COMPONENTS/"
                                    "COST_COMPONENTS before use",
                                )
                            )
        return diags

    def _check_totals(
        self,
        reg_file: SourceFile,
        time_comps: Tuple[str, ...],
        cost_comps: Tuple[str, ...],
    ) -> List[Diagnostic]:
        """Flag total_time/total_cost that enumerate literal keys but miss
        registry components (sum(dict.values()) never fires)."""
        diags: List[Diagnostic] = []
        targets = {"total_time": set(time_comps), "total_cost": set(cost_comps)}
        for node in ast.walk(reg_file.tree):
            if isinstance(node, ast.FunctionDef) and node.name in targets:
                literals = {
                    n.value
                    for n in ast.walk(node)
                    if isinstance(n, ast.Constant) and isinstance(n.value, str)
                }
                if literals:
                    missing = targets[node.name] - literals
                    if missing:
                        diags.append(
                            self.diag(
                                reg_file,
                                node,
                                "C004",
                                f"{node.name} enumerates components but "
                                f"misses {sorted(missing)}",
                                "sum the whole component dict, or list every "
                                "registry entry",
                            )
                        )
        return diags
