"""Pass 3 — determinism lint (D001–D003).

The simulator's replayable traces, the token-identical serve round trip
and every bit-exact bench pin assume the core never reads ambient
entropy. Under ``src/repro/{core,serve,dist}``:

* **D001** — wall-clock reads: ``time.time``/``time_ns``/``monotonic``/
  ``perf_counter``, ``datetime.now``/``utcnow``/``today``. (The
  orchestrator's real-segment timing for the ThroughputTracker is the one
  sanctioned use — suppressed inline with the invariant named.)
* **D002** — implicit-state RNGs: the stdlib ``random`` module (global
  Mersenne state) and numpy's legacy global RNG (``np.random.rand``,
  ``np.random.seed``, ...).
* **D003** — ``np.random.default_rng(...)`` whose seed does not flow from
  an explicit ``seed``/``SeedSequence``/``entropy`` value: no-arg
  construction draws OS entropy; a bare numeric literal hides the seed
  from the policy/config layer that must own it.

``jax.random`` is exempt by design — JAX PRNG keys are explicit values,
so determinism is visible in the dataflow.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Sequence

from tools.analysis.core import Diagnostic, Pass, SourceFile

_CLOCK_ATTRS = {"time", "time_ns", "monotonic", "perf_counter"}
_DATETIME_ATTRS = {"now", "utcnow", "today"}
_NUMPY_LEGACY_RNG = {
    "rand", "randn", "randint", "random", "random_sample", "seed",
    "choice", "shuffle", "permutation", "uniform", "normal", "standard_normal",
}
_SEEDY_MARKERS = ("seed", "entropy")


def _attr_chain(node: ast.expr) -> List[str]:
    """``np.random.default_rng`` -> ["np", "random", "default_rng"]."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    else:
        parts.append("?")
    return list(reversed(parts))


def _seed_flows(node: ast.expr) -> bool:
    """True when the expression references an explicit seed: a name or
    attribute containing 'seed'/'entropy', or a SeedSequence construction."""
    for sub in ast.walk(node):
        ident: Optional[str] = None
        if isinstance(sub, ast.Name):
            ident = sub.id
        elif isinstance(sub, ast.Attribute):
            ident = sub.attr
        elif isinstance(sub, ast.Call):
            chain = _attr_chain(sub.func)
            if chain and chain[-1] == "SeedSequence":
                return True
        elif isinstance(sub, ast.keyword) and sub.arg:
            ident = sub.arg
        if ident and any(m in ident.lower() for m in _SEEDY_MARKERS):
            return True
    return False


class DeterminismPass(Pass):
    name = "determinism"
    rules = {
        "D001": "wall-clock read in deterministic core "
                "(time.time/datetime.now/...)",
        "D002": "implicit-state RNG (stdlib random / numpy legacy global "
                "RNG)",
        "D003": "np.random.default_rng without an explicit seed/"
                "SeedSequence argument",
    }

    def applies_to(self, path: Path) -> bool:
        parts = path.parts
        if "analysis_fixtures" in parts:
            return "determinism" in parts
        return (
            len(parts) >= 3
            and parts[:2] == ("src", "repro")
            and parts[2] in ("core", "serve", "dist")
        )

    def run(self, files: Sequence[SourceFile], root: Path) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for f in files:
            stdlib_random_names = self._stdlib_random_imports(f)
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Call):
                    continue
                chain = _attr_chain(node.func)
                diags.extend(
                    self._check_call(f, node, chain, stdlib_random_names)
                )
        return diags

    def _stdlib_random_imports(self, f: SourceFile) -> set:
        """Local names bound to the stdlib random module or its members."""
        names: set = set()
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "random":
                        names.add(alias.asname or "random")
            elif isinstance(node, ast.ImportFrom):
                if node.module == "random":
                    for alias in node.names:
                        names.add(alias.asname or alias.name)
        return names

    def _check_call(
        self,
        f: SourceFile,
        node: ast.Call,
        chain: List[str],
        stdlib_random_names: set,
    ) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        head, tail = chain[0], chain[-1]

        # D001 — wall clocks
        if len(chain) >= 2 and chain[-2] == "time" and tail in _CLOCK_ATTRS:
            diags.append(
                self.diag(
                    f, node, "D001",
                    f"wall-clock read '{'.'.join(chain)}' in deterministic "
                    f"core",
                    "thread simulated wall hours through instead; if this "
                    "measures real execution, suppress with the invariant "
                    "named",
                )
            )
        elif tail in _DATETIME_ATTRS and "datetime" in chain[:-1]:
            diags.append(
                self.diag(
                    f, node, "D001",
                    f"wall-clock read '{'.'.join(chain)}'",
                    "deterministic code cannot read the calendar",
                )
            )

        # D002 — implicit-state RNGs
        elif head in stdlib_random_names and (
            len(chain) > 1 or tail in stdlib_random_names
        ):
            diags.append(
                self.diag(
                    f, node, "D002",
                    f"stdlib random call '{'.'.join(chain)}' uses hidden "
                    f"global state",
                    "use np.random.default_rng(seed) threaded from the "
                    "policy seed",
                )
            )
        elif (
            len(chain) >= 3
            and chain[-2] == "random"
            and tail in _NUMPY_LEGACY_RNG
        ):
            diags.append(
                self.diag(
                    f, node, "D002",
                    f"numpy legacy global RNG '{'.'.join(chain)}'",
                    "construct a Generator: np.random.default_rng(seed)",
                )
            )

        # D003 — unseeded / literal-seeded Generator construction
        elif tail == "default_rng":
            if not node.args and not node.keywords:
                diags.append(
                    self.diag(
                        f, node, "D003",
                        "default_rng() draws OS entropy — unseeded",
                        "pass the policy/config seed (or a SeedSequence "
                        "derived from it)",
                    )
                )
            else:
                flows = any(_seed_flows(a) for a in node.args) or any(
                    _seed_flows(kw.value) for kw in node.keywords
                )
                if not flows:
                    diags.append(
                        self.diag(
                            f, node, "D003",
                            "default_rng seed does not flow from an explicit "
                            "seed/SeedSequence argument",
                            "derive the argument from a value named seed*/"
                            "entropy so ownership is visible",
                        )
                    )
        return diags
