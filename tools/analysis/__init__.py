"""repro-lint: invariant-enforcing static analysis for the repro tree.

Run as ``python -m tools.analysis [paths...] [--format=json|text]``.
See docs/invariants.md for the rule catalogue and suppression syntax.
"""
from tools.analysis.core import (
    REPO,
    Diagnostic,
    Pass,
    SourceFile,
    all_passes,
    render,
    run_analysis,
)

__all__ = [
    "REPO",
    "Diagnostic",
    "Pass",
    "SourceFile",
    "all_passes",
    "render",
    "run_analysis",
]
