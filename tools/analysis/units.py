"""Pass 1 — units-of-measure checker (U001–U003).

A naming-convention dimension system: identifier suffixes declare the
unit a value is measured in (``*_hours`` vs ``*_seconds``, ``*_bytes`` vs
``*_gb`` vs ``*_gbps``, ``*_usd``/``*_price``, ``*_tokens``), and
``a_per_b`` names declare rates. The checker flags:

* **U001** — ``+``/``-``/comparison between two values whose inferred
  dimensions are BOTH known and differ (``wall_hours > mttr_seconds`` is
  exactly the bug class that silently rescales every BENCH number).
  Multiplication/division legitimately change dimension and are not
  flagged.
* **U002** — a bare unit-conversion literal (60, 3600, 86400, 1e6, 1e9,
  1024, 2**30, 1024**3) used in ``*``/``/`` arithmetic. Conversions must
  go through the named constants in ``repro.core.units`` so there is one
  greppable home for every factor.
* **U003** — an accounting call site (``bill_session``, ``settle_leg``,
  ``leg_state_bytes``, ``Session.add``/``Breakdown.add``) whose argument
  embeds conversion-literal arithmetic inline: the ledger's entry points
  must receive values already in canonical units.

Scope: ``src/repro/{core,serve,dist}`` and ``benchmarks/`` —
``repro/core/units.py`` itself is exempt (it is where the literals live).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from tools.analysis.core import Diagnostic, Pass, SourceFile

CONVERSION_LITERALS = {
    60.0,
    3600.0,
    86400.0,
    1e6,
    1e9,
    1024.0,
    float(2**20),
    float(2**30),
}

# suffix token -> canonical dimension
_SUFFIX_DIMS: Dict[str, str] = {
    "hours": "hours",
    "hrs": "hours",
    "seconds": "seconds",
    "secs": "seconds",
    "bytes": "bytes",
    "gb": "gb",
    "gib": "gib",
    "gbps": "gbps",
    "usd": "usd",
    "dollars": "usd",
    "price": "usd",
    "tokens": "tokens",
}

# denominator tokens accepted inside ``a_per_b`` rate names
_PER_DENOMS: Dict[str, str] = {
    "s": "seconds",
    "sec": "seconds",
    "secs": "seconds",
    "second": "seconds",
    "seconds": "seconds",
    "h": "hours",
    "hour": "hours",
    "hours": "hours",
}


def dim_of_identifier(name: str) -> Optional[str]:
    """Infer a dimension from an identifier, or None when unsuffixed."""
    tokens = name.lower().split("_")
    if "per" in tokens:
        i = tokens.index("per")
        num = tokens[i - 1] if i > 0 else ""
        den = tokens[i + 1] if i + 1 < len(tokens) else ""
        num_dim = _SUFFIX_DIMS.get(num)
        den_dim = _PER_DENOMS.get(den) or _SUFFIX_DIMS.get(den)
        if num_dim and den_dim:
            return f"{num_dim}/{den_dim}"
        return None
    return _SUFFIX_DIMS.get(tokens[-1])


def _expr_dim(node: ast.expr) -> Optional[str]:
    """Conservative dimension inference: only plain names, attributes and
    calls-of-suffixed-functions carry a dimension; anything composite is
    unknown (and unknown never fires U001)."""
    if isinstance(node, ast.Name):
        return dim_of_identifier(node.id)
    if isinstance(node, ast.Attribute):
        return dim_of_identifier(node.attr)
    if isinstance(node, ast.Call):
        return _expr_dim(node.func)
    return None


def _is_conversion_literal(node: ast.expr) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, (int, float)):
        if isinstance(node.value, bool):
            return False
        return float(node.value) in CONVERSION_LITERALS
    # 2**30-style: a power of small literal ints that lands on a factor
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Pow):
        left, right = node.left, node.right
        if (
            isinstance(left, ast.Constant)
            and isinstance(right, ast.Constant)
            and isinstance(left.value, int)
            and isinstance(right.value, int)
        ):
            try:
                return float(left.value**right.value) in CONVERSION_LITERALS
            except OverflowError:
                return False
    return False


_ACCOUNTING_FUNCS = {"bill_session", "settle_leg", "leg_state_bytes"}


def _call_name(node: ast.Call) -> Optional[str]:
    if isinstance(node.func, ast.Name):
        return node.func.id
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


class UnitsPass(Pass):
    name = "units"
    rules = {
        "U001": "arithmetic or comparison mixes incompatible unit dimensions",
        "U002": "bare unit-conversion literal in arithmetic "
                "(use repro.core.units constants)",
        "U003": "conversion-literal arithmetic inline at an accounting "
                "call site",
    }

    def applies_to(self, path: Path) -> bool:
        parts = path.parts
        if path.name == "units.py":
            return False
        if "analysis_fixtures" in parts:
            return "units" in parts
        if len(parts) >= 3 and parts[:2] == ("src", "repro"):
            return parts[2] in ("core", "serve", "dist")
        return len(parts) >= 1 and parts[0] == "benchmarks"

    def run(self, files: Sequence[SourceFile], root: Path) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        for f in files:
            diags.extend(self._check_file(f))
        return diags

    def _check_file(self, f: SourceFile) -> List[Diagnostic]:
        diags: List[Diagnostic] = []
        # nodes already reported through U003 don't re-fire as bare U002
        claimed: set = set()

        for node in ast.walk(f.tree):
            if isinstance(node, ast.Call):
                fname = _call_name(node)
                is_session_add = (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr == "add"
                    and len(node.args) >= 2
                )
                if fname in _ACCOUNTING_FUNCS or is_session_add:
                    for arg in list(node.args) + [kw.value for kw in node.keywords]:
                        for sub in ast.walk(arg):
                            if isinstance(sub, ast.BinOp) and isinstance(
                                sub.op, (ast.Mult, ast.Div)
                            ):
                                if _is_conversion_literal(
                                    sub.left
                                ) or _is_conversion_literal(sub.right):
                                    claimed.add(id(sub))
                                    diags.append(
                                        self.diag(
                                            f,
                                            sub,
                                            "U003",
                                            f"unit conversion inline in argument "
                                            f"to accounting entry point "
                                            f"'{fname}'",
                                            "convert via repro.core.units before "
                                            "the call so the ledger receives "
                                            "canonical units",
                                        )
                                    )

        for node in ast.walk(f.tree):
            if isinstance(node, ast.BinOp):
                if isinstance(node.op, (ast.Add, ast.Sub)):
                    ld, rd = _expr_dim(node.left), _expr_dim(node.right)
                    if ld and rd and ld != rd:
                        diags.append(
                            self.diag(
                                f,
                                node,
                                "U001",
                                f"mixes '{ld}' with '{rd}' in +/- arithmetic",
                                "convert one side explicitly (see "
                                "repro.core.units) or rename to the true unit",
                            )
                        )
                elif isinstance(node.op, (ast.Mult, ast.Div)):
                    if id(node) not in claimed and (
                        _is_conversion_literal(node.left)
                        or _is_conversion_literal(node.right)
                    ):
                        diags.append(
                            self.diag(
                                f,
                                node,
                                "U002",
                                "bare unit-conversion literal in arithmetic",
                                "name the factor via repro.core.units "
                                "(SECONDS_PER_HOUR, BYTES_PER_GB, ...)",
                            )
                        )
            elif isinstance(node, ast.Compare) and len(node.ops) == 1:
                ld = _expr_dim(node.left)
                rd = _expr_dim(node.comparators[0])
                if ld and rd and ld != rd:
                    diags.append(
                        self.diag(
                            f,
                            node,
                            "U001",
                            f"compares '{ld}' against '{rd}'",
                            "convert one side explicitly before comparing",
                        )
                    )
        return diags
