"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement),
plus prefill/decode consistency against the full-sequence forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import InputShape, TrainConfig, ShardingLayout, get_arch, list_archs
from repro.models import build_model, concrete_inputs
from repro.train.steps import build_train_step, init_train_state

ARCHS = list_archs()
SHAPE = InputShape("tiny", seq_len=32, global_batch=2, mode="train")


@pytest.fixture(scope="module")
def built(request):
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_arch(arch).reduced()
            model = build_model(cfg)
            params = model.init(jax.random.key(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


def test_all_archs_registered():
    assert len(ARCHS) == 10, ARCHS


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch, built):
    cfg, model, params = built(arch)
    batch = concrete_inputs(cfg, SHAPE, jax.random.key(1))
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs_and_is_finite(arch, built):
    cfg, model, params = built(arch)
    tc = TrainConfig(total_steps=10, warmup_steps=0)  # warmup 0: step-0 lr > 0
    step = build_train_step(model, tc, ShardingLayout(sequence_shard_activations=False))
    state = init_train_state(model, jax.random.key(0))
    batch = concrete_inputs(cfg, SHAPE, jax.random.key(1))
    batch["labels"] = batch["tokens"]
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state.step) == 1
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda acc, pq: acc
        or bool(jnp.any(pq[0] != pq[1])),
        jax.tree_util.tree_map(lambda a, b: (a, b), state.params, new_state.params),
        False,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, built):
    """decode(token_S | cache(prefill tokens_0..S-1)) == forward(tokens_0..S)."""
    cfg, model, params = built(arch)
    S = 16
    batch = concrete_inputs(cfg, InputShape("t", S + 1, 1, "train"), jax.random.key(2))
    batch.pop("labels", None)
    full_logits, _ = model.forward(params, batch)

    pre = {k: (v[:, :S] if k == "tokens" else v) for k, v in batch.items()}
    _, cache = model.prefill(params, pre, S + 1)
    step_logits, _ = model.decode_step(
        params, cache, batch["tokens"][:, S : S + 1], jnp.int32(S)
    )
    a = np.asarray(full_logits[:, -1], np.float32)
    b = np.asarray(step_logits[:, 0], np.float32)
    # bf16 accumulation-order differences: compare top-1 and correlation
    assert np.argmax(a) == np.argmax(b) or np.corrcoef(a.ravel(), b.ravel())[0, 1] > 0.99


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "hymba-1.5b", "xlstm-350m"])
def test_subquadratic_archs_decode_with_bounded_cache(arch, built):
    """long_500k-capable archs must have cache size independent of seq_len."""
    cfg, model, params = built(arch)
    big = model.cache_specs(batch=1, seq_len=1 << 16)
    small = model.cache_specs(batch=1, seq_len=1 << 12)

    def total(specs):
        import numpy as np
        from repro.models.common import ParamSpec

        return sum(
            int(np.prod(s.shape))
            for s in jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, ParamSpec)
            )
        )

    if cfg.sub_quadratic:
        assert total(big) == total(small)


def test_param_counts_in_expected_range():
    """Analytic parameter counts should be in each arch's advertised ballpark."""
    expect = {
        "qwen1.5-32b": (30e9, 36e9),
        "qwen3-4b": (3.5e9, 4.8e9),
        "gemma-7b": (7.5e9, 9.5e9),     # gemma counts 8.5B with embeddings
        "qwen1.5-4b": (3.3e9, 4.5e9),
        "phi3.5-moe-42b-a6.6b": (39e9, 45e9),
        "mixtral-8x7b": (44e9, 49e9),
        "whisper-tiny": (25e6, 50e6),
        "hymba-1.5b": (1.2e9, 2.0e9),
        # our mLSTM cell uses full (inner×inner) q/k/v maps where the paper
        # block-diagonalizes them — structurally faithful, slightly heavier
        "xlstm-350m": (0.25e9, 0.6e9),
        "internvl2-26b": (18e9, 24e9),  # LLM backbone only (ViT is stubbed)
    }
    for arch, (lo, hi) in expect.items():
        n = build_model(get_arch(arch)).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"
