"""Structured event telemetry (``repro.obs``): the frozen registry's
lossless JSONL round trip, the replay oracle — an event log re-billed
through the REAL accounting entry points reconstructs the run's
Breakdown bit-exactly — null-recorder byte-identity (telemetry off
changes nothing), cross-engine log identity (reference and vectorized
simulators emit the same timeline), and the replay/export CLIs."""
import dataclasses
import json

from hypothesis import given, settings, strategies as st
import numpy as np
import pytest

from repro.core import (
    CheckpointPolicy,
    Job,
    MigrationPolicy,
    OnDemandPolicy,
    ReplicationPolicy,
    Simulator,
    SiwoftPolicy,
    generate_markets,
    legacy_menu,
    split_history_future,
)
from repro.core import provisioner as alg
from repro.core.accounting import (
    TIME_COMPONENTS,
    Breakdown,
    PriceTable,
    Session,
    bill_session,
)
from repro.core.market import Market, MarketSet
from repro.obs import events as E
from repro.obs import replay as rp
from repro.obs.export import read_jsonl, to_chrome_trace, write_jsonl
from repro.obs.recorder import NullRecorder, current, recording
from repro.serve import (
    FleetSimulator,
    ServePolicy,
    ServingWorkload,
    on_demand_reference,
)

# --- shared hand-built serving scenario (mirrors test_serve_fleet) ----------


def _hand_markets():
    mk = [
        Market(0, "g4.a", "us-east-1", "us-east-1a", 10, 1.0,
               device_count=4, interconnect_gbps=25.0),
        Market(1, "g4.b", "us-east-1", "us-east-1b", 10, 1.0,
               device_count=4, interconnect_gbps=25.0),
        Market(2, "g4.c", "us-west-2", "us-west-2a", 10, 1.0,
               device_count=4, interconnect_gbps=25.0),
        Market(3, "g4.d", "eu-central-1", "eu-central-1a", 10, 1.0,
               device_count=4, interconnect_gbps=25.0),
    ]
    H = 24 * 90
    hp = np.full((4, H), 0.35)
    hp[2, ::45] = 1.5
    F = 48
    fp = np.full((4, F), 0.35)
    fp[1, 6:8] = 1.5
    return MarketSet(mk, hp), MarketSet(mk, fp, start_hour=H)


def _hand_workload():
    return ServingWorkload(
        target_tokens_per_sec=500.0,
        replica_tokens_per_sec=100.0,
        state_gb=30.0,
        param_bytes=120_000_000,
        cache_bytes=30_000_000,
        inflight_context_tokens=2048.0,
    )


def _rate(hours=48):
    rate = np.full(hours, 400.0)
    rate[0] = 0.0
    return rate


def _bd_fields(bd: Breakdown) -> tuple:
    return (
        dict(bd.time), dict(bd.cost), dict(bd.leg_cost), bd.revocations,
        bd.sessions, bd.wall_time, bd.served_tokens, bd.shed_tokens,
        bd.queued_token_seconds,
    )


def _replay_single(events):
    runs, problems = rp.verify_events(events)
    assert problems == [], problems
    assert len(runs) == 1
    run = runs[0]
    assert run.pin is not None
    assert rp.mismatches(run.breakdown, run.pin) == []
    return run


# --- registry + round trip --------------------------------------------------


def test_default_recorder_is_null_and_disabled():
    rec = current()
    assert isinstance(rec, NullRecorder)
    assert rec.enabled is False


def test_wire_names_are_unique_and_snake_case():
    assert len(E.EVENT_TYPES) == 20
    for name, cls in E.EVENT_TYPES.items():
        assert name == E.wire_name(cls)
        assert name == name.lower() and " " not in name


def test_every_event_type_round_trips_through_json():
    samples = [
        E.RunStart(t=0.0, subsystem="fleet", label="fleet/static",
                   horizon_hours=48.0),
        E.PriceTrace(t=0.0, prices=((0.35, 1.5), (0.4, 0.4))),
        E.RunEnd(t=48.0, wall_hours=48.0),
        E.Provision(t=1.0, market_id=3, legs=(3, 1), replica_id=2,
                    rate_tokens_per_sec=325.0),
        E.Revoke(t=6.0, market_id=1, replica_id=0),
        E.ReshardStart(t=6.0, bytes_moved=120_000_000, gbps=25.0),
        E.ReshardDone(t=6.01, hours=0.01),
        E.ScaleDecision(t=7.0, kind="up", offered_tokens_per_sec=400.0,
                        forecast_tokens_per_sec=480.0,
                        capacity_tokens_per_sec=650.0,
                        target_tokens_per_sec=600.0),
        E.ScaleUp(t=7.0, added=1, target_tokens_per_sec=600.0),
        E.ScaleDown(t=30.0, retired=1, target_tokens_per_sec=400.0),
        E.Admit(t=3.0, request_id=7, lane=1, pages_reserved=4),
        E.Evict(t=9.0, request_id=7, lane=1, reason="length"),
        E.Shed(t=5.0, request_id=7, lane=1, prompt_tokens=17,
               resume_tokens=4),
        E.Drain(t=5.0, moved_requests=2),
        E.GaugeSample(t=5.0, name="engine.occupancy", value=0.5),
        E.SessionBilled(t=8.0, market_id=1, start_wall=0.0,
                        intervals=(("startup", 0.2), ("execution", 5.8)),
                        legs=(1,), leg_anchors=None, leg_releases=None,
                        price_const=None),
        E.SessionBilled(t=8.0, market_id=0, start_wall=0.0,
                        intervals=(("execution", 8.0),), legs=(0, 2),
                        leg_anchors=(0.0, 0.0), leg_releases=(True, False),
                        price_const=0.9),
        E.LegSettled(t=12.0, market_id=2, anchor=3.0, end_wall=12.0),
        E.RouterInterval(t=0.0, t0=0.0, t1=1.0, offered_tokens=1e5,
                         served_tokens=9e4, shed_tokens=1e4,
                         queued_token_seconds=50.0,
                         slo_violation_seconds=2.5, q_end=10.0,
                         delay_segments=((1.0, 0.0, 0.5),)),
        E.SloViolation(t=0.0, seconds=2.5),
        E.BreakdownPin(t=48.0, time=(("execution", 48.0),),
                       cost=(("execution", 16.8),), leg_cost=((0, 16.8),),
                       revocations=1, sessions=2, wall_time=48.0,
                       served_tokens=1e6, shed_tokens=0.0,
                       queued_token_seconds=12.5),
    ]
    assert {type(s) for s in samples} == set(E.EVENT_TYPES.values())
    for ev in samples:
        wire = json.loads(json.dumps(E.as_dict(ev)))
        back = E.from_dict(wire)
        assert back == ev, ev


def test_jsonl_file_round_trip(tmp_path):
    events = [
        E.RunStart(t=0.0, subsystem="x", label="y", horizon_hours=1.0),
        E.Revoke(t=0.5, market_id=3),
        E.RunEnd(t=1.0, wall_hours=1.0),
    ]
    path = tmp_path / "trace.jsonl"
    assert write_jsonl(path, events) == 3
    assert read_jsonl(path) == events


# --- the replay oracle on the serving fleet ---------------------------------


def test_fleet_static_sizing_replay_bit_exact():
    hist, fut = _hand_markets()
    wl = _hand_workload()
    policy = ServePolicy(slo_horizon_hours=12.0, capacity_headroom=1.4)
    with recording() as rec:
        rep = FleetSimulator(hist, fut, wl, policy).run(48.0, _rate())
    run = _replay_single(rec.events)
    assert run.subsystem == "fleet" and run.label == "fleet/static"
    assert _bd_fields(run.breakdown) == _bd_fields(rep.breakdown)
    # the scenario actually exercises the interesting paths
    assert rep.revocations == 1 and rep.breakdown.served_tokens > 0


def test_fleet_static_mode_replay_bit_exact():
    hist, fut = _hand_markets()
    wl = _hand_workload()
    with recording() as rec:
        rep = FleetSimulator(
            hist, fut, wl,
            ServePolicy(slo_horizon_hours=12.0, capacity_headroom=1.5),
            mode="static",
        ).run(48.0, _rate())
    run = _replay_single(rec.events)
    assert run.label == "static/static"
    assert _bd_fields(run.breakdown) == _bd_fields(rep.breakdown)
    assert rep.breakdown.time["recovery"] > 0  # full restores replayed too


def test_fleet_autoscale_replay_bit_exact():
    hist, fut = _hand_markets()
    wl = _hand_workload()
    policy = ServePolicy(slo_horizon_hours=12.0, capacity_headroom=1.4)
    hours = 48
    rate = 250.0 - 150.0 * np.cos(2 * np.pi * np.arange(hours) / 24.0)
    rate[0] = 0.0
    with recording() as rec:
        rep = FleetSimulator(
            hist, fut, wl, policy, sizing="auto"
        ).run(float(hours), rate)
    run = _replay_single(rec.events)
    assert run.label == "fleet/auto"
    assert _bd_fields(run.breakdown) == _bd_fields(rep.breakdown)
    # the diurnal rate must have driven real scaler traffic
    kinds = [e.kind for e in rec.events if isinstance(e, E.ScaleDecision)]
    assert "up" in kinds or "down" in kinds
    assert rep.scale_downs > 0 or rep.scale_ups > 0


def test_on_demand_reference_replay_bit_exact():
    hist, fut = _hand_markets()
    wl = _hand_workload()
    policy = ServePolicy(slo_horizon_hours=12.0, capacity_headroom=1.4)
    feats = alg.MarketFeatures.from_history(hist)
    with recording() as rec:
        rep = on_demand_reference(wl, feats, fut, 48.0, _rate(), policy)
    run = _replay_single(rec.events)
    assert run.label == "on_demand"
    assert _bd_fields(run.breakdown) == _bd_fields(rep.breakdown)


def test_fleet_breakdown_literal_pin():
    """The hand-built 48 h scenario's totals, pinned as literals: the
    replay oracle guarantees log == run, this pins run == history (the
    numbers current at instrumentation time — a drift here is a billing
    change, not a telemetry change)."""
    hist, fut = _hand_markets()
    wl = _hand_workload()
    policy = ServePolicy(slo_horizon_hours=12.0, capacity_headroom=1.4)
    with recording() as rec:
        rep = FleetSimulator(hist, fut, wl, policy).run(48.0, _rate())
    run = _replay_single(rec.events)
    assert run.breakdown.total_cost == rep.breakdown.total_cost
    bd = rep.breakdown
    assert bd.total_cost == 50.40000000000013
    assert bd.time["execution"] == 143.83310112988207
    assert (bd.wall_time, bd.revocations, bd.sessions) == (48.0, 1, 4)
    assert bd.served_tokens == 67_680_000.0 and bd.shed_tokens == 0.0


def test_null_recorder_keeps_run_byte_identical():
    """Telemetry OFF is the default; ON must not perturb one bit of the
    arithmetic. Run the same fleet twice — under the null recorder and
    under a live one — and compare every Breakdown field with ==."""
    hist, fut = _hand_markets()
    wl = _hand_workload()
    policy = ServePolicy(slo_horizon_hours=12.0, capacity_headroom=1.4)
    assert current().enabled is False  # default: null
    plain = FleetSimulator(hist, fut, wl, policy).run(48.0, _rate())
    with recording() as rec:
        traced = FleetSimulator(hist, fut, wl, policy).run(48.0, _rate())
    assert rec.events  # the live run DID emit
    assert _bd_fields(plain.breakdown) == _bd_fields(traced.breakdown)
    assert plain.cost_dollars == traced.cost_dollars


# --- the replay oracle on the training simulator ----------------------------


SIM_POLICIES = (
    SiwoftPolicy(),
    CheckpointPolicy(),
    MigrationPolicy(),
    ReplicationPolicy(),
    OnDemandPolicy(),
)


@pytest.fixture(scope="module")
def sim_markets():
    ms = generate_markets(seed=0, n_hours=24 * 90 + 24 * 45,
                          menu=legacy_menu())
    return split_history_future(ms, 24 * 90)


def test_simulator_replay_bit_exact_both_engines(sim_markets):
    hist, fut = sim_markets
    job = Job(length_hours=24, memory_gb=16)
    for engine in ("vectorized", "reference"):
        sim = Simulator(hist, fut, seed=0, engine=engine)
        for policy in SIM_POLICIES:
            with recording() as rec:
                bd = sim.run_job(job, policy, n_revocations=2)
            run = _replay_single(rec.events)
            assert run.subsystem == "simulator"
            assert _bd_fields(run.breakdown) == _bd_fields(bd), (
                engine, type(policy).__name__)


def test_simulator_engines_emit_identical_logs(sim_markets):
    """The vectorized core bills through PriceTable and the scalar oracle
    through a closure — but the TIMELINE is engine-invariant: both must
    emit byte-identical event logs (the cross-engine form of the
    bit-exactness pin in test_vectorized_core)."""
    hist, fut = sim_markets
    job = Job(length_hours=24, memory_gb=16)
    for policy in SIM_POLICIES:
        logs = []
        for engine in ("vectorized", "reference"):
            with recording() as rec:
                Simulator(hist, fut, seed=0, engine=engine).run_job(
                    job, policy, n_revocations=2
                )
            logs.append(json.dumps([E.as_dict(e) for e in rec.events]))
        assert logs[0] == logs[1], type(policy).__name__


# --- the replay oracle on the orchestrator (real JAX training) --------------


def test_orchestrator_replay_bit_exact(host_mesh):
    """The orchestrator drives REAL training, yet its billed timeline
    replays like any other: checkpoint mode with forced revocations
    exercises sessions, recovery billing, and the revocation counter."""
    import tempfile

    from repro.config import TrainConfig, get_arch
    from repro.core.orchestrator import SpotTrainingOrchestrator
    from repro.data import SyntheticLM
    from repro.models import build_model

    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    ds = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=4, seed=0)
    ms = generate_markets(seed=3, n_hours=24 * 90 + 24 * 30)
    hist, fut = split_history_future(ms, 24 * 90)
    tc = TrainConfig(total_steps=60, warmup_steps=5)
    with tempfile.TemporaryDirectory() as d, recording() as rec:
        rep = SpotTrainingOrchestrator(
            model, ds, host_mesh, hist, fut, mode="checkpoint", tc=tc,
            segment_steps=10, steps_per_trace_hour=200, ckpt_dir=d,
            ckpt_every=5, seed=0, ft_revocations=2,
        ).run(30)
    run = _replay_single(rec.events)
    assert run.subsystem == "orchestrator"
    assert _bd_fields(run.breakdown) == _bd_fields(rep.breakdown)
    assert run.breakdown.revocations == rep.revocations >= 1


# --- property test: random sessions through emit -> JSONL -> replay ---------


@given(
    n_sessions=st.integers(1, 6),
    seed=st.integers(0, 10_000),
    price_lo=st.floats(0.05, 0.5),
    price_hi=st.floats(0.6, 3.0),
)
@settings(max_examples=40, deadline=None)
def test_random_sessions_replay_bit_exact(n_sessions, seed, price_lo, price_hi, tmp_path):
    """Any run assembled from random sessions survives emit -> JSONL ->
    replay with its Breakdown reconstructed bit-exactly: Python's json
    floats round-trip shortest-repr exact, and replay bills through the
    same bill_session the run used."""
    rng = np.random.default_rng(seed)
    n_markets, horizon = 4, 48  # roomy: max 6 sessions x ~6 h each
    prices = rng.uniform(price_lo, price_hi, size=(n_markets, horizon))
    table = PriceTable(prices)

    bd = Breakdown()
    events = [
        E.RunStart(t=0.0, subsystem="simulator", label="random",
                   horizon_hours=float(horizon)),
        E.price_trace(0.0, prices),
    ]
    wall = 0.0
    for _ in range(n_sessions):
        market = int(rng.integers(0, n_markets))
        session = Session(market_id=market, start_wall=wall)
        for comp in rng.choice(TIME_COMPONENTS[:6], size=2, replace=False):
            session.add(str(comp), float(rng.uniform(0.1, 3.0)))
        events.append(E.session_billed(wall, session))
        wall += bill_session(session, table, bd)
    bd.wall_time = wall
    events.append(E.breakdown_pin(wall, bd))
    events.append(E.RunEnd(t=wall, wall_hours=wall))

    path = tmp_path / "random.jsonl"
    write_jsonl(path, events)
    run = _replay_single(read_jsonl(path))
    assert _bd_fields(run.breakdown) == _bd_fields(bd)


# --- CLIs -------------------------------------------------------------------


def _fleet_trace(tmp_path, name="fleet.jsonl"):
    hist, fut = _hand_markets()
    wl = _hand_workload()
    policy = ServePolicy(slo_horizon_hours=12.0, capacity_headroom=1.4)
    with recording() as rec:
        FleetSimulator(hist, fut, wl, policy).run(48.0, _rate())
    path = tmp_path / name
    write_jsonl(path, rec.events)
    return path, rec.events


def test_replay_cli_accepts_and_rejects(tmp_path, capsys):
    path, events = _fleet_trace(tmp_path)
    assert rp.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "1 run(s)" in out and "0 mismatch(es)" in out

    # corrupt the pin: the CLI must exit nonzero and name the field
    bad = []
    for ev in events:
        if isinstance(ev, E.BreakdownPin):
            ev = dataclasses.replace(ev, revocations=ev.revocations + 1)
        bad.append(ev)
    bad_path = tmp_path / "bad.jsonl"
    write_jsonl(bad_path, bad)
    assert rp.main([str(bad_path)]) == 1
    err = capsys.readouterr().err
    assert "revocations" in err


def test_chrome_trace_export(tmp_path, capsys):
    path, events = _fleet_trace(tmp_path)
    trace = to_chrome_trace(events)
    assert trace["traceEvents"]
    phases = {ev["ph"] for ev in trace["traceEvents"]}
    assert "X" in phases and "M" in phases  # slices + track names
    # every event JSON-serializable (Perfetto loads the file as-is)
    blob = json.dumps(trace)
    assert "fleet" in blob

    from repro.obs.export import main as export_main

    out = tmp_path / "trace.json"
    assert export_main([str(path), "-o", str(out)]) == 0
    assert "CHROME_TRACE" in capsys.readouterr().out
    assert json.loads(out.read_text())["traceEvents"]
