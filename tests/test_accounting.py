"""Billing-cycle accounting properties (hypothesis) + CSV trace loader."""
import math

from hypothesis import given, settings, strategies as st
import numpy as np
import pytest

from repro.core.accounting import Breakdown, Session, bill_session
from repro.core.market import generate_markets, load_csv_traces


@given(
    start=st.floats(0, 100),
    durations=st.lists(st.floats(0.01, 5.0), min_size=1, max_size=6),
    price=st.floats(0.01, 10.0),
)
@settings(max_examples=60, deadline=None)
def test_billing_invariants(start, durations, price):
    session = Session(market_id=0, start_wall=start)
    comps = ["execution", "re_execution", "checkpointing", "recovery", "startup"]
    for i, d in enumerate(durations):
        session.add(comps[i % len(comps)], d)
    bd = Breakdown()
    used = bill_session(session, lambda m, h: price, bd)
    total = sum(durations)
    assert used == pytest.approx(total, rel=1e-9)
    # time conservation
    assert bd.total_time == pytest.approx(total, rel=1e-9)
    # whole-hour billing: cost = ceil(used) * price exactly (flat price)
    assert bd.total_cost == pytest.approx(math.ceil(total) * price, rel=1e-6)
    # buffer bounded by one cycle
    assert 0 <= bd.cost["billing_buffer"] <= price + 1e-9


@given(
    d1=st.floats(0.1, 3.0), d2=st.floats(0.1, 3.0), price=st.floats(0.1, 5.0)
)
@settings(max_examples=30, deadline=None)
def test_splitting_sessions_never_cheaper(d1, d2, price):
    """Whole-hour billing: two sessions cost ≥ one merged session — the
    source of the paper's 'buffer costs of billing cycles' FT overhead."""
    def cost(durs):
        bd = Breakdown()
        for d in durs:
            s = Session(0, 0.0)
            s.add("execution", d)
            bill_session(s, lambda m, h: price, bd)
        return bd.total_cost

    assert cost([d1, d2]) >= cost([d1 + d2]) - 1e-9


def test_csv_roundtrip(tmp_path):
    """Topology-aware trace format: device_count/interconnect columns ride
    along and survive the roundtrip."""
    ms = generate_markets(seed=0, n_hours=48)
    rows = ["market_id,instance_type,region,zone,memory_gb,on_demand_price,"
            "device_count,interconnect_gbps,"
            + ",".join(f"h{i}" for i in range(48))]
    for m in ms.markets[:10]:
        prices = ",".join(f"{p:.6f}" for p in ms.prices[m.market_id])
        rows.append(
            f"{m.market_id},{m.instance_type},{m.region},{m.zone},"
            f"{m.memory_gb},{m.on_demand_price},"
            f"{m.device_count},{m.interconnect_gbps},{prices}"
        )
    p = tmp_path / "traces.csv"
    p.write_text("\n".join(rows))
    loaded = load_csv_traces(str(p))
    assert len(loaded.markets) == 10
    np.testing.assert_allclose(loaded.prices, ms.prices[:10], atol=1e-6)
    np.testing.assert_allclose(loaded.mttr_hours(), ms.mttr_hours()[:10])
    for got, want in zip(loaded.markets, ms.markets[:10]):
        assert got.device_count == want.device_count
        assert got.interconnect_gbps == want.interconnect_gbps
        assert got.total_memory_gb == want.total_memory_gb


def test_legacy_csv_without_topology_columns(tmp_path):
    """Pre-menu traces (6 meta columns) still load, as 1-device markets."""
    rows = ["market_id,instance_type,region,zone,memory_gb,on_demand_price,h0,h1",
            "0,m5.xlarge,us-east-1,us-east-1a,16,0.192,0.05,0.06"]
    p = tmp_path / "legacy.csv"
    p.write_text("\n".join(rows))
    loaded = load_csv_traces(str(p))
    assert loaded.markets[0].device_count == 1
    assert loaded.prices.shape == (1, 2)


def test_staggered_anchors_match_legacy_when_aligned():
    """leg_anchors == session start with every leg released is EXACTLY the
    legacy aligned-cycle billing (the bit-exactness escape hatch)."""
    for durs in ([0.4], [0.7, 0.9], [1.0], [2.5, 0.25]):
        legacy, staggered = Breakdown(), Breakdown()
        for bd, anchored in ((legacy, False), (staggered, True)):
            s = Session(
                0, 3.25, legs=(0, 1),
                leg_anchors=(3.25, 3.25) if anchored else None,
                leg_releases=(True, True) if anchored else None,
            )
            for d in durs:
                s.add("execution", d)
            bill_session(s, lambda m, h: 2.0 if m else 1.0, bd)
        assert legacy.total_cost == staggered.total_cost
        assert legacy.leg_cost == staggered.leg_cost


def test_mid_cycle_one_leg_repair_bills_only_that_legs_partial_hour():
    """THE staggering scenario, pinned: allocation (A=0, B=1) loses B at
    wall 0.4; A's cycle stays open (no buffer at the boundary), the repair
    session (A, C=2) runs 0.6 h more and releases everything. Flat $1/h on
    every market so the dollars ARE the hours:

    * B: 0.4 h used + 0.6 h buffer (its own partial hour)  = 1.0
    * A: 1.0 h used + 0 buffer (cycle closes exactly at 1.0) = 1.0
    * C: 0.6 h used + 0.4 h buffer (anchored at 0.4)         = 1.0

    Legacy aligned billing would charge A 2.0 (0.4 + 0.6 buffer at the
    revocation, then a fresh 0.6 + 0.4-buffer cycle): the repair no longer
    restarts the surviving leg's cycle. sum(leg_cost) == total_cost holds
    exactly.
    """
    bd = Breakdown()
    price = lambda m, h: 1.0
    s1 = Session(
        0, 0.0, legs=(0, 1),
        leg_anchors=(0.0, 0.0),
        leg_releases=(False, True),  # B revoked; A's occupancy continues
    )
    s1.add("execution", 0.4)
    bill_session(s1, price, bd)
    s2 = Session(
        0, 0.4, legs=(0, 2),
        leg_anchors=(0.0, 0.4),      # A keeps its anchor; C starts fresh
        leg_releases=(True, True),
    )
    s2.add("execution", 0.6)
    bill_session(s2, price, bd)
    assert bd.leg_cost[0] == pytest.approx(1.0, abs=1e-12)
    assert bd.leg_cost[1] == pytest.approx(1.0, abs=1e-12)
    assert bd.leg_cost[2] == pytest.approx(1.0, abs=1e-12)
    assert sum(bd.leg_cost.values()) == bd.total_cost  # exact decomposition
    assert bd.cost["billing_buffer"] == pytest.approx(1.0, abs=1e-12)

    # legacy aligned cycles on the same trajectory: A pays the extra
    # mid-cycle buffer restart — staggering is strictly cheaper
    legacy = Breakdown()
    l1 = Session(0, 0.0, legs=(0, 1))
    l1.add("execution", 0.4)
    bill_session(l1, price, legacy)
    l2 = Session(0, 0.4, legs=(0, 2))
    l2.add("execution", 0.6)
    bill_session(l2, price, legacy)
    assert legacy.leg_cost[0] == pytest.approx(2.0)
    assert legacy.total_cost > bd.total_cost


def test_settle_leg_closes_deferred_cycle():
    """A deferred leg whose allocation drops it settles its final partial
    cycle standalone — and lands in leg_cost so the decomposition stays
    exact."""
    from repro.core.accounting import settle_leg

    bd = Breakdown()
    s = Session(0, 0.0, legs=(0, 1), leg_anchors=(0.0, 0.0),
                leg_releases=(True, False))
    s.add("execution", 0.25)
    bill_session(s, lambda m, h: 1.0, bd)
    # leg 1 deferred; its occupancy ended at 0.25 and nothing reuses it
    paid = settle_leg(bd, 1, 0.0, 0.25, lambda m, h: 1.0)
    assert paid == pytest.approx(0.75)
    assert bd.leg_cost[1] == pytest.approx(1.0)
    assert sum(bd.leg_cost.values()) == pytest.approx(bd.total_cost)


def test_reshard_component_sums_into_totals():
    """The new ``reshard`` component is a first-class billing citizen: it
    lands in Breakdown.time/cost and sums into total_time/total_cost."""
    from repro.core.accounting import COST_COMPONENTS, TIME_COMPONENTS

    assert "reshard" in TIME_COMPONENTS and "reshard" in COST_COMPONENTS
    s = Session(market_id=0, start_wall=0.0)
    s.add("execution", 0.5)
    s.add("reshard", 0.25)
    bd = Breakdown()
    bill_session(s, lambda m, h: 2.0, bd)
    assert bd.time["reshard"] == pytest.approx(0.25)
    assert bd.cost["reshard"] == pytest.approx(0.5)
    assert bd.total_time == pytest.approx(0.75)
    # 0.75 h used -> 1 whole billed hour at $2/h
    assert bd.total_cost == pytest.approx(2.0)
    assert bd.cost["billing_buffer"] == pytest.approx(0.5)
