"""End-to-end: the paper's provisioner driving REAL JAX training, all three
modes, with revocation/restore/goodput accounting."""
import tempfile

import numpy as np
import pytest

from repro.config import TrainConfig, get_arch
from repro.core import generate_markets, split_history_future
from repro.core.orchestrator import SpotTrainingOrchestrator
from repro.data import SyntheticLM
from repro.models import build_model


@pytest.fixture(scope="module")
def setup(host_mesh):
    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    ds = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=4, seed=0)
    ms = generate_markets(seed=3, n_hours=24 * 90 + 24 * 30)
    hist, fut = split_history_future(ms, 24 * 90)
    tc = TrainConfig(total_steps=60, warmup_steps=5)
    return cfg, model, ds, hist, fut, tc, host_mesh


def _run(setup, mode, **kw):
    cfg, model, ds, hist, fut, tc, mesh = setup
    with tempfile.TemporaryDirectory() as d:
        orch = SpotTrainingOrchestrator(
            model, ds, mesh, hist, fut, mode=mode, tc=tc,
            segment_steps=10, steps_per_trace_hour=200, ckpt_dir=d,
            ckpt_every=5, seed=0, **kw,
        )
        return orch.run(30)


def test_siwoft_mode_full_goodput(setup):
    """Algorithm-1 markets (MTTR-selected) see no revocation in this trace."""
    rep = _run(setup, "siwoft")
    assert rep.useful_steps == 30
    assert rep.revocations == 0
    assert rep.goodput == 1.0
    assert rep.losses[0] > rep.losses[-1]


def test_checkpoint_mode_recovers_and_finishes(setup):
    rep = _run(setup, "checkpoint", ft_revocations=2)
    assert rep.useful_steps == 30
    assert rep.revocations >= 1
    assert rep.wasted_steps >= 1
    assert rep.goodput < 1.0
    assert np.isfinite(rep.cost_dollars) and rep.cost_dollars > 0


def test_hybrid_mode(setup):
    rep = _run(setup, "hybrid")
    assert rep.useful_steps == 30
    assert rep.losses[0] > rep.losses[-1]


def test_modes_converge_to_same_loss_scale(setup):
    """Revocation handling must not corrupt optimization."""
    r1 = _run(setup, "siwoft")
    r2 = _run(setup, "checkpoint", ft_revocations=2)
    assert abs(r1.losses[-1] - r2.losses[-1]) < 1.0
