"""Elastic resharding + a subprocess multi-device integration test (8 host
devices via XLA_FLAGS, since the main test process is pinned to 1 CPU)."""
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np

from repro.dist import reshard_tree


def test_reshard_tree_identity(host_mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    sh = {"w": NamedSharding(host_mesh, P())}
    out = reshard_tree(tree, sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


MULTI_DEVICE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.config import ShardingLayout, TrainConfig, get_arch
    from repro.dist import param_shardings, reshard_params
    from repro.launch.mesh import make_mesh
    from repro.models import build_model
    from repro.train.steps import build_train_step, init_train_state
    from repro.data import SyntheticLM

    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    layout = ShardingLayout()

    mesh_a = make_mesh((4, 2), ("data", "model"))
    mesh_b = make_mesh((2, 2), ("data", "model"))  # elastic shrink: 8 -> 4

    params = model.init(jax.random.key(0))
    sh_a = param_shardings(model.specs, mesh_a, layout)
    params = jax.device_put(params, sh_a)

    # one sharded train step on mesh A
    ds = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
    tc = TrainConfig(warmup_steps=1, total_steps=10)
    step = build_train_step(model, tc, layout)
    from repro.train.steps import TrainState
    from repro.optim import init_opt_state
    state = TrainState(params, init_opt_state(params), jnp.zeros((), jnp.int32))
    with mesh_a:
        state, m1 = jax.jit(step)(state, ds.batch(0))
    loss_a = float(m1["loss"])

    # revocation shrinks capacity: reshard the params onto mesh B and step
    new_params = reshard_params(state.params, model.specs, mesh_b, layout)
    step_b = jax.device_put(
        jnp.zeros((), jnp.int32) + 1, NamedSharding(mesh_b, P())
    )
    state_b = TrainState(new_params, init_opt_state(new_params), step_b)
    ds_b = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
    with mesh_b:
        state_b, m2 = jax.jit(step)(state_b, ds_b.batch(1))
    loss_b = float(m2["loss"])
    assert np.isfinite(loss_a) and np.isfinite(loss_b), (loss_a, loss_b)
    print("ELASTIC_OK", loss_a, loss_b)
    """
)


def test_elastic_reshard_across_meshes_subprocess():
    # inherit the parent env (JAX_PLATFORMS etc. — a bare env makes the PJRT
    # plugin probe for TPU metadata and hang); only PYTHONPATH is forced
    import os
    import pathlib

    repo = pathlib.Path(__file__).resolve().parents[1]
    res = subprocess.run(
        [sys.executable, "-c", MULTI_DEVICE_SCRIPT],
        capture_output=True,
        text=True,
        timeout=420,
        env={**os.environ, "PYTHONPATH": str(repo / "src")},
        cwd=str(repo),
    )
    assert "ELASTIC_OK" in res.stdout, res.stdout + res.stderr
