"""Layout-sweep answer to the ROADMAP question "do fsdp_heavy / moe_tp
beat baseline on collective bytes?" — asserted against the committed
``results/dryrun`` artifacts from ``launch/dryrun.py --cell ... --layout``.

The measured answer (pinned here so it stays true as the sharding layer
evolves) is NUANCED, not the hoped-for clean win:

* ``fsdp_heavy`` on qwen3-4b train_4k: a marginal collective-bytes WIN
  over baseline (joint (data, model) sharding of vocab/ffn removes a
  sliver of gradient all-reduce wire).
* ``fsdp_heavy`` on gemma-7b train_4k: a clear REGRESSION — gemma's wide
  256k vocab sharded jointly forces re-gathers that cost ~43 % more wire
  and a ~6× peak-memory blowup. fsdp_heavy is a memory/bytes trade, not a
  free lunch, and baseline (which already FSDP-shards the embed dim) is
  the right default.
* ``moe_tp`` on mixtral-8x7b: EXACTLY baseline — mixtral's 8 experts
  don't divide the 16-wide model axis, so baseline's expert-parallel rule
  already falls back to replication and both rule sets resolve to the
  same PartitionSpecs (the divisibility discipline of
  ``dist/sharding.py`` at work).
* ``moe_tp`` on phi3.5-moe (16 experts — divisible): slightly MORE wire
  than baseline; tensor-parallel experts pay all-reduce on every expert
  ffn where expert parallelism paid all-to-all on a thinner buffer.
"""
import json
import pathlib

import pytest

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "dryrun"


def _load(arch: str, layout: str, shape: str = "train_4k", mesh: str = "16x16"):
    p = RESULTS / f"{arch}__{shape}__{mesh}__{layout}.json"
    if not p.exists():
        pytest.skip(f"missing dryrun artifact {p.name} (run the layout sweep)")
    return json.loads(p.read_text())


@pytest.mark.parametrize("arch,layout", [
    ("qwen3-4b", "fsdp_heavy"),
    ("gemma-7b", "fsdp_heavy"),
    ("mixtral-8x7b", "moe_tp"),
    ("phi3.5-moe-42b-a6.6b", "moe_tp"),
])
def test_layout_sweep_artifacts_are_complete(arch, layout):
    base = _load(arch, "baseline")
    alt = _load(arch, layout)
    for r in (base, alt):
        assert r["flops"] > 0 and r["collective_wire_bytes"] > 0


def test_fsdp_heavy_beats_baseline_on_qwen_collective_bytes():
    base = _load("qwen3-4b", "baseline")
    alt = _load("qwen3-4b", "fsdp_heavy")
    assert alt["collective_wire_bytes"] <= base["collective_wire_bytes"]


def test_fsdp_heavy_regresses_on_gemma_wide_vocab():
    """The negative result, pinned: joint vocab sharding on a 256k-vocab
    model costs MORE wire, much more memory, and even extra FLOPs (XLA
    re-materializes around the joint-sharded unembed). Baseline — which
    already FSDP-shards the embed dim — stays the default."""
    base = _load("gemma-7b", "baseline")
    alt = _load("gemma-7b", "fsdp_heavy")
    assert alt["collective_wire_bytes"] > base["collective_wire_bytes"]
    assert alt["peak_bytes_per_device"] > 2 * base["peak_bytes_per_device"]
    assert alt["flops"] > 1.2 * base["flops"]


def test_moe_tp_is_noop_when_experts_dont_divide_model_axis():
    """mixtral: 8 experts % 16 model shards != 0 — both rule sets resolve
    identically, byte for byte."""
    base = _load("mixtral-8x7b", "baseline")
    alt = _load("mixtral-8x7b", "moe_tp")
    assert alt["collective_wire_bytes"] == base["collective_wire_bytes"]
    assert alt["hbm_bytes"] == base["hbm_bytes"]


def test_moe_tp_costs_wire_when_experts_do_divide():
    """phi3.5-moe (16 experts, divisible): tensor-parallel experts trade
    all-to-all for all-reduce and pay ~2 % more wire — expert parallelism
    keeps the default slot."""
    base = _load("phi3.5-moe-42b-a6.6b", "baseline")
    alt = _load("phi3.5-moe-42b-a6.6b", "moe_tp")
    assert alt["collective_wire_bytes"] >= base["collective_wire_bytes"]
    assert alt["collective_wire_bytes"] <= 1.10 * base["collective_wire_bytes"]
