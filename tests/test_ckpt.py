"""Checkpoint manager: roundtrip, keep-k, atomicity, async error surfacing."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager


@pytest.fixture()
def tree():
    return {
        "a": jnp.arange(12.0).reshape(3, 4),
        "b": {"c": jnp.ones((2, 5), jnp.bfloat16), "d": jnp.zeros((7,), jnp.int32)},
    }


def test_roundtrip(tmp_path, tree):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(5, tree, block=True)
    step, restored = mgr.restore(like=tree)
    assert step == 5
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    mgr.close()


def test_keep_last_k(tmp_path, tree):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in range(1, 6):
        mgr.save(s, tree)
    mgr.wait()
    assert mgr.all_steps() == [4, 5]
    mgr.close()


def test_tmp_dirs_invisible(tmp_path, tree):
    """A crash mid-write leaves only a .tmp dir, which readers ignore."""
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, tree, block=True)
    fake = pathlib.Path(tmp_path) / "step_0000000009.tmp"
    fake.mkdir()
    (fake / "arr_0.npy").write_bytes(b"garbage")
    assert mgr.all_steps() == [1]
    assert mgr.latest_step() == 1
    mgr.close()


def test_restore_specific_step(tmp_path, tree):
    mgr = CheckpointManager(tmp_path, keep=5)
    for s in (1, 2, 3):
        t = jax.tree_util.tree_map(lambda x: x + s, tree)
        mgr.save(s, t)
    mgr.wait()
    step, restored = mgr.restore(step=2, like=tree)
    assert step == 2
    np.testing.assert_allclose(np.asarray(restored["a"])[0, 0], 2.0)
    mgr.close()


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    with pytest.raises(FileNotFoundError):
        mgr.restore()
    mgr.close()


def test_async_overlap_many_saves(tmp_path, tree):
    """save() must not block; manifest of the final commit is complete."""
    mgr = CheckpointManager(tmp_path, keep=10)
    for s in range(8):
        mgr.save(s, tree)
    mgr.wait()
    last = pathlib.Path(tmp_path) / "step_0000000007" / "manifest.json"
    manifest = json.loads(last.read_text())
    assert manifest["n_leaves"] == len(jax.tree_util.tree_leaves(tree))
    mgr.close()


def test_restore_onto_shardings(tmp_path, tree, host_mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(tmp_path)
    mgr.save(1, tree, block=True)
    sh = jax.tree_util.tree_map(lambda _: NamedSharding(host_mesh, P()), tree)
    _, restored = mgr.restore(like=tree, shardings=sh)
    assert restored["a"].sharding == NamedSharding(host_mesh, P())
    mgr.close()
