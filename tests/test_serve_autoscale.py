"""Property-test harness for the demand-driven autoscaler (the
scaler/router/engine loop): token conservation across arbitrary
scale-up/scale-down sequences, capacity never below the in-flight
floor, cooldown respected on random traces — plus the AutoScaler rule
unit tests and the end-to-end auto-vs-static pin on a diurnal trace."""
import math

from hypothesis import given, settings, strategies as st
import numpy as np
import pytest

from repro.core.market import Market, MarketSet
from repro.serve import (
    AutoscalePolicy,
    AutoScaler,
    CapacityEvent,
    FleetSimulator,
    ServePolicy,
    ServingWorkload,
    idle_headroom_tokens,
    route_trace,
)

HEADROOM = 1.25


def _scaler(policy=None, *, headroom=HEADROOM, survive=True):
    return AutoScaler(
        policy or AutoscalePolicy(),
        capacity_headroom=headroom,
        survive_one_loss=survive,
    )


# --- the rule engine, one rule at a time ------------------------------------

def test_forecast_is_window_max_clamped_to_trace():
    s = _scaler(AutoscalePolicy(forecast_window_hours=3))
    trace = [10.0, 50.0, 20.0, 80.0, 5.0]
    assert s.forecast(trace, 0) == 50.0   # [10, 50, 20]
    assert s.forecast(trace, 1) == 80.0   # [50, 20, 80]
    assert s.forecast(trace, 4) == 5.0    # window past the end
    assert s.forecast(trace, 99) == 5.0   # clamped to last hour
    assert s.forecast([], 0) == 0.0


def test_satisfied_mirrors_provisioning_bars():
    s = _scaler(headroom=1.25)
    # headroom bar: 3×100 < 300×1.25
    assert not s.satisfied([100.0, 100.0, 100.0], 300.0)
    # N−1 bar: 500 capacity but losing the 400 leaves 100 < 200
    assert not s.satisfied([400.0, 100.0], 200.0)
    assert s.satisfied([150.0, 150.0, 150.0], 300.0)
    # without survive_one_loss only the headroom bar remains
    assert _scaler(survive=False).satisfied([400.0, 100.0], 200.0)


def test_decide_scale_up_ignores_cooldown():
    s = _scaler()
    s.record(0.0, "init")
    d = s.decide(0.5, [100.0], forecast=500.0, offered_now=50.0)
    assert d.kind == "up" and d.target_tokens_per_sec == 500.0


def test_decide_target_floors_at_offered_rate():
    """The in-flight floor: a forecast of zero can never size the fleet
    below live traffic."""
    s = _scaler()
    d = s.decide(10.0, [100.0, 100.0], forecast=0.0, offered_now=90.0)
    assert d.target_tokens_per_sec == 90.0
    assert d.kind != "up"  # 200 ≥ 90×1.25 and 200−100 ≥ 90


def test_decide_scale_down_needs_low_water_cooldown_and_min_replicas():
    pol = AutoscalePolicy(low_water=0.5, cooldown_hours=3.0, min_replicas=2)
    rates = [100.0, 100.0, 100.0, 100.0]
    # utilization 50×1.25/400 = 0.156 < 0.5, but cooldown armed at t=0
    s = _scaler(pol)
    s.record(0.0, "init")
    assert s.decide(2.0, rates, forecast=50.0, offered_now=50.0).kind == "hold"
    assert s.decide(3.0, rates, forecast=50.0, offered_now=50.0).kind == "down"
    # min_replicas floor wins even when utilization is low
    s2 = _scaler(pol)
    assert s2.decide(9.0, [100.0, 100.0], forecast=10.0, offered_now=10.0).kind == "hold"
    # above the low-water mark: hold
    s3 = _scaler(pol)
    assert s3.decide(9.0, rates, forecast=200.0, offered_now=200.0).kind == "hold"


def test_record_counts_events_and_ignores_hold():
    s = _scaler()
    s.record(0.0, "init")
    s.record(1.0, "hold")
    s.record(2.0, "up")
    s.record(6.0, "down")
    assert s.scale_ups == 1 and s.scale_downs == 1
    assert s.events == [(0.0, "init"), (2.0, "up"), (6.0, "down")]
    with pytest.raises(AssertionError):
        s.record(7.0, "sideways")


def test_autoscale_policy_validates():
    for bad in (
        dict(forecast_window_hours=0),
        dict(low_water=0.0),
        dict(low_water=1.0),
        dict(cooldown_hours=-1.0),
        dict(min_replicas=0),
    ):
        with pytest.raises(AssertionError):
            AutoscalePolicy(**bad)


# --- the property harness: scaler loop on random traces ---------------------

def _drive(scaler, trace, unit=50.0):
    """Run the scaler's own loop shape (provision-until-satisfied on up,
    guarded single retire on down) over an hourly trace; returns the
    capacity timeline as CapacityEvents plus the final replica rates."""
    rates = []
    target0 = max(scaler.forecast(trace, 0), trace[0] if len(trace) else 0.0)
    while not scaler.satisfied(rates, target0) or (
        len(rates) < scaler.policy.min_replicas
    ):
        rates.append(unit)
    scaler.record(0.0, "init")
    events = [CapacityEvent(0.0, sum(rates))]
    for h, offered in enumerate(trace):
        d = scaler.decide(
            float(h), rates, forecast=scaler.forecast(trace, h),
            offered_now=offered,
        )
        # the in-flight floor: the target never sizes below live traffic
        assert d.target_tokens_per_sec >= offered
        if d.kind == "up":
            while not scaler.satisfied(rates, d.target_tokens_per_sec):
                rates.append(unit)
            scaler.record(float(h), "up")
        elif d.kind == "down":
            trial = rates[:-1]
            if len(trial) >= scaler.policy.min_replicas and scaler.satisfied(
                trial, d.target_tokens_per_sec
            ):
                rates = trial
                scaler.record(float(h), "down")
        if events[-1].tokens_per_sec != sum(rates):
            events.append(CapacityEvent(float(h), sum(rates)))
        # capacity never drops below the in-flight floor after any event
        assert scaler.satisfied(rates, offered), (h, offered, rates)
    return events, rates


@given(
    trace=st.lists(st.floats(0.0, 400.0), min_size=1, max_size=24),
    window=st.integers(1, 4),
    cooldown=st.floats(0.0, 6.0),
)
@settings(max_examples=60, deadline=None)
def test_scaler_loop_token_conservation_on_random_traces(
    trace, window, cooldown
):
    """q0 + offered == served + shed + q_end across ARBITRARY scale
    sequences: whatever capacity timeline the scaler produces, the router
    neither invents nor loses tokens."""
    scaler = _scaler(
        AutoscalePolicy(forecast_window_hours=window, cooldown_hours=cooldown)
    )
    events, _ = _drive(scaler, trace)
    stats = route_trace(
        trace, events, max_delay_seconds=30.0, shed_delay_seconds=120.0
    )
    inflow = stats.offered_tokens  # q0 == 0
    outflow = stats.served_tokens + stats.shed_tokens + stats.q_end
    assert inflow == pytest.approx(outflow, rel=1e-9, abs=1e-6)
    assert stats.shed_tokens >= -1e-9 and stats.q_end >= -1e-9


@given(
    trace=st.lists(st.floats(0.0, 400.0), min_size=1, max_size=24),
    cooldown=st.floats(0.0, 8.0),
    min_replicas=st.integers(1, 3),
)
@settings(max_examples=60, deadline=None)
def test_scaler_capacity_never_below_inflight_floor(
    trace, cooldown, min_replicas
):
    """After every decision the surviving fleet still clears the OFFERED
    rate with full headroom and N−1 margin — scale-downs can never cut
    into live traffic (asserted inside _drive), and the replica count
    never falls below min_replicas."""
    scaler = _scaler(
        AutoscalePolicy(cooldown_hours=cooldown, min_replicas=min_replicas)
    )
    _, rates = _drive(scaler, trace)
    assert len(rates) >= min_replicas


@given(
    trace=st.lists(
        st.tuples(st.booleans(), st.floats(0.0, 400.0)),
        min_size=2,
        max_size=36,
    ),
    cooldown=st.sampled_from([0.0, 1.0, 3.0, 5.5]),
)
@settings(max_examples=60, deadline=None)
def test_scaler_cooldown_respected_on_random_traces(trace, cooldown):
    """No realized scale-DOWN lands within cooldown_hours of the previous
    scale event (up, down, or init); scale-ups are exempt."""
    # spike the trace so both directions actually fire
    offered = [v if calm else v + 600.0 for calm, v in trace]
    scaler = _scaler(AutoscalePolicy(cooldown_hours=cooldown, low_water=0.7))
    _drive(scaler, offered, unit=150.0)
    for (t_prev, _), (t, kind) in zip(scaler.events, scaler.events[1:]):
        if kind == "down":
            assert t - t_prev >= cooldown, scaler.events


# --- end-to-end: FleetSimulator(sizing="auto") ------------------------------

def _hand_markets():
    """Six calm 4-device markets in distinct regions; in the future
    window B revokes at hour 30 — the surprise the auto fleet must
    absorb mid-trace. Six (not four) so scale-up has spare diversity."""
    regions = [
        "us-east-1", "eu-west-1", "ap-southeast-1",
        "eu-central-1", "us-west-2", "sa-east-1",
    ]
    mk = [
        Market(i, f"g4.{chr(97 + i)}", r, f"{r}a", 10, 1.0,
               device_count=4, interconnect_gbps=25.0)
        for i, r in enumerate(regions)
    ]
    H = 24 * 90
    hp = np.full((len(mk), H), 0.35)
    F = 48
    fp = np.full((len(mk), F), 0.35)
    fp[1, 30:32] = 1.5  # B revokes at future hour 30
    return MarketSet(mk, hp), MarketSet(mk, fp, start_hour=H)


def _workload():
    gib = 1 << 30
    return ServingWorkload(
        target_tokens_per_sec=500.0,
        replica_tokens_per_sec=100.0,
        state_gb=30.0,
        param_bytes=int(0.12 * gib),
        cache_bytes=int(0.03 * gib),
        inflight_context_tokens=2048.0,
    )


def _diurnal(hours):
    t = np.arange(hours, dtype=float)
    rate = 300.0 - 200.0 * np.cos(2 * math.pi * ((t % 24) / 24.0))
    rate[0] = 0.0
    return rate


@pytest.fixture(scope="module")
def auto_run():
    hist, fut = _hand_markets()
    wl = _workload()
    policy = ServePolicy(
        slo_horizon_hours=24.0, capacity_headroom=1.25, cache_policy="drop"
    )
    rate = _diurnal(48)
    static = FleetSimulator(hist, fut, wl, policy).run(48.0, rate)
    auto = FleetSimulator(hist, fut, wl, policy, sizing="auto").run(48.0, rate)
    return static, auto, rate


def test_auto_sizing_cheaper_than_static_at_zero_violation(auto_run):
    static, auto, _ = auto_run
    assert auto.slo_violation_seconds == 0.0
    assert auto.cost_dollars < static.cost_dollars
    assert auto.idle_headroom_tokens < static.idle_headroom_tokens


def test_auto_sizing_conserves_tokens_and_scales_both_ways(auto_run):
    _, auto, _ = auto_run
    r = auto.router
    assert r.offered_tokens == pytest.approx(
        r.served_tokens + r.shed_tokens + r.q_end, rel=1e-9, abs=1e-3
    )
    assert auto.scale_ups > 0 and auto.scale_downs > 0
    assert auto.replicas_provisioned > 0
    assert auto.p99_delay_seconds <= 30.0  # zero violation ⇒ p99 within SLO


def test_auto_sizing_is_deterministic(auto_run):
    _, auto, rate = auto_run
    hist, fut = _hand_markets()
    again = FleetSimulator(
        hist, fut, _workload(),
        ServePolicy(
            slo_horizon_hours=24.0, capacity_headroom=1.25, cache_policy="drop"
        ),
        sizing="auto",
    ).run(48.0, rate)
    assert again.cost_dollars == auto.cost_dollars
    assert again.router.served_tokens == auto.router.served_tokens
    assert again.scale_ups == auto.scale_ups
    assert again.scale_downs == auto.scale_downs


def test_auto_sizing_survives_revocation(auto_run):
    """us-b revokes at future hour 30: the auto fleet repairs (or proves
    the survivors already clear the bar) and still ends at zero
    violation-seconds."""
    _, auto, _ = auto_run
    assert auto.revocations >= 1


def test_auto_requires_fleet_mode():
    hist, fut = _hand_markets()
    with pytest.raises(ValueError):
        FleetSimulator(
            hist, fut, _workload(), ServePolicy(), mode="static", sizing="auto"
        )
    with pytest.raises(AssertionError):
        FleetSimulator(
            hist, fut, _workload(), ServePolicy(), sizing="bogus"
        )


def test_idle_headroom_integral_hand_computed():
    """2 hours at capacity 100 against offered [40, 120]: headroom is
    60 tok/s for the first hour, 0 for the second."""
    got = idle_headroom_tokens([40.0, 120.0], [CapacityEvent(0.0, 100.0)])
    assert got == pytest.approx(60.0 * 3600.0)
    # a capacity step mid-trace splits the integral at the event time
    got2 = idle_headroom_tokens(
        [40.0, 40.0], [CapacityEvent(0.0, 100.0), CapacityEvent(1.5, 40.0)]
    )
    assert got2 == pytest.approx(60.0 * 1.5 * 3600.0)
