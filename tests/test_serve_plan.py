"""launch/serve.py --plan: the real revocation→migration→serve round trip
on an 8-device pool, plus the bit-exact single-replica/no-revocation
equivalence between the plan path and the legacy host-mesh path.

Two subprocesses:

* 8 forced host devices — three serves end to end: an uninterrupted
  plan-8 reference, plan 8→4 with a revocation after 3 tokens and the
  cache dropped + re-prefilled, and the same with the cache migrated
  over the DCN. Asserted: both round trips complete, move params-only
  bytes strictly below the training path's restore, and decode the SAME
  greedy tokens as the uninterrupted reference — the migration is
  invisible in the output stream.
* 1 device — the legacy host-mesh path (today's serve.py, untouched
  code) against plan mode with a single 1-device replica: identical
  meshes, so the token streams must match BIT-EXACTLY (different mesh
  *shapes* are allowed to differ in low-order float bits, which is why
  this equivalence is pinned on the same shape).
"""
import os
import pathlib
import subprocess
import sys
import textwrap

COMMON = textwrap.dedent(
    """
    import contextlib, io, json, sys
    from repro.launch import serve

    def run(argv):
        out = io.StringIO()
        sys.argv = ["serve"] + argv
        with contextlib.redirect_stdout(out):
            serve.main()
        return out.getvalue()

    def plan_json(text):
        for line in text.splitlines():
            if line.startswith("PLAN_JSON "):
                return json.loads(line[len("PLAN_JSON "):])
        raise AssertionError(text)

    def first_row(text):
        for line in text.splitlines():
            if line.startswith("first row: "):
                return json.loads(line[len("first row: "):])
        raise AssertionError(text)

    # batch 4: the KV cache actually shards over the data axis on both
    # mesh shapes, so the migrate policy has real cache bytes to move
    base = ["--arch", "qwen3-4b", "--batch", "4",
            "--prompt-len", "16", "--new-tokens", "8"]
    """
)

MIGRATION_SCRIPT = (
    'import os\nos.environ["XLA_FLAGS"] = '
    '"--xla_force_host_platform_device_count=8"\n'
    + COMMON
    + textwrap.dedent(
        """
        ref = plan_json(run(base + ["--plan", "8"]))
        drop = plan_json(run(base + ["--plan", "8,4", "--revoke-after", "3",
                                     "--cache-policy", "drop"]))
        mig = plan_json(run(base + ["--plan", "8,4", "--revoke-after", "3",
                                    "--cache-policy", "migrate"]))

        assert ref["params_bytes"] == 0 and ref["migrated_at"] is None

        # the round trip ran: params-only bytes moved, strictly below the
        # training path (params + Adam moments never move for serving);
        # everything decoded BEFORE the migration is bit-identical to the
        # uninterrupted run (it is the same computation), the continuation
        # is a full-length greedy stream on the new mesh (a different mesh
        # shape may flip low-order bf16 bits, so only the prefix is pinned
        # at batch 4 — see the batch-2 run below for full-stream equality)
        for name, r in (("drop", drop), ("migrate", mig)):
            assert r["migrated_at"] == 3, r
            assert 0 < r["params_bytes"] < r["train_path_bytes"], r
            pre = [row[:4] for row in ref["tokens"]]
            assert [row[:4] for row in r["tokens"]] == pre, (name, r["tokens"])
            assert all(len(row) == len(ref["tokens"][0]) for row in r["tokens"])
        # drop rebuilt the cache by re-prefill (no cache bytes on the
        # wire); migrate paid for the cache it moved
        assert drop["cache_bytes"] == 0
        assert mig["cache_bytes"] > 0
        # both runs measured real decode rates on both mesh shapes
        assert set(drop["measured_steps_per_sec"]) == {"4x2", "2x2"}, drop

        # batch 2: the cache layout coincides across the two mesh shapes,
        # so the whole migrated stream must be indistinguishable from the
        # uninterrupted reference — the migration is invisible end to end
        b2 = [a if a != "4" else "2" for a in base]
        ref2 = plan_json(run(b2 + ["--plan", "8"]))
        drop2 = plan_json(run(b2 + ["--plan", "8,4", "--revoke-after", "3",
                                    "--cache-policy", "drop"]))
        assert drop2["tokens"] == ref2["tokens"], (drop2["tokens"],
                                                   ref2["tokens"])
        print("PLAN_MIGRATION_OK", drop["params_bytes"],
              drop["train_path_bytes"], mig["cache_bytes"])
        """
    )
)

EQUIV_SCRIPT = (
    'import os\nos.environ["XLA_FLAGS"] = '
    '"--xla_force_host_platform_device_count=1"\n'
    + COMMON
    + textwrap.dedent(
        """
        legacy = first_row(run(base))
        ref = plan_json(run(base + ["--plan", "1"]))
        # single-replica / no-revocation: plan mode decodes EXACTLY what
        # the (untouched) legacy host-mesh path decodes
        assert ref["tokens"][0] == legacy, (ref["tokens"][0], legacy)
        assert ref["tokens"] == plan_json(run(base + ["--plan", "1"]))["tokens"]
        print("PLAN_EQUIV_OK", legacy)
        """
    )
)


def _run(script):
    repo = pathlib.Path(__file__).resolve().parents[1]
    return subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=560,
        env={**os.environ, "PYTHONPATH": str(repo / "src")},
        cwd=str(repo),
    )


def test_serve_plan_migration_subprocess():
    res = _run(MIGRATION_SCRIPT)
    assert "PLAN_MIGRATION_OK" in res.stdout, res.stdout + res.stderr


def test_serve_plan_single_replica_bit_exact_equivalence():
    res = _run(EQUIV_SCRIPT)
    assert "PLAN_EQUIV_OK" in res.stdout, res.stdout + res.stderr


ENGINE_SCRIPT = (
    'import os\nos.environ["XLA_FLAGS"] = '
    '"--xla_force_host_platform_device_count=8"\n'
    + COMMON
    + textwrap.dedent(
        """
        ref = plan_json(run(base + ["--plan", "8", "--engine"]))
        rt = plan_json(run(base + ["--plan", "8,4", "--revoke-after", "3",
                                   "--engine"]))

        assert ref["engine"] is True and rt["engine"] is True

        # the continuous-batching engine re-prefills prompt + committed
        # tokens after the shed, so the WHOLE stream — not just the
        # pre-revocation prefix — is bit-identical to the uninterrupted
        # run, even across the 4x2 -> 2x2 mesh change
        assert rt["tokens"] == ref["tokens"], (rt["tokens"], ref["tokens"])
        assert rt["migrated_at"] == 3, rt
        assert 0 < rt["params_bytes"] < rt["train_path_bytes"], rt
        assert rt["cache_bytes"] == 0  # pages die with the instance
        # real decode timings measured on both mesh shapes
        assert set(rt["measured_steps_per_sec"]) == {"4x2", "2x2"}, rt
        assert all(v > 0 for v in rt["measured_steps_per_sec"].values())
        assert rt["engine_tokens_per_sec"] > 0
        print("PLAN_ENGINE_OK", rt["engine_tokens_per_sec"])
        """
    )
)


def test_serve_plan_engine_round_trip_token_identical():
    res = _run(ENGINE_SCRIPT)
    assert "PLAN_ENGINE_OK" in res.stdout, res.stdout + res.stderr
