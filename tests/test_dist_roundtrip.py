"""Fast single-process coverage for repro.dist beyond the seed tests:
a param_shardings -> device_put -> reshard_tree round-trip on the host
mesh (values must survive any re-layout bit-exactly), plus rule-table /
constrainer properties that need no multi-device subprocess."""
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
import numpy as np

from repro.config import ShardingLayout, get_arch
from repro.dist import (
    PARAM_RULES,
    batch_shardings,
    cache_shardings,
    make_activation_constrainer,
    opt_state_shardings,
    param_shardings,
    replicate,
    reshard_params,
    resolve_pspec,
)
from repro.models import build_model
from repro.models.common import ParamSpec


def fake_mesh(shape, axes):
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


def spec_axes(spec):
    """Flatten a PartitionSpec into the mesh axis names it uses."""
    return [
        a
        for part in spec
        for a in ((part,) if isinstance(part, str) else (part or ()))
    ]


def test_param_roundtrip_values_unchanged(host_mesh):
    """device_put under param shardings then reshard to a different spec:
    every leaf must come back bit-identical."""
    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    layout = ShardingLayout()
    params = model.init(jax.random.key(0))
    ref = jax.tree_util.tree_map(np.asarray, params)

    sharded = jax.device_put(params, param_shardings(model.specs, host_mesh, layout))
    # a different spec on the same devices — the elastic no-op case
    back = replicate(sharded, host_mesh)
    rere = reshard_params(back, model.specs, host_mesh, layout)
    for a, b in zip(
        jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(rere)
    ):
        np.testing.assert_array_equal(a, np.asarray(b))
    for leaf in jax.tree_util.tree_leaves(back):
        assert leaf.sharding.spec == P()


def test_opt_rules_override():
    mesh = fake_mesh((16, 16), ("data", "model"))
    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    zero1 = ShardingLayout(param_rules="tp_only", opt_rules="baseline")
    p_sh = jax.tree_util.tree_leaves(param_shardings(model.specs, mesh, zero1))
    o_sh = jax.tree_util.tree_leaves(opt_state_shardings(model.specs, mesh, zero1))
    # tp_only params never touch the data axis; baseline moments do
    assert all("data" not in spec_axes(s.spec) for s in p_sh)
    assert any("data" in spec_axes(s.spec) for s in o_sh)


def test_all_rule_sets_resolve_all_archs():
    """Every PARAM_RULES preset must resolve every arch divisibly."""
    mesh = fake_mesh((16, 16), ("data", "model"))
    sizes = dict(mesh.shape)
    for rules_name in PARAM_RULES:
        for arch in ("qwen3-4b", "mixtral-8x7b", "internvl2-26b"):
            model = build_model(get_arch(arch))
            sh = param_shardings(model.specs, mesh, rules_name)
            for spec, s in zip(
                jax.tree_util.tree_leaves(
                    model.specs, is_leaf=lambda x: isinstance(x, ParamSpec)
                ),
                jax.tree_util.tree_leaves(sh),
            ):
                parts = list(s.spec) + [None] * (len(spec.shape) - len(s.spec))
                for dim, part in zip(spec.shape, parts):
                    axes = (part,) if isinstance(part, str) else (part or ())
                    k = 1
                    for a in axes:
                        k *= sizes[a]
                    assert dim % k == 0, (rules_name, arch, spec.shape, s.spec)


def test_cache_shardings_shard_slot_dim_over_model():
    mesh = fake_mesh((16, 16), ("data", "model"))
    cfg = get_arch("qwen3-4b")
    model = build_model(cfg)
    c_specs = model.cache_specs(batch=32, seq_len=4096)
    sh = cache_shardings(c_specs, mesh, ShardingLayout())
    k_sh = sh["blocks"]["k"]
    # (layers, batch, slots, kv_heads, hd): scan dim unsharded, slots on model
    assert k_sh.spec[0] is None
    assert "model" in spec_axes(k_sh.spec)


def test_batch_shardings_indivisible_batch_replicates():
    mesh = fake_mesh((16, 16), ("data", "model"))
    x = jax.ShapeDtypeStruct((6, 128), np.int32)  # 6 % 16 != 0
    assert batch_shardings({"tokens": x}, mesh)["tokens"].spec == P(None, None)


def test_constrainer_is_identity_on_host_mesh(host_mesh):
    cfg = get_arch("qwen3-4b").reduced()
    constrain = make_activation_constrainer(host_mesh, ShardingLayout(), cfg)
    x = jnp.ones((2, 8, cfg.d_model))
    y = constrain(x, "activation")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    # unknown names pass through untouched
    assert constrain(x, "not_a_site") is x


def test_resolve_pspec_never_reuses_axis_across_dims():
    mesh = fake_mesh((16, 16), ("data", "model"))
    rules = PARAM_RULES["fsdp_heavy"]
    spec = resolve_pspec((4096, 14336), ("embed", "ffn"), rules, mesh)
    flat = spec_axes(spec)
    assert len(flat) == len(set(flat))
