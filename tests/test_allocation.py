"""Multi-leg allocations (ISSUE 4): the Allocation type, the DCN-discounted
combined-throughput model, the split search, allocation-aware Algorithm-1
restriction after a leg revocation, and the per-leg accounting invariants.

The legacy-equivalence contract is pinned hard here: a single-leg
allocation must reproduce the PR 3 (pre-allocation) simulator BIT-EXACTLY
— the expected floats below were captured by running the PR 3 code and are
compared with ``==``, not approx."""
import numpy as np
import pytest

from repro.core import (
    Allocation,
    DCN_BANDWIDTH_GBPS,
    Job,
    Leg,
    Simulator,
    SiwoftPolicy,
    combined_throughput,
    generate_markets,
    shape_throughput,
    split_history_future,
)
from repro.core import provisioner as alg
from repro.core.accounting import Breakdown, Session, bill_session
from repro.core.provisioner import MarketFeatures


# --- the Allocation type ----------------------------------------------------

def test_allocation_structure():
    a = Allocation.of([3, 7], [8, 8])
    assert a.is_split and len(a) == 2
    assert a.markets == (3, 7) and a.device_counts == (8, 8)
    assert a.total_devices == 16
    assert a.touches(3) and not a.touches(5)
    s = Allocation.single(4, 2)
    assert not s.is_split and s.markets == (4,)
    with pytest.raises(AssertionError):
        Allocation.of([3, 3], [8, 8])  # one spot request per market


def test_replace_leg_is_the_repair_primitive():
    a = Allocation.of([3, 7], [8, 4])
    r = a.replace_leg(7, Leg(9, 4))
    assert r.markets == (3, 9) and r.device_counts == (8, 4)
    assert a.surviving(7) == (Leg(3, 8),)


def test_allocations_are_hashable_dict_keys():
    d = {Allocation.of([1, 2], [4, 4]): "x", Allocation.single(1, 4): "y"}
    assert d[Allocation.of([1, 2], [4, 4])] == "x"


# --- combined throughput: the DCN discount ----------------------------------

def test_single_leg_throughput_is_the_single_market_physics():
    for n, bw in [(1, 10.0), (4, 25.0), (8, 60.0)]:
        assert combined_throughput([n], [bw]) == shape_throughput(n, bw)


def test_split_never_beats_same_devices_on_one_interconnect():
    """The tentpole's honesty clause: 4+4 over DCN < 8 behind either leg's
    own fabric — the effective bandwidth is min(DCN, slowest leg egress)."""
    for bws in ([25.0, 25.0], [60.0, 25.0], [10.0, 50.0]):
        split = combined_throughput([4, 4], bws)
        assert split < shape_throughput(8, min(bws))
        assert split == shape_throughput(8, min(DCN_BANDWIDTH_GBPS, min(bws)))


def test_split_value_depends_on_leg_fabric():
    """Moderate-fabric legs: doubling devices beats one leg alone even
    through the DCN discount — what makes a split worth pricing. But two
    FAST boxes (60 GB/s) coupled over a 2.5 GB/s DCN do NOT beat one such
    box: the discount is honest physics, not a knob, and the model cannot
    be gamed into federating its way past a tight interconnect."""
    assert combined_throughput([4, 4], [25.0, 25.0]) > shape_throughput(4, 25.0)
    assert combined_throughput([8, 8], [60.0, 60.0]) < shape_throughput(8, 60.0)


def test_split_throughput_sublinear_and_monotone_in_dcn():
    t2 = combined_throughput([4, 4], [25.0, 25.0], dcn_gbps=2.5)
    t2_fast = combined_throughput([4, 4], [25.0, 25.0], dcn_gbps=10.0)
    assert t2 < 2 * shape_throughput(4, 2.5)
    assert t2_fast > t2


# --- allocation-level features ----------------------------------------------

def _feats(seed=0):
    ms = generate_markets(seed=seed, n_hours=24 * 90)
    return MarketFeatures.from_history(ms)


def test_allocation_mttr_is_min_over_legs():
    feats = _feats()
    i, j = 0, 7
    a = Allocation.of([i, j], [1, 1])
    assert alg.allocation_mttr(a, feats) == min(
        float(feats.mttr[i]), float(feats.mttr[j])
    )


def test_single_leg_delegates_to_market_functions_exactly():
    feats = _feats()
    for m in (0, 5, 17):
        a = Allocation.single(m, int(feats.device_count[m]))
        assert alg.allocation_throughput(a, feats) == float(feats.throughput[m])
        assert alg.allocation_expected_cost_to_complete(
            24.0, feats, a
        ) == alg.expected_cost_to_complete(24.0, feats, m)
        assert alg.allocation_wall_hours(24.0, feats, a) == alg.wall_hours(
            24.0, feats, m
        )


def test_admission_is_strictly_harder_for_wider_splits():
    """min-MTTR composition: adding a leg can only lower the allocation's
    lifetime, never raise it."""
    feats = _feats()
    order = np.argsort(feats.mttr)
    weak, strong = int(order[0]), int(order[-1])
    single = Allocation.single(strong, 1)
    split = Allocation.of([strong, weak], [1, 1])
    assert alg.allocation_mttr(split, feats) <= alg.allocation_mttr(single, feats)
    assert alg.allocation_mttr(split, feats) == float(feats.mttr[weak])


# --- the split search -------------------------------------------------------

def test_fitting_job_yields_singles_only_and_preserves_order():
    """When any single shape fits and split_margin is off, the candidate set
    is find_suitable_servers one-for-one — the bit-exactness precondition."""
    feats = _feats()
    job = Job(24, 16)
    allocs = alg.find_suitable_allocations(job, feats, SiwoftPolicy())
    assert all(not a.is_split for a in allocs)
    assert [a.markets[0] for a in allocs] == alg.find_suitable_servers(job, feats)


def test_oversized_job_splits_when_no_single_shape_fits():
    feats = _feats()
    job = Job(24, 400.0)  # menu max total is 320 GB
    assert alg.find_suitable_servers(job, feats) == []
    allocs = alg.find_suitable_allocations(job, feats, SiwoftPolicy())
    assert allocs and all(a.is_split for a in allocs)
    for a in allocs[:20]:
        assert alg.allocation_memory_gb(a, feats) >= job.memory_gb
        assert len(a) <= SiwoftPolicy().max_legs
        # legs pass the policy's correlation cut against each other
        for x in a.markets:
            for y in a.markets:
                if x != y:
                    assert feats.corr[x, y] < SiwoftPolicy().correlation_threshold
    # ranked by expected cost-to-complete
    eccs = [
        alg.allocation_expected_cost_to_complete(job.length_hours, feats, a)
        for a in allocs
    ]
    assert eccs == sorted(eccs)


def test_split_margin_enables_opportunistic_splits():
    """With a margin set, a split that beats the best single shape by the
    margin joins the candidate set even though singles exist — and with
    the margin at its default (None) it must NOT."""
    feats = _feats()
    job = Job(24, 16)
    default = alg.find_suitable_allocations(job, feats, SiwoftPolicy())
    assert all(not a.is_split for a in default)
    opportunistic = alg.find_suitable_allocations(
        job, feats, SiwoftPolicy(split_margin=0.0)
    )
    assert len(opportunistic) >= len(default)
    # any split that made it in genuinely beats the best single on ecc
    best_single = min(
        alg.allocation_expected_cost_to_complete(job.length_hours, feats, a)
        for a in default
    )
    for a in opportunistic:
        if a.is_split:
            assert (
                alg.allocation_expected_cost_to_complete(job.length_hours, feats, a)
                < best_single
            )


# --- allocation-aware step 13/14 (satellite: two-leg regression) ------------

def test_find_low_correlation_excludes_markets_correlated_with_survivors():
    """THE two-leg regression: a replacement must be low-correlated with
    the revoked market AND with every surviving leg."""
    n = 4
    corr = np.zeros((n, n))
    np.fill_diagonal(corr, 1.0)
    corr[1, 2] = corr[2, 1] = 0.9   # candidate 2 co-revokes with survivor 1
    feats = MarketFeatures(
        mttr=np.full(n, 100.0),
        corr=corr,
        memory_gb=np.full(n, 64.0),
        on_demand=np.full(n, 1.0),
        avg_price=np.full(n, 0.3),
    )
    policy = SiwoftPolicy()
    # single-market call (no survivors): 2 is perfectly fine
    assert 2 in alg.find_low_correlation(feats, 0, policy)
    # allocation (0, 1) loses leg 0; survivor 1 vetoes market 2
    W = alg.find_low_correlation(feats, 0, policy, surviving=(1,))
    assert 2 not in W
    assert 3 in W
    assert 0 not in W  # self-correlation 1: the revoked market is never in W


def test_restrict_after_revocation_two_leg_case():
    """Allocations touching the revoked market drop; the repair allocation
    (surviving leg + replacement from W) stays eligible even though a
    surviving leg is trivially self-correlated (not in W)."""
    n = 4
    corr = np.zeros((n, n))
    np.fill_diagonal(corr, 1.0)
    feats = MarketFeatures(
        mttr=np.array([100.0, 90.0, 80.0, 70.0]),
        corr=corr,
        memory_gb=np.full(n, 64.0),
        on_demand=np.full(n, 1.0),
        avg_price=np.array([0.3, 0.3, 0.3, 0.3]),
    )
    policy = SiwoftPolicy()
    a01 = Allocation.of([0, 1], [1, 1])
    a02 = Allocation.of([0, 2], [1, 1])   # touches revoked market 0 -> drops
    a12 = Allocation.of([1, 2], [1, 1])   # the repair: survivor 1 + fresh 2
    a23 = Allocation.of([2, 3], [1, 1])
    S = [a01, a02, a12, a23]
    lifetimes = alg.compute_allocation_lifetimes(feats, S)
    # leg 0 of a01 revoked; survivor is market 1
    W = alg.find_low_correlation(feats, 0, policy, surviving=(1,))
    S2 = alg.restrict_after_revocation(
        S, a01, W, lifetimes, {0}, feats, job=Job(10, 64), surviving=(1,)
    )
    assert a01 not in S2
    assert a02 not in S2            # contains the revoked market
    assert a12 in S2 and a23 in S2  # repair stays eligible
    lts = [lifetimes[a] for a in S2]
    assert lts == sorted(lts, reverse=True)


def test_restrict_after_revocation_int_path_unchanged():
    """The pre-allocation int signature still works (FT baselines, legacy
    callers) — regression guard for the generalization."""
    feats = _feats()
    job = Job(24, 16)
    policy = SiwoftPolicy()
    suitable = alg.find_suitable_servers(job, feats)
    lifetimes = alg.compute_lifetime(feats, suitable)
    S = alg.server_based_lifetime(job, lifetimes, policy, feats)
    s = alg.highest(S)
    W = alg.find_low_correlation(feats, s, policy)
    S2 = alg.restrict_after_revocation(S, s, W, lifetimes, {s}, feats)
    assert s not in S2
    assert all(isinstance(i, (int, np.integer)) for i in S2)


# --- per-leg accounting invariants (satellite) ------------------------------

def test_multi_leg_session_bills_each_leg_at_its_own_price():
    prices = {0: 2.0, 1: 3.0}
    s = Session(market_id=0, start_wall=0.0, legs=(0, 1))
    s.add("execution", 0.5)
    bd = Breakdown()
    bill_session(s, lambda m, h: prices[m], bd)
    assert bd.time["execution"] == pytest.approx(0.5)          # wall, not leg-hours
    assert bd.cost["execution"] == pytest.approx(0.5 * (2 + 3))
    # whole-hour billing per leg: each leg pays its own 0.5 h buffer
    assert bd.cost["billing_buffer"] == pytest.approx(0.5 * (2 + 3))
    assert bd.leg_cost[0] == pytest.approx(1.0 + 1.0)
    assert bd.leg_cost[1] == pytest.approx(1.5 + 1.5)
    assert sum(bd.leg_cost.values()) == pytest.approx(bd.total_cost)


def test_leg_costs_sum_to_total_across_policies():
    ms = generate_markets(seed=0, n_hours=24 * 90 + 24 * 45)
    hist, fut = split_history_future(ms, 24 * 90)
    sim = Simulator(hist, fut, seed=0)
    for job in (Job(24, 16), Job(24, 400.0)):  # single-leg and forced split
        bd = sim.run_job(job, SiwoftPolicy())
        assert sum(bd.leg_cost.values()) == pytest.approx(bd.total_cost, rel=1e-12)
        assert all(v > 0 for v in bd.leg_cost.values())


def test_breakdown_add_merges_leg_costs():
    a, b = Breakdown(), Breakdown()
    a.add_leg_cost(3, 1.0)
    b.add_leg_cost(3, 0.5)
    b.add_leg_cost(4, 2.0)
    a.add(b)
    assert a.leg_cost == {3: 1.5, 4: 2.0}


# --- legacy equivalence: PR 3 reports, bit-exact ----------------------------

# Captured by running the PR 3 (pre-allocation) simulator: seed 0,
# Job(24 h, 16 GB), siwoft, default menu and the paper's legacy menu.
# Compared with ==: the allocation refactor must not perturb one ulp.
_PR3_DEFAULT = {
    "time_execution": 7.386866480069499,
    "time_startup": 0.041666666666666664,
    "cost_execution": 2.4221125778785235,
    "cost_startup": 0.013225300146947977,
    "cost_billing_buffer": 0.18725739719018386,
    "wall": 7.428533146736166,
}
_PR3_LEGACY = {
    "time_execution": 24.000000000000004,
    "time_startup": 0.041666666666666664,
    "cost_execution": 2.7858337891732825,
    "cost_startup": 0.0052006380675345566,
    "cost_billing_buffer": 0.10908661717119851,
    "wall": 24.04166666666667,
}


@pytest.mark.parametrize(
    "menu_kw,expect",
    [({}, _PR3_DEFAULT), ({"legacy": True}, _PR3_LEGACY)],
    ids=["default_menu", "legacy_menu"],
)
def test_single_leg_reproduces_pr3_report_bit_exactly(menu_kw, expect):
    from repro.core import legacy_menu

    kw = {"menu": legacy_menu()} if menu_kw else {}
    ms = generate_markets(seed=0, n_hours=24 * 90 + 24 * 45, **kw)
    hist, fut = split_history_future(ms, 24 * 90)
    bd = Simulator(hist, fut, seed=0).run_job(Job(24, 16), SiwoftPolicy())
    assert bd.time["execution"] == expect["time_execution"]
    assert bd.time["startup"] == expect["time_startup"]
    assert bd.cost["execution"] == expect["cost_execution"]
    assert bd.cost["startup"] == expect["cost_startup"]
    assert bd.cost["billing_buffer"] == expect["cost_billing_buffer"]
    assert bd.wall_time == expect["wall"]
    assert bd.revocations == 0 and bd.sessions == 1
    # every other component identically zero, like PR 3
    for k, v in bd.time.items():
        if k not in ("execution", "startup"):
            assert v == 0.0, k
    # and the per-leg breakdown (new) still sums to the same total
    assert sum(bd.leg_cost.values()) == pytest.approx(bd.total_cost, rel=1e-12)


# --- end-to-end: the simulator completes an unfittable job ------------------

def test_simulator_completes_oversized_job_via_split():
    """The paper's hard wall removed: a 400 GB job (no single shape fits)
    completes under pure no-FT siwoft as a 2-leg allocation, billed sanely."""
    ms = generate_markets(seed=0, n_hours=24 * 90 + 24 * 45)
    hist, fut = split_history_future(ms, 24 * 90)
    sim = Simulator(hist, fut, seed=0)
    job = Job(24, 400.0)
    bd = sim.run_job(job, SiwoftPolicy())
    assert bd.time["execution"] > 0
    assert bd.total_cost > 0
    assert len(bd.leg_cost) >= 2            # at least two legs billed
    assert bd.time["checkpointing"] == 0.0  # still no FT mechanism
    assert bd.time["recovery"] == 0.0
    # combined throughput: the split finishes faster than the reference
    # 1-device wall time but slower than a hypothetical unified 16-dev mesh
    assert bd.time["execution"] < 24.0


def test_simulator_raises_when_nothing_fits():
    ms = generate_markets(seed=0, n_hours=24 * 90 + 24 * 45)
    hist, fut = split_history_future(ms, 24 * 90)
    sim = Simulator(hist, fut, seed=0)
    with pytest.raises(ValueError, match="fits no allocation"):
        sim.run_job(Job(24, 10_000.0), SiwoftPolicy())  # > 2 x 320 GB


# --- 3-leg splits behind max_legs=3 + the pairwise correlation budget -------

def _three_leg_feats(corr_pairs=()):
    """Five 40 GB-total markets (8 dev × 5 GB): no single shape and no PAIR
    fits a 100 GB job — only triples do. ``corr_pairs`` lists (i, j) whose
    co-revocation is pushed above any reasonable budget."""
    n = 5
    corr = np.eye(n)
    for i, j in corr_pairs:
        corr[i, j] = corr[j, i] = 0.9
    return MarketFeatures(
        mttr=np.full(n, 400.0),
        corr=corr,
        memory_gb=np.full(n, 5.0),
        on_demand=np.full(n, 1.0),
        avg_price=np.full(n, 0.3),
        device_count=np.full(n, 8.0),
        interconnect_gbps=np.full(n, 50.0),
        throughput=np.array([shape_throughput(8, 50.0)] * n),
    )


def test_three_leg_split_gated_behind_max_legs():
    """max_legs=2 (the default) cannot provision the triple-only job; the
    SAME features open up behind max_legs=3 — and every admitted split has
    exactly 3 legs (a fitting split never grows extra legs)."""
    feats = _three_leg_feats()
    job = Job(24.0, 100.0)
    assert alg.find_suitable_allocations(job, feats, SiwoftPolicy()) == []
    allocs = alg.find_suitable_allocations(job, feats, SiwoftPolicy(max_legs=3))
    assert allocs and all(len(a) == 3 for a in allocs)


def test_three_leg_pairwise_correlation_budget():
    """A 3-leg candidate is admitted only when ALL THREE pairs co-revoke
    below the budget: markets 1–3 are correlated, so every admitted triple
    avoids holding both."""
    feats = _three_leg_feats(corr_pairs=[(1, 3)])
    job = Job(24.0, 100.0)
    policy = SiwoftPolicy(max_legs=3)  # budget defaults to the 0.2 threshold
    allocs = alg.find_suitable_allocations(job, feats, policy)
    assert allocs
    for a in allocs:
        assert not ({1, 3} <= set(a.markets)), a.markets
        for x in a.markets:
            for y in a.markets:
                if x != y:
                    assert feats.corr[x, y] < policy.split_corr_cut


def test_split_correlation_budget_independent_of_step13_threshold():
    """The split budget is its own knob: a loose step-13 threshold (0.95)
    with a tight split budget still refuses the correlated pair — and
    vice versa a loose budget admits it."""
    feats = _three_leg_feats(corr_pairs=[(1, 3)])
    job = Job(24.0, 100.0)
    tight = SiwoftPolicy(
        max_legs=3, correlation_threshold=0.95, split_correlation_budget=0.2
    )
    for a in alg.find_suitable_allocations(job, feats, tight):
        assert not ({1, 3} <= set(a.markets)), a.markets
    loose = SiwoftPolicy(
        max_legs=3, correlation_threshold=0.2, split_correlation_budget=0.95
    )
    assert any(
        {1, 3} <= set(a.markets)
        for a in alg.find_suitable_allocations(job, feats, loose)
    )


def test_three_leg_mttr_composes_as_min():
    """Admission stays honest at 3 legs: the allocation's lifetime is the
    MIN over its legs, so one weak leg disqualifies the whole triple."""
    feats = _three_leg_feats()
    feats.mttr[2] = 4.0  # weak leg: below 2 x the ~2.9 h wall on a triple
    job = Job(24.0, 100.0)
    policy = SiwoftPolicy(max_legs=3)
    allocs = alg.find_suitable_allocations(job, feats, policy)
    lifetimes = alg.compute_allocation_lifetimes(feats, allocs)
    for a, lt in lifetimes.items():
        assert lt == min(feats.mttr[m] for m in a.markets)
    # Alg.-1 admission (MTTR >= 2 x wall on the shape) rejects every triple
    # holding the weak leg; the survivors only draw from {0, 1, 3, 4}
    S = alg.server_based_lifetime(job, lifetimes, policy, feats)
    admitted = [
        a for a in S
        if lifetimes[a] >= policy.lifetime_factor
        * alg.allocation_wall_hours(job.length_hours, feats, a)
    ]
    assert admitted and all(2 not in a.markets for a in admitted)
