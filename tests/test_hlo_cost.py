"""Trip-count-aware HLO cost walker: validated against programs with known
FLOP counts (the measurement backbone of the roofline analysis)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo, parse_module


def _cost(f, *args):
    return analyze_hlo(jax.jit(f).lower(*args).compile().as_text())


def test_single_matmul():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    r = _cost(lambda a, b: a @ b, x, x)
    assert r["flops"] == pytest.approx(2 * 256**3, rel=0.01)


def test_scan_multiplies_by_trip_count():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f(a, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, a, None, length=7)
        return y

    r = _cost(f, x, x)
    assert r["flops"] == pytest.approx(7 * 2 * 256**3, rel=0.02)


def test_nested_scan():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(a, w):
        def outer(c, _):
            def inner(cc, _):
                return cc @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, a, None, length=5)
        return y

    r = _cost(f, x, x)
    assert r["flops"] == pytest.approx(15 * 2 * 128**3, rel=0.05)


def test_unrolled_equals_scanned():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def unrolled(a, w):
        for _ in range(6):
            a = a @ w
        return a

    def scanned(a, w):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), a, None, length=6)
        return y

    r1, r2 = _cost(unrolled, x, x), _cost(scanned, x, x)
    assert r1["flops"] == pytest.approx(r2["flops"], rel=0.05)


def test_remat_counts_recompute():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def loss_plain(a, w):
        return jnp.sum((a @ w) @ w)

    def loss_remat(a, w):
        f = jax.checkpoint(lambda a_: (a_ @ w) @ w)
        return jnp.sum(f(a))

    g_plain = _cost(jax.grad(loss_plain), x, x)
    g_remat = _cost(jax.grad(loss_remat), x, x)
    # at trivial sizes XLA may CSE the recompute away; remat must never be
    # counted as CHEAPER than the plain backward
    assert g_remat["flops"] >= g_plain["flops"] * 0.99


def test_parser_handles_tuple_shapes_with_index_comments():
    hlo = """
HloModule m

ENTRY %main.1 (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %t = (f32[8,8]{1,0}, /*index=1*/f32[8,8]{1,0}) tuple(%a, %a)
  ROOT %g = f32[8,8]{1,0} get-tuple-element(%t), index=0
}
"""
    comps = parse_module(hlo)
    assert "__entry__" in comps
