"""Per-shape throughput model: price-vs-speed provisioning (beyond the
paper — see ISSUE 3 / docs/trace-format.md).

Covers the three contract points of the change:
* the analytic model is strictly monotone and sublinear in device count,
* cost-to-complete ranking can flip toward a pricier-but-faster shape on
  a long job (the risk-adjusted integration over the remaining work),
* legacy single-device market sets reproduce the pre-throughput simulator
  exactly (throughput ≡ 1, execution time == job length, ranking ==
  MTTR-then-price).
"""
import numpy as np
import pytest

from repro.core import (
    Job,
    OnDemandPolicy,
    Simulator,
    SiwoftPolicy,
    generate_markets,
    legacy_menu,
    load_csv_traces,
    shape_throughput,
    split_history_future,
)
from repro.core import provisioner as alg
from repro.core.market import Market, MarketSet
from repro.core.provisioner import MarketFeatures


# --- analytic model ---------------------------------------------------------

def test_one_device_is_the_unit_reference():
    """θ(1, ·) == 1.0 exactly, whatever the interconnect — the anchor that
    keeps legacy single-device traces bit-identical."""
    for bw in (1.0, 10.0, 50.0, 999.0):
        assert shape_throughput(1, bw) == 1.0


def test_more_devices_strictly_faster_but_sublinear():
    counts = [1, 2, 4, 8, 16, 32]
    for bw in (10.0, 25.0, 60.0):
        thr = [shape_throughput(n, bw) for n in counts]
        for a, b in zip(thr, thr[1:]):
            assert b > a  # strictly more steps/hour
        for n in counts:
            assert shape_throughput(2 * n, bw) < 2 * shape_throughput(n, bw)


def test_interconnect_helps_multi_device_shapes():
    assert shape_throughput(4, 50.0) > shape_throughput(4, 10.0)
    assert shape_throughput(8, 60.0) > shape_throughput(8, 25.0)


def test_menu_carries_throughput_into_features():
    ms = generate_markets(seed=0, n_hours=24 * 30)
    feats = MarketFeatures.from_history(ms)
    for i, m in enumerate(ms.markets):
        assert feats.throughput[i] == pytest.approx(
            shape_throughput(m.device_count, m.interconnect_gbps)
        )
    assert feats.throughput.max() > 1.0  # the menu is heterogeneous


# --- cost-to-complete ranking ----------------------------------------------

def _two_shape_features(mttr_hours: float = 100.0) -> MarketFeatures:
    """Market 0: cheap 1-device. Market 1: pricier 8-device (more $/h AND
    more $ per unit of work). Equal MTTR so the lifetime sort ties and the
    cost-to-complete tie-break decides."""
    n = 2
    return MarketFeatures(
        mttr=np.array([mttr_hours, mttr_hours]),
        corr=np.zeros((n, n)),
        memory_gb=np.array([64.0, 8.0]),
        on_demand=np.array([0.2, 2.0]),
        avg_price=np.array([0.1, 1.0]),
        device_count=np.array([1.0, 8.0]),
        interconnect_gbps=np.array([10.0, 50.0]),
        throughput=np.array([1.0, shape_throughput(8, 50.0)]),
    )


def test_cost_to_complete_is_price_over_throughput():
    feats = _two_shape_features()
    work = 10.0
    assert alg.cost_to_complete(work, feats, 0) == pytest.approx(0.1 * 10.0)
    assert alg.cost_to_complete(work, feats, 1) == pytest.approx(
        1.0 * 10.0 / shape_throughput(8, 50.0)
    )


def test_ranking_flips_to_faster_shape_on_long_job():
    """Short job: the cheap 1-device shape wins. Long job: its wall time
    approaches the MTTR, the restart expectation inflates its bill, and the
    pricier 8-device shape — still more $ per unit of work! — undercuts it
    on expected cost-to-complete. Both are admitted (MTTR ≥ 2 × wall)."""
    feats = _two_shape_features(mttr_hours=100.0)
    policy = SiwoftPolicy()

    def first_choice(work):
        job = Job(length_hours=work, memory_gb=4.0)
        suitable = alg.find_suitable_servers(job, feats)
        assert sorted(suitable) == [0, 1]
        lifetimes = alg.compute_lifetime(feats, suitable)
        S = alg.server_based_lifetime(job, lifetimes, policy, feats)
        return alg.highest(S)

    assert first_choice(10.0) == 0    # cheap slow shape
    assert first_choice(45.0) == 1    # pricier fast shape wins the long job
    # the public helper must agree with the full Algorithm-1 path; it now
    # returns an Allocation — single-leg here, since both shapes fit
    assert alg.plan_first_choice(Job(10.0, 4.0), feats, policy).markets == (0,)
    assert alg.plan_first_choice(Job(45.0, 4.0), feats, policy).markets == (1,)
    # the flip is in the expected (risk-adjusted) cost, not the base cost:
    assert alg.cost_to_complete(45.0, feats, 0) < alg.cost_to_complete(45.0, feats, 1)
    assert alg.expected_cost_to_complete(45.0, feats, 0) > alg.expected_cost_to_complete(
        45.0, feats, 1
    )


def test_admission_uses_wall_time_on_the_shape():
    """A job too long for the slow shape's lifetime window is still
    admitted on the fast shape: MTTR ≥ 2 × (work / θ)."""
    feats = _two_shape_features(mttr_hours=100.0)
    job = Job(length_hours=60.0, memory_gb=4.0)   # wall 60 h vs 7.6 h
    assert not alg.lifetime_admits(job, 100.0, SiwoftPolicy(), throughput=1.0)
    assert alg.lifetime_admits(
        job, 100.0, SiwoftPolicy(), throughput=float(feats.throughput[1])
    )


# --- simulator: completion time varies with device_count --------------------

def _flat_market_set(device_count: int, n_hours: int = 200) -> MarketSet:
    """One never-revoking market of the given shape at a flat spot price."""
    m = Market(
        0, f"shape{device_count}", "r", "ra", 16, on_demand_price=1.0,
        device_count=device_count, interconnect_gbps=25.0,
    )
    prices = np.full((1, n_hours), 0.3)
    return MarketSet(markets=[m], prices=prices)


@pytest.mark.parametrize("devices,expect_faster", [(1, False), (4, True)])
def test_completion_time_scales_with_device_count(devices, expect_faster):
    ms = _flat_market_set(devices)
    hist, fut = split_history_future(ms, 100)
    sim = Simulator(hist, fut, seed=0)
    job = Job(length_hours=10.0, memory_gb=16.0)
    bd = sim.run_job(job, SiwoftPolicy())
    wall_exec = bd.time["execution"]
    if expect_faster:
        expected = 10.0 / shape_throughput(devices, 25.0)
        assert wall_exec == pytest.approx(expected)
        assert bd.wall_time < 10.0
    else:
        assert wall_exec == pytest.approx(10.0)


def test_on_demand_reference_is_throughput_aware():
    """The O baseline picks the fitting shape with the lowest od price per
    unit of work, not the lowest raw $/h."""
    fast = Market(0, "fast8", "r", "ra", 8, on_demand_price=2.0,
                  device_count=8, interconnect_gbps=50.0)
    slow = Market(1, "slow1", "r", "ra", 64, on_demand_price=0.5)
    prices = np.full((2, 100), 0.1)
    ms = MarketSet(markets=[fast, slow], prices=prices)
    hist, fut = split_history_future(ms, 50)
    sim = Simulator(hist, fut, seed=0)
    job = Job(length_hours=10.0, memory_gb=32.0)
    bd = sim.run_job(job, OnDemandPolicy())
    # fast8: 2.0/7.89 ≈ 0.253 $/work-h beats slow1's 0.5 — despite 4× $/h
    theta = shape_throughput(8, 50.0)
    assert bd.time["execution"] == pytest.approx(10.0 / theta)
    assert bd.total_cost >= 2.0 * (10.0 / theta)  # billed at the fast od price


# --- legacy equivalence -----------------------------------------------------

def test_legacy_menu_reproduces_prechange_simulator():
    """Single-device market sets are the pre-throughput world: every
    throughput is 1.0, execution time equals the job length exactly, and
    Algorithm 1's ranking reduces to MTTR-descending with the historical
    price tie-break (the pre-change ordering)."""
    ms = generate_markets(seed=2, n_hours=24 * 90 + 24 * 30, menu=legacy_menu())
    hist, fut = split_history_future(ms, 24 * 90)
    feats = MarketFeatures.from_history(hist)
    assert (feats.throughput == 1.0).all()

    job = Job(length_hours=24.0, memory_gb=16.0)
    suitable = alg.find_suitable_servers(job, feats)
    lifetimes = alg.compute_lifetime(feats, suitable)
    S = alg.server_based_lifetime(job, lifetimes, SiwoftPolicy(), feats)
    # pre-change ordering: (-mttr, avg_price, index) over the admitted pool
    admitted = [
        i for i in suitable if lifetimes[i] >= 2.0 * job.length_hours
    ] or list(suitable)
    expected = sorted(
        admitted, key=lambda i: (-lifetimes[i], float(feats.avg_price[i]), i)
    )
    assert S == expected

    sim = Simulator(hist, fut, seed=2)
    bd = sim.run_job(job, SiwoftPolicy())
    assert bd.time["execution"] == pytest.approx(job.length_hours)


def test_legacy_csv_defaults_to_unit_throughput(tmp_path):
    rows = ["0,m5.xlarge,us-east-1,us-east-1a,16,0.192,0.05,0.06"]
    p = tmp_path / "legacy.csv"
    p.write_text("\n".join(rows))
    loaded = load_csv_traces(str(p))
    assert loaded.markets[0].steps_per_hour is None
    assert loaded.markets[0].throughput == 1.0


def test_csv_header_without_h0_marker_uses_header_width(tmp_path):
    """Optional columns with UNLABELED price columns (the PR 2 topology
    layout): the header names exactly the metadata block, so its length
    determines the block width — the measured rate must not be parsed as
    the hour-0 price."""
    rows = [
        "market_id,instance_type,region,zone,memory_gb,on_demand_price,"
        "steps_per_hour",
        "0,m5.xlarge,us-east-1,us-east-1a,16,0.192,3.1,0.05,0.06",
    ]
    p = tmp_path / "no_h0.csv"
    p.write_text("\n".join(rows))
    loaded = load_csv_traces(str(p))
    assert loaded.markets[0].steps_per_hour == pytest.approx(3.1)
    assert loaded.prices.shape == (1, 2)
    assert loaded.prices[0, 0] == pytest.approx(0.05)


def test_csv_steps_per_hour_column_overrides_model(tmp_path):
    """A measured steps_per_hour column wins over the analytic model; an
    empty cell means 'no measurement' and falls back to it."""
    rows = [
        "market_id,instance_type,region,zone,memory_gb,on_demand_price,"
        "device_count,interconnect_gbps,steps_per_hour,h0,h1",
        "0,g5.2xlarge,us-east-1,us-east-1a,16,0.402,2,25.0,1.5,0.1,0.1",
        "1,m5.xlarge,us-east-1,us-east-1a,16,0.192,1,10.0,,0.05,0.05",
    ]
    p = tmp_path / "measured.csv"
    p.write_text("\n".join(rows))
    loaded = load_csv_traces(str(p))
    assert loaded.markets[0].throughput == pytest.approx(1.5)  # measured
    assert loaded.markets[1].throughput == 1.0                 # analytic
    feats = MarketFeatures.from_history(loaded)
    assert feats.throughput[0] == pytest.approx(1.5)
    assert loaded.prices.shape == (2, 2)


# --- measured-throughput feedback ------------------------------------------

def test_throughput_tracker_corrects_analytic_model():
    from repro.dist.meshplan import ThroughputTracker

    tr = ThroughputTracker()
    analytic = {"a": 1.0, "b": shape_throughput(4)}   # model predicts 3.03×
    assert tr.correction("b", analytic) == 1.0        # nothing measured yet
    tr.observe("a", steps=100, seconds=100.0)         # 1.0 step/s
    assert tr.correction("b", analytic) == 1.0        # single-shape anchor
    tr.observe("b", steps=100, seconds=50.0)          # measured only 2.0×
    c = tr.correction("b", analytic)
    assert c == pytest.approx(2.0 / shape_throughput(4))
    assert c < 1.0                                    # scaled worse than model
    assert tr.correction("a", analytic) == 1.0        # the anchor stays 1.0


def test_tracker_ema_converges():
    from repro.dist.meshplan import ThroughputTracker

    tr = ThroughputTracker(ema=0.5)
    for _ in range(10):
        tr.observe("k", steps=10, seconds=2.0)
    assert tr.steps_per_sec("k") == pytest.approx(5.0)
    tr.observe("k", steps=0, seconds=1.0)   # degenerate observations ignored
    tr.observe("k", steps=10, seconds=0.0)
    assert tr.steps_per_sec("k") == pytest.approx(5.0)
