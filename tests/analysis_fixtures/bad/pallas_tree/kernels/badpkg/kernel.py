"""P004: a pallas_call kernel package with no ref.py and no kernel test."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 3.0


def triple(x):
    return pl.pallas_call(
        _kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec((64, 64), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((64, 64), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((128, 64), jnp.float32),
    )(x)
