"""D003: default_rng without an explicit seed/SeedSequence flowing in."""
import numpy as np


def build(n):
    rng = np.random.default_rng()              # D003: OS entropy
    rng2 = np.random.default_rng(12345)        # D003: anonymous literal seed
    return rng.normal(size=n) + rng2.normal(size=n)
