"""D001: wall-clock reads in the deterministic core."""
import time
from datetime import datetime


def stamp(trace):
    trace.started = time.time()                # D001
    trace.tick = time.monotonic()              # D001
    trace.day = datetime.now()                 # D001
    return trace
