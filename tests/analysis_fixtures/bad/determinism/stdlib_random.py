"""D002: implicit-state RNGs (stdlib random / numpy legacy global)."""
import random

import numpy as np


def jitter(prices):
    noise = random.random()                    # D002: stdlib global state
    pick = random.choice(prices)               # D002
    np.random.seed(0)                          # D002: numpy legacy global RNG
    return noise, pick, np.random.rand(3)      # D002
