"""U002: bare unit-conversion literals in arithmetic."""


def convert(wall_hours, state_bytes):
    wall_seconds = wall_hours * 3600           # U002: bare 3600
    state_gb = state_bytes / 1e9               # U002: bare 1e9
    state_gib = state_bytes / 2**30            # U002: bare power-of-two factor
    return wall_seconds, state_gb, state_gib
