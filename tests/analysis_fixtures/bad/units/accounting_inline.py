"""U003: conversion-literal arithmetic inline at accounting entry points."""


def ledger(session, leg, bill_session, settle_leg, wall_seconds, mem_bytes):
    bill_session(session, wall_seconds / 3600.0)         # U003
    settle_leg(leg, price=mem_bytes / 1e9)               # U003 (keyword arg)
    session.add("execution", wall_seconds / 3600.0)      # U003 (Session.add)
