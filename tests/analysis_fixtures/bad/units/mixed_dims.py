"""U001: +/- and comparisons across incompatible unit dimensions."""


def deadline_check(wall_hours, mttr_seconds, budget_usd, spent_tokens):
    slack = wall_hours - mttr_seconds          # U001: hours minus seconds
    if wall_hours > mttr_seconds:              # U001: compares hours to seconds
        slack = budget_usd + spent_tokens      # U001: usd plus tokens
    return slack
