"""V001: per-hour Python loops in a vectorized-core hot module (4 hits)."""
import numpy as np


def bill_hour_by_hour(prices, n_hours):
    total = 0.0
    for h in range(n_hours):                   # V001: range over an hour count
        total += float(prices[0, min(h, prices.shape[1] - 1)])
    return total


def scan_revocations(rev):
    # no 'hour' identifier in the range bound; fires via the
    # trace-array-subscript signature (rev indexed by the loop variable)
    hits = []
    for h in range(rev.shape[1]):              # V001
        if rev[0, h]:
            hits.append(h)
    return hits


def ar1_per_market(eps, phi):
    noise = np.empty_like(eps)
    for i in range(eps.shape[0]):              # V001: eps[i, h] indexed by i
        x = 0.0
        for h in range(eps.shape[1]):          # V001: eps[i, h] indexed by h
            x = phi * x + eps[i, h]
            noise[i, h] = x
    return noise
