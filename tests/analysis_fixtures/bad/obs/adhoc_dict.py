"""O001: untyped dict events bypass the frozen registry schema."""


def run(rec, wall, market):
    if rec.enabled:
        rec.emit({"type": "provision", "t": wall, "market_id": market})
        rec.emit(dict(type="revoke", t=wall, market_id=market))
        rec.emit({k: v for k, v in [("type", "run_end"), ("t", wall)]})
