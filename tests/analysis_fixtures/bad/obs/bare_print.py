"""O002: stdout is machine-owned; human status goes to the stderr logger."""


def run(bd, wall):
    print("billing hour", wall)
    print(f"cost so far: {sum(bd.cost.values()):.4f}")
    return wall + 1.0
