"""P003: Python side effects inside a kernel body (trace-time, not per-step)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SEEN = []
_COUNT = 0


def _kernel(x_ref, o_ref):
    global _COUNT                              # P003: global mutation
    print("step")                              # P003: print at trace time
    _SEEN.append(x_ref.shape)                  # P003: closure list mutation
    o_ref[...] = x_ref[...]


def copy(x):
    return pl.pallas_call(
        _kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec((64, 64), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((64, 64), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((128, 64), jnp.float32),
    )(x)
