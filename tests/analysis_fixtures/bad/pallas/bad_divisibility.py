"""P001: block shape does not tile the declared out_shape."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def double(x):
    return pl.pallas_call(
        _kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec((128, 300), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((128, 300), lambda i: (i, 0)),  # P001: 128 !| 300
        out_shape=jax.ShapeDtypeStruct((300, 300), jnp.float32),
    )(x)
