"""P001 through ``grid_spec=``: the page block shape does not tile the
declared output ref — a paged-attention-style kernel whose block-table
index maps are otherwise correct (arity = grid rank + prefetch)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(bt_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


def gather_pages(block_table, pool):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(4, 8),
        in_specs=[
            pl.BlockSpec((1, 16), lambda b, j, bt: (bt[b, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 24), lambda b, j, bt: (b, 0)),  # P001: 24 !| 100
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((4, 100), jnp.float32),
    )(block_table, pool)
