"""P002 through ``grid_spec=``: with ``num_scalar_prefetch=2`` every
index_map must take grid_rank + 2 parameters — forgetting the prefetch
refs silently shifts which block each grid step reads."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(bt_ref, sl_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


def gather(block_table, seq_lens, pool):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(4, 8),
        in_specs=[
            pl.BlockSpec((1, 16), lambda b, j, bt: (bt[b, j], 0)),  # P002: 3 != 4
        ],
        out_specs=pl.BlockSpec((1, 16), lambda b, j, bt, sl: (b, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((4, 16), jnp.float32),
    )(block_table, seq_lens, pool)
