"""P002: index_map arity differs from the grid rank."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] + 1.0


def bump(x):
    return pl.pallas_call(
        _kernel,
        grid=(4, 8),
        in_specs=[pl.BlockSpec((64, 64), lambda i: (i, 0))],   # P002: 1 != 2
        out_specs=pl.BlockSpec((64, 64), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((256, 512), jnp.float32),
    )(x)
