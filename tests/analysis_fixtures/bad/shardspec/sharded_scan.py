"""S003: a scan (lax.scan stacking) dim mapped to a real mesh axis."""
import jax


def build():
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    rules = {
        "embed": ("data",),
        "layers": ("model",),                  # S003: scan dims never shard
        "groups": ("data",),                   # S003
    }
    return mesh, rules
