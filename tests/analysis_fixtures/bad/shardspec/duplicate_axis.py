"""S002: one mesh axis used twice within a single spec."""
import jax
from jax.sharding import PartitionSpec as P


def build():
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    twice = P("data", "data")                  # S002: data partitions two dims
    joint = P(("model", "model"), None)        # S002: repeated in joint tuple
    return mesh, twice, joint
