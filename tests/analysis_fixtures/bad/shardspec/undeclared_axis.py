"""S001: PartitionSpec / rule table name an axis no mesh declares."""
import jax
from jax.sharding import PartitionSpec as P


def build():
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    spec = P("data", "tensor")                 # S001: 'tensor' undeclared
    rules = {"embed": ("dataa",)}              # S001: typo'd 'dataa'
    return mesh, spec, rules
