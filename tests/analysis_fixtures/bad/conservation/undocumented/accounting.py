"""C002: 'billing_buffer' never documented."""
TIME_COMPONENTS = ("execution", "recovery")
COST_COMPONENTS = TIME_COMPONENTS + ("billing_buffer",)


class Breakdown:
    def __init__(self):
        self.time = {k: 0.0 for k in TIME_COMPONENTS}
        self.cost = {k: 0.0 for k in COST_COMPONENTS}

    def total_time(self):
        return sum(self.time.values())

    def total_cost(self):
        return sum(self.cost.values())
