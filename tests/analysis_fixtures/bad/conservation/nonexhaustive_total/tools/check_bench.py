KNOWN = ("execution", "recovery", "billing_buffer")
