KNOWN = ("execution", "billing_buffer")
