"""Clean under V001: vectorized hour-axis work + one sanctioned loop."""
import numpy as np


def next_revocation_table(rev):
    n, n_hours = rev.shape
    hours = np.arange(n_hours, dtype=np.int32)
    cand = np.where(rev, hours, np.int32(n_hours))
    np.minimum.accumulate(cand[:, ::-1], axis=1, out=cand[:, ::-1])
    cand[cand == n_hours] = -1
    return cand


def bill_interval(prices, first_hour, steps):
    idx = np.minimum(first_hour + np.arange(steps.size), prices.shape[1] - 1)
    return float(np.add.reduce(steps * prices[0, idx]))


def hourly_decisions(offered, n_hours):
    # sequential decision recurrence: each hour consumes the previous
    # hour's choice, so the loop is sanctioned and suppressed by name
    out = []
    state = 0.0
    for h in range(n_hours):  # decision recurrence  # repro-lint: disable=V001
        state = 0.5 * state + float(offered[min(h, offered.size - 1)])
        out.append(state)
    return out


def jobs_not_hours(batch):
    # loops over jobs (not the hour axis, no trace subscripts) are fine
    total = 0.0
    for i in range(len(batch)):
        total += batch[i]
    return total
