"""Accepted: tiles divide, index_map arity matches grid rank, pure body."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(scale, x_ref, o_ref):
    o_ref[...] = x_ref[...] * scale


def scale_by(x, scale=2.0):
    grid = (4, 8)
    return pl.pallas_call(
        functools.partial(_kernel, scale),
        grid=grid,
        in_specs=[pl.BlockSpec((64, 64), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((64, 64), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((256, 512), jnp.float32),
    )(x)
