"""Accepted: RNG state flows from explicit seeds; no ambient entropy."""
import numpy as np


def build(seed, n):
    rng = np.random.default_rng(seed)
    child = np.random.default_rng(np.random.SeedSequence(entropy=seed))
    spawned = np.random.default_rng((seed, 77))
    return rng.normal(size=n) + child.normal(size=n) + spawned.normal(size=n)
