"""Accepted: conversions through named constants, same-dimension math."""

SECONDS_PER_HOUR = 3600.0
BYTES_PER_GB = 1e9


def convert(wall_hours, mttr_hours, state_bytes, limit_bytes):
    wall_seconds = wall_hours * SECONDS_PER_HOUR
    slack_hours = wall_hours - mttr_hours
    if state_bytes > limit_bytes:
        state_gb = state_bytes / BYTES_PER_GB
    else:
        state_gb = 0.0
    return wall_seconds, slack_hours, state_gb


def ledger(session, wall_hours):
    session.add("execution", wall_hours)
