"""Accepted: every axis declared, used once, scan dims replicated."""
import jax
from jax.sharding import PartitionSpec as P


def build(multi_pod):
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    shape = (2, 4, 4) if multi_pod else (4, 4)
    mesh = jax.make_mesh(shape, axes)
    spec = P(("pod", "data"), "model", None)
    rules = {"embed": ("data",), "ffn": ("model",), "layers": ()}
    return mesh, spec, rules
