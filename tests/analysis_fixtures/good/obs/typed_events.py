"""Clean instrumentation: typed events, guarded emission, stderr logging."""
from repro.obs import events as obs_ev
from repro.obs import get_logger
from repro.obs.recorder import current as obs_current

log = get_logger("fixture")


def run(session, wall, market):
    rec = obs_current()
    if rec.enabled:
        rec.emit(obs_ev.Provision(t=wall, market_id=market, legs=(market,)))
        rec.emit(obs_ev.session_billed(wall, session))
    log.info("hour billed", wall=wall, market=market)
    return wall + 1.0
