"""Mesh planning + byte-level reshard cost model + the orchestrator's live
cross-mesh migration path.

In-process tests cover the pure pieces (shape factorization, pool capping,
zero-byte identity reshards, footprint derivation). The multi-device
behavior — grow→shrink→grow bit-exactness, moved-bytes bounds, and the
orchestrator re-jitting onto a different mesh shape after a siwoft
revocation — runs in a subprocess with 8 forced host devices (the main
test process is pinned to 1 CPU)."""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.dist import (
    ElasticMeshManager,
    live_shardings,
    mesh_shape_for,
    reshard_bytes,
    train_state_bytes,
    tree_bytes,
)


def test_mesh_shape_factorization():
    assert mesh_shape_for(1) == (1, 1)
    assert mesh_shape_for(2) == (2, 1)
    assert mesh_shape_for(4) == (2, 2)
    assert mesh_shape_for(8) == (4, 2)
    assert mesh_shape_for(256) == (16, 16)
    for n in range(1, 20):
        d, m = mesh_shape_for(n)
        assert d * m == n


def test_manager_caps_to_pool_and_caches():
    man = ElasticMeshManager()  # 1 CPU in the main test process
    p8 = man.plan_for(8)
    p4 = man.plan_for(4)
    assert p8.device_count == len(jax.devices())
    assert p8.requested_devices == 8
    # capped shapes collapse onto one cached mesh -> zero-byte migrations
    assert p8.key == p4.key
    assert p8.mesh is p4.mesh


def test_reshard_bytes_zero_for_identical_shardings(host_mesh):
    tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.zeros((), jnp.int32)}
    sh = live_shardings(tree)
    assert reshard_bytes(tree, sh, sh) == 0
    assert tree_bytes(tree) == 64 * 4 + 4


def test_train_state_footprint_replaces_hardcoded_16gb():
    from repro.config import get_arch
    from repro.models import build_model
    from repro.models.common import param_bytes

    model = build_model(get_arch("qwen3-4b").reduced())
    b = train_state_bytes(model)
    assert b == 3 * param_bytes(model.specs)
    gb = b / 2**30
    assert 0 < gb < 1.0  # reduced model: far from the seed's 16.0 GB


MULTI_DEVICE_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.config import ShardingLayout, TrainConfig, get_arch
    from repro.dist import (
        ElasticMeshManager, live_shardings, param_shardings,
        reshard_bytes, reshard_tree, tree_bytes,
    )
    from repro.launch.mesh import make_mesh
    from repro.models import build_model

    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    layout = ShardingLayout()

    man = ElasticMeshManager()
    plan4, plan8, plan2 = man.plan_for(4), man.plan_for(8), man.plan_for(2)
    assert plan8.mesh_shape == (4, 2) and plan4.mesh_shape == (2, 2)

    # ---- grow -> shrink -> grow roundtrip is bit-exact ------------------
    params0 = model.init(jax.random.key(0))
    ref = jax.tree_util.tree_map(np.asarray, params0)
    sh4 = param_shardings(model.specs, plan4.mesh, layout)
    sh8 = param_shardings(model.specs, plan8.mesh, layout)
    sh2 = param_shardings(model.specs, plan2.mesh, layout)
    p = reshard_tree(params0, sh4)       # place on 4
    p = reshard_tree(p, sh8)             # grow to 8
    p = reshard_tree(p, sh2)             # shrink to 2
    p = reshard_tree(p, sh8)             # grow again
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), b), p, ref
    )
    print("ROUNDTRIP_BITEXACT_OK")

    # ---- moved bytes: 0 for identical, bounded by full size ------------
    p4 = reshard_tree(params0, sh4)
    assert reshard_bytes(p4, live_shardings(p4), sh4) == 0
    full = tree_bytes(p4)
    moved = reshard_bytes(p4, sh4, sh8)
    assert 0 < moved <= full, (moved, full)
    print("RESHARD_BYTES_OK", moved, full)

    # ---- allocation plans: leg spans + one-leg rebuild bytes ------------
    from repro.dist import leg_state_bytes

    aplan = man.plan_for_allocation([4, 4])     # 2-leg split on the 8 pool
    assert aplan.key == plan8.key               # same execution substrate
    assert aplan.leg_spans == ((0, 4), (4, 8))
    assert man.plan_for_allocation([4]).key == plan4.key  # single delegates
    sh_a = param_shardings(model.specs, aplan.mesh, layout)
    pa = reshard_tree(params0, sh_a)
    full_a = tree_bytes(pa)
    leg0 = leg_state_bytes(pa, sh_a, aplan, 0)
    leg1 = leg_state_bytes(pa, sh_a, aplan, 1)
    # a one-leg rebuild moves strictly fewer bytes than a full restore —
    # the byte-level sense in which a leg revocation is cheaper than
    # losing (or checkpoint-restoring) the whole allocation
    assert 0 < leg0 < full_a, (leg0, full_a)
    assert 0 < leg1 < full_a, (leg1, full_a)
    # together the legs cover at least the whole state (replicated slices
    # can be counted on both legs, so >= rather than ==)
    assert leg0 + leg1 >= full_a
    # capped pool: a 16+16 allocation honors as 4+4 on 8 local devices
    wide = man.plan_for_allocation([16, 16])
    assert wide.device_count == 8 and wide.leg_spans == ((0, 4), (4, 8))
    print("ALLOC_LEG_BYTES_OK", leg0, leg1, full_a)

    # ---- orchestrator: siwoft revocation -> live reshard + re-jit ------
    from repro.core.market import Market, MarketSet
    from repro.core.orchestrator import SpotTrainingOrchestrator
    from repro.data import SyntheticLM

    markets = [
        Market(0, "p8", "r1", "r1a", 2, 1.0, device_count=8, interconnect_gbps=50.0),
        Market(1, "g4", "r1", "r1b", 4, 1.0, device_count=4, interconnect_gbps=25.0),
        Market(2, "c1", "r2", "r2a", 16, 1.0, device_count=1, interconnect_gbps=10.0),
    ]
    H = 60
    hp = np.full((3, H), 0.3)
    hp[1, ::30] = 1.5   # m1: MTTR 30 h
    hp[2, ::6] = 1.5    # m2: MTTR 6 h  (m0 never revokes in history)
    hist = MarketSet(markets, hp)
    F = 12
    fp = np.full((3, F), 0.3)
    fp[0, 1:] = 1.5     # m0 revokes from future hour 1
    fut = MarketSet(markets, fp, start_hour=H)

    ds = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=4, seed=0)
    tc = TrainConfig(total_steps=40, warmup_steps=2)
    # reference rate 1 step/trace-hour: the 8-device market still delivers
    # ~6.4 steps/hour (shape throughput), so the hour-1 revocation lands
    # around step 6 — mid-first-segment — instead of after the job is done
    orch = SpotTrainingOrchestrator(
        model, ds, make_mesh((4, 2), ("data", "model")), hist, fut,
        mode="siwoft", tc=tc, segment_steps=10, steps_per_trace_hour=1, seed=0,
    )
    rep = orch.run(20)
    assert rep.useful_steps == 20 and rep.revocations == 1, (
        rep.useful_steps, rep.revocations)
    assert rep.mesh_shapes[0] == (4, 2), rep.mesh_shapes
    assert (2, 2) in rep.mesh_shapes[1:], rep.mesh_shapes
    assert len(set(rep.mesh_shapes)) >= 2
    assert len(orch._steps) >= 2          # re-jitted for the new mesh
    assert rep.reshard_bytes > 0 and rep.reshard_events == 1
    assert rep.breakdown.time["reshard"] > 0
    assert rep.breakdown.cost["reshard"] > 0
    assert rep.reshard_bytes <= tree_bytes(params0) * 3 + 64
    assert all(np.isfinite(rep.losses))
    print("ORCH_RESHARD_OK", rep.reshard_bytes, rep.mesh_shapes)

    # ---- allocation: one-leg revocation with NO same-shape repair ------
    # Only two 8-dev markets + one 4-dev: when leg B revokes, no same-shape
    # replacement exists, so the ordinary pick lands on the (A, C) split —
    # and the changed leg's DCN crossing must still be billed (regression:
    # this path used to drop the bytes silently).
    am = [
        Market(0, "big8.a", "r1", "r1a", 40, 1.2, device_count=8, interconnect_gbps=60.0),
        Market(1, "big8.b", "r2", "r2a", 40, 1.2, device_count=8, interconnect_gbps=60.0),
        Market(2, "mid4.c", "r3", "r3a", 40, 0.7, device_count=4, interconnect_gbps=25.0),
    ]
    ahp = np.full((3, 90), 0.35); ahp[2, ::60] = 1.0
    afp = np.full((3, 24), 0.35); afp[1, 2:4] = 1.5
    orch2 = SpotTrainingOrchestrator(
        model, ds, make_mesh((4, 2), ("data", "model")),
        MarketSet(am, ahp), MarketSet(am, afp, start_hour=90),
        mode="siwoft", tc=TrainConfig(total_steps=80, warmup_steps=2),
        segment_steps=10, steps_per_trace_hour=1, seed=0,
        job_memory_gb=400.0,
    )
    rep2 = orch2.run(20)
    assert rep2.allocations_used[0] == (0, 1), rep2.allocations_used
    assert (0, 2) in rep2.allocations_used, rep2.allocations_used
    assert rep2.revocations >= 1 and rep2.leg_repairs == 0
    assert rep2.reshard_bytes > 0          # replacement still crossed DCN
    assert rep2.useful_steps == 20
    assert abs(sum(rep2.leg_costs.values()) - rep2.cost_dollars) < 1e-6
    print("ALLOC_REPLACEMENT_BILLING_OK", rep2.reshard_bytes)
    """
)


def test_meshplan_multi_device_subprocess():
    # inherit the parent env (JAX_PLATFORMS etc. — a bare env makes the PJRT
    # plugin probe for TPU metadata and hang); only PYTHONPATH is forced
    repo = pathlib.Path(__file__).resolve().parents[1]
    res = subprocess.run(
        [sys.executable, "-c", MULTI_DEVICE_SCRIPT],
        capture_output=True,
        text=True,
        timeout=560,
        env={**os.environ, "PYTHONPATH": str(repo / "src")},
        cwd=str(repo),
    )
    out = res.stdout + res.stderr
    assert "ROUNDTRIP_BITEXACT_OK" in res.stdout, out
    assert "RESHARD_BYTES_OK" in res.stdout, out
    assert "ALLOC_LEG_BYTES_OK" in res.stdout, out
    assert "ORCH_RESHARD_OK" in res.stdout, out
    assert "ALLOC_REPLACEMENT_BILLING_OK" in res.stdout, out
