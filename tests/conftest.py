"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (1-CPU) device set; only launch/dryrun.py forces 512 devices.

Also registers a minimal ``hypothesis`` fallback when the real package is
not installed (see ``_install_hypothesis_fallback``): the property tests in
test_accounting / test_core_market / test_train_and_data then run against a
small deterministic random sample instead of failing at import. CI installs
the real hypothesis via the ``test`` extra (pyproject.toml); the fallback
only exists so a bare environment can still run the full suite.
"""


def _install_hypothesis_fallback():
    import functools
    import inspect
    import random
    import sys
    import types

    class _Strategy:
        def __init__(self, draw):
            self.example = draw

    def floats(min_value, max_value, **_kw):
        lo, hi = float(min_value), float(max_value)

        def draw(rng):
            # boundary values first-class, like hypothesis' shrink targets
            if rng.random() < 0.15:
                return lo if rng.random() < 0.5 else hi
            return rng.uniform(lo, hi)

        return _Strategy(draw)

    def integers(min_value, max_value, **_kw):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def lists(elements, min_size=0, max_size=10, **_kw):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]

        return _Strategy(draw)

    def booleans(**_kw):
        return _Strategy(lambda rng: rng.random() < 0.5)

    def sampled_from(values, **_kw):
        pool = list(values)

        return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

    def tuples(*strategies, **_kw):
        return _Strategy(
            lambda rng: tuple(s.example(rng) for s in strategies)
        )

    def settings(max_examples=25, **_kw):
        def deco(f):
            f._fallback_max_examples = max_examples
            return f

        return deco

    def given(**strategies):
        def deco(f):
            sig = inspect.signature(f)
            rest = [
                p for name, p in sig.parameters.items() if name not in strategies
            ]

            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                # read at call time so @settings works above OR below @given
                # (wraps copies f.__dict__, settings-above sets it on wrapper)
                n = getattr(wrapper, "_fallback_max_examples", 25)
                rng = random.Random(f.__qualname__)  # deterministic per test
                for _ in range(n):
                    drawn = {k: s.example(rng) for k, s in strategies.items()}
                    f(*args, **kwargs, **drawn)

            # pytest must see only the non-strategy params (fixtures);
            # __signature__ wins over __wrapped__ in inspect.signature
            wrapper.__signature__ = sig.replace(parameters=rest)
            del wrapper.__wrapped__
            return wrapper

        return deco

    mod = types.ModuleType("hypothesis")
    mod.given, mod.settings = given, settings
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.floats, st_mod.integers, st_mod.lists = floats, integers, lists
    st_mod.booleans, st_mod.sampled_from = booleans, sampled_from
    st_mod.tuples = tuples
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _install_hypothesis_fallback()

import jax
import pytest

from repro.config import InputShape


@pytest.fixture(scope="session")
def host_mesh():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh()


@pytest.fixture(scope="session")
def tiny_shape():
    return InputShape("tiny", seq_len=32, global_batch=2, mode="train")


def pytest_report_header(config):
    return f"jax {jax.__version__}, devices={jax.devices()}"
