"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (1-CPU) device set; only launch/dryrun.py forces 512 devices.
"""
import jax
import numpy as np
import pytest

from repro.config import InputShape, get_arch, list_archs


@pytest.fixture(scope="session")
def host_mesh():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh()


@pytest.fixture(scope="session")
def tiny_shape():
    return InputShape("tiny", seq_len=32, global_batch=2, mode="train")


def pytest_report_header(config):
    return f"jax {jax.__version__}, devices={jax.devices()}"
