"""repro-lint test suite: every bad fixture raises exactly its rule, every
good fixture is accepted, the real tree is clean, and suppression /
reporting behave as documented (docs/invariants.md)."""
import json
from pathlib import Path
import sys

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.analysis.conservation import ConservationPass  # noqa: E402
from tools.analysis.core import (  # noqa: E402
    SourceFile,
    all_passes,
    render,
    run_analysis,
)
from tools.analysis.determinism import DeterminismPass  # noqa: E402
from tools.analysis.obs import ObsPass  # noqa: E402
from tools.analysis.pallas import PallasPass  # noqa: E402
from tools.analysis.perf import PerfPass  # noqa: E402
from tools.analysis.shardspec import ShardSpecPass  # noqa: E402
from tools.analysis.units import UnitsPass  # noqa: E402

FIX = REPO / "tests" / "analysis_fixtures"


def run_pass(p, files, root=REPO):
    srcs = [SourceFile.load(f) for f in files]
    return p.run(srcs, root)


def rules_of(diags):
    return {d.rule for d in diags}


# ---------------------------------------------------------------------------
# units (U001–U003)
# ---------------------------------------------------------------------------

def test_units_bad_fixtures_fire_exactly_their_rule():
    cases = {
        "mixed_dims.py": ("U001", 3),
        "bare_literal.py": ("U002", 3),
        "accounting_inline.py": ("U003", 3),
    }
    for name, (rule, count) in cases.items():
        diags = run_pass(UnitsPass(), [FIX / "bad" / "units" / name])
        assert rules_of(diags) == {rule}, (name, diags)
        assert len(diags) == count, (name, diags)


def test_units_good_fixture_accepted():
    assert run_pass(UnitsPass(), [FIX / "good" / "units" / "clean.py"]) == []


def test_units_scope_excludes_units_module_itself():
    p = UnitsPass()
    assert not p.applies_to(Path("src/repro/core/units.py"))
    assert p.applies_to(Path("src/repro/core/accounting.py"))
    assert p.applies_to(Path("benchmarks/fig1.py"))
    assert not p.applies_to(Path("src/repro/models/gpt.py"))


# ---------------------------------------------------------------------------
# conservation (C001–C004) — mini-tree fixtures
# ---------------------------------------------------------------------------

def test_conservation_bad_trees_fire_exactly_their_rule():
    cases = {
        "unknown_component": ("C001", 2),
        "undocumented": ("C002", 1),
        "gate_missing": ("C003", 1),
        "nonexhaustive_total": ("C004", 1),
    }
    for tree, (rule, count) in cases.items():
        root = FIX / "bad" / "conservation" / tree
        diags = run_pass(ConservationPass(), [root / "accounting.py"], root)
        assert rules_of(diags) == {rule}, (tree, diags)
        assert len(diags) == count, (tree, diags)


def test_conservation_good_tree_accepted():
    root = FIX / "good" / "conservation" / "clean_tree"
    assert run_pass(ConservationPass(), [root / "accounting.py"], root) == []


def test_conservation_silent_without_registry(tmp_path):
    f = tmp_path / "noreg.py"
    f.write_text("def g(bd, h):\n    bd.time['whatever'] += h\n")
    assert run_pass(ConservationPass(), [f], tmp_path) == []


# ---------------------------------------------------------------------------
# determinism (D001–D003)
# ---------------------------------------------------------------------------

def test_determinism_bad_fixtures_fire_exactly_their_rule():
    cases = {
        "wall_clock.py": ("D001", 3),
        "stdlib_random.py": ("D002", 4),
        "unseeded_rng.py": ("D003", 2),
    }
    for name, (rule, count) in cases.items():
        diags = run_pass(DeterminismPass(), [FIX / "bad" / "determinism" / name])
        assert rules_of(diags) == {rule}, (name, diags)
        assert len(diags) == count, (name, diags)


def test_determinism_good_fixture_accepted():
    diags = run_pass(DeterminismPass(), [FIX / "good" / "determinism" / "seeded.py"])
    assert diags == []


def test_determinism_scope_is_core_serve_dist():
    p = DeterminismPass()
    assert p.applies_to(Path("src/repro/core/orchestrator.py"))
    assert p.applies_to(Path("src/repro/serve/router.py"))
    assert not p.applies_to(Path("benchmarks/serve_bench.py"))
    assert not p.applies_to(Path("src/repro/launch/dryrun.py"))


# ---------------------------------------------------------------------------
# pallas (P001–P004)
# ---------------------------------------------------------------------------

def test_pallas_bad_fixtures_fire_exactly_their_rule():
    cases = {
        "bad_divisibility.py": ("P001", 1),
        "bad_arity.py": ("P002", 1),
        "bad_table_divisibility.py": ("P001", 1),   # via grid_spec=
        "bad_prefetch_arity.py": ("P002", 1),       # grid rank + prefetch
        "side_effect.py": ("P003", 3),
    }
    for name, (rule, count) in cases.items():
        diags = run_pass(PallasPass(), [FIX / "bad" / "pallas" / name])
        assert rules_of(diags) == {rule}, (name, diags)
        assert len(diags) == count, (name, diags)


def test_pallas_missing_ref_and_test_fire_p004():
    root = FIX / "bad" / "pallas_tree"
    kernel = root / "kernels" / "badpkg" / "kernel.py"
    diags = run_pass(PallasPass(), [kernel], root)
    assert rules_of(diags) == {"P004"}, diags
    assert len(diags) == 2, diags  # no ref.py AND not exercised by tests


def test_pallas_good_fixture_accepted():
    diags = run_pass(PallasPass(), [FIX / "good" / "pallas" / "clean_kernel.py"])
    assert diags == []


def test_pallas_real_kernels_clean():
    kernels = sorted((REPO / "src" / "repro" / "kernels").rglob("kernel*.py"))
    assert kernels, "expected real kernel modules in src/repro/kernels"
    assert run_pass(PallasPass(), kernels, REPO) == []


# ---------------------------------------------------------------------------
# shardspec (S001–S003)
# ---------------------------------------------------------------------------

def test_shardspec_bad_fixtures_fire_exactly_their_rule():
    cases = {
        "undeclared_axis.py": "S001",
        "duplicate_axis.py": "S002",
        "sharded_scan.py": "S003",
    }
    for name, rule in cases.items():
        diags = run_pass(ShardSpecPass(), [FIX / "bad" / "shardspec" / name])
        assert rules_of(diags) == {rule}, (name, diags)
        assert diags, name


def test_shardspec_good_fixture_accepted():
    diags = run_pass(ShardSpecPass(), [FIX / "good" / "shardspec" / "clean.py"])
    assert diags == []


def test_shardspec_real_tree_declares_all_used_axes():
    files = sorted((REPO / "src" / "repro" / "dist").glob("*.py")) + sorted(
        (REPO / "src" / "repro" / "launch").glob("*.py")
    )
    assert run_pass(ShardSpecPass(), files, REPO) == []


# ---------------------------------------------------------------------------
# perf (V001)
# ---------------------------------------------------------------------------

def test_perf_bad_fixture_fires_v001():
    diags = run_pass(PerfPass(), [FIX / "bad" / "perf" / "hour_loop.py"])
    assert rules_of(diags) == {"V001"}, diags
    # range-over-hour-count, rev-subscript, and both oracle-style loops
    assert len(diags) == 4, diags


def test_perf_good_fixture_accepted():
    # via run_analysis so the fixture's sanctioned-loop inline disable
    # applies, same as the real gate
    diags = run_analysis(paths=[FIX / "good" / "perf"], root=REPO,
                         only_passes=["perf"])
    assert diags == []


def test_perf_scope_is_the_six_hot_modules():
    p = PerfPass()
    for mod in (
        "src/repro/core/market.py",
        "src/repro/core/simulator.py",
        "src/repro/core/accounting.py",
        "src/repro/core/provisioner.py",
        "src/repro/serve/fleet.py",
        "src/repro/serve/router.py",
    ):
        assert p.applies_to(Path(mod)), mod
    # loops elsewhere (orchestrator bookkeeping, benches, tests) are free
    assert not p.applies_to(Path("src/repro/core/orchestrator.py"))
    assert not p.applies_to(Path("benchmarks/sim_bench.py"))
    assert not p.applies_to(Path("src/repro/serve/engine.py"))


def test_perf_suppressed_oracles_keep_real_tree_clean():
    """The scalar oracles and the fleet's decision loop are hour loops by
    design — every one must carry an inline disable, leaving the hot
    modules free of unsuppressed V001s."""
    hot = [
        REPO / "src" / "repro" / "core" / "market.py",
        REPO / "src" / "repro" / "core" / "simulator.py",
        REPO / "src" / "repro" / "core" / "accounting.py",
        REPO / "src" / "repro" / "core" / "provisioner.py",
        REPO / "src" / "repro" / "serve" / "fleet.py",
        REPO / "src" / "repro" / "serve" / "router.py",
    ]
    assert run_analysis(paths=hot, root=REPO, only_passes=["perf"]) == []
    # ...and the oracles DO contain sanctioned loops the pass would flag
    raw = run_pass(PerfPass(), [REPO / "src" / "repro" / "core" / "market.py"])
    assert any(d.rule == "V001" for d in raw), (
        "expected the scalar oracles in market.py to trip V001 pre-suppression"
    )


# ---------------------------------------------------------------------------
# obs (O001–O002)
# ---------------------------------------------------------------------------

def test_obs_bad_fixtures_fire_exactly_their_rule():
    cases = {
        "adhoc_dict.py": ("O001", 3),
        "bare_print.py": ("O002", 2),
    }
    for name, (rule, count) in cases.items():
        diags = run_pass(ObsPass(), [FIX / "bad" / "obs" / name])
        assert rules_of(diags) == {rule}, (name, diags)
        assert len(diags) == count, (name, diags)


def test_obs_good_fixture_accepted():
    diags = run_pass(ObsPass(), [FIX / "good" / "obs" / "typed_events.py"])
    assert diags == []


def test_obs_scope_is_core_serve_dist():
    p = ObsPass()
    for mod in (
        "src/repro/core/orchestrator.py",
        "src/repro/serve/engine.py",
        "src/repro/dist/elastic.py",
    ):
        assert p.applies_to(Path(mod)), mod
    # the logger itself writes to stderr via print; launchers own stdout
    # contracts (PLAN_JSON / CSV); benches print CSV rows — all exempt
    assert not p.applies_to(Path("src/repro/obs/log.py"))
    assert not p.applies_to(Path("src/repro/launch/serve.py"))
    assert not p.applies_to(Path("benchmarks/serve_bench.py"))


# ---------------------------------------------------------------------------
# suppression, runner, reporting
# ---------------------------------------------------------------------------

def test_line_suppression_silences_exactly_that_rule(tmp_path):
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    f = pkg / "conv.py"
    f.write_text(
        "def f(wall_hours):\n"
        "    return wall_hours * 3600  # repro-lint: disable=U002\n"
    )
    assert run_analysis(paths=[tmp_path / "src"], root=tmp_path) == []
    f.write_text("def f(wall_hours):\n    return wall_hours * 3600\n")
    diags = run_analysis(paths=[tmp_path / "src"], root=tmp_path)
    assert [d.rule for d in diags] == ["U002"]


def test_file_suppression_header(tmp_path):
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    f = pkg / "clock.py"
    f.write_text(
        "# repro-lint: disable-file=D001\n"
        "import time\n"
        "def f():\n"
        "    return time.time()\n"
    )
    assert run_analysis(paths=[tmp_path / "src"], root=tmp_path) == []


def test_repo_tree_is_clean():
    assert run_analysis() == []


def test_render_json_roundtrip(tmp_path):
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    (pkg / "conv.py").write_text("def f(h):\n    return h * 3600\n")
    diags = run_analysis(paths=[tmp_path / "src"], root=tmp_path)
    payload = json.loads(render(diags, tmp_path, fmt="json"))
    assert payload["tool"] == "repro-lint"
    assert payload["problems"] == len(diags) == 1
    d = payload["diagnostics"][0]
    assert d["rule"] == "U002" and d["path"].endswith("conv.py")
    text = render(diags, tmp_path, fmt="text")
    assert "U002" in text and text.endswith("1 problem(s)")


def test_rule_catalogue_is_unique_and_documented():
    doc = (REPO / "docs" / "invariants.md").read_text(encoding="utf-8")
    seen = {}
    for p in all_passes():
        assert p.name and p.rules
        for rule, meaning in p.rules.items():
            assert rule not in seen, f"{rule} declared by {seen.get(rule)} and {p.name}"
            seen[rule] = p.name
            assert meaning
            assert rule in doc, f"{rule} missing from docs/invariants.md"


def test_cli_exits_zero_on_clean_tree(capsys):
    from tools.analysis.__main__ import main

    assert main(["--format=json"]) == 0
    out = capsys.readouterr().out
    assert json.loads(out)["problems"] == 0
