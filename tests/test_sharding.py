"""Sharding-rule resolution: divisibility fallbacks, batch=1 replication,
per-arch resolvability on the production mesh (no real devices needed —
mesh axis math only requires an AbstractMesh-compatible mesh; we use the
host mesh shaped (1,1) plus synthetic Mesh objects via jax.sharding)."""
import jax
from jax.sharding import Mesh, PartitionSpec as P
import numpy as np
import pytest

from repro.config import ShardingLayout, get_arch, list_archs
from repro.dist import PARAM_RULES, batch_shardings, param_shardings, resolve_pspec
from repro.models import build_model
from repro.models.common import ParamSpec


def fake_mesh(shape, axes):
    """Mesh over repeated CPU devices — good enough for spec resolution."""
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes)


MESH = fake_mesh((16, 16), ("data", "model"))
RULES = PARAM_RULES["baseline"]


def test_divisible_dims_get_sharded():
    spec = resolve_pspec((2560, 6912), ("embed", "ffn"), RULES, MESH)
    assert spec == P("data", "model")


def test_indivisible_head_dim_falls_back():
    # 40 q-heads * 128 = 5120 fused projection: divisible -> model
    spec = resolve_pspec((5120, 5120), ("embed", "q_dim"), RULES, MESH)
    assert spec == P("data", "model")


def test_indivisible_vocab_replicates():
    # 92553 (internvl) not divisible by 16 -> vocab falls out, embed gets data
    spec = resolve_pspec((92553, 6144), ("vocab", "embed"), RULES, MESH)
    assert spec == P(None, "data")


def test_mesh_axis_used_once_per_tensor():
    spec = resolve_pspec((4096, 4096), ("embed", "q_dim"), RULES, MESH)
    flat = [a for part in spec for a in ((part,) if isinstance(part, str) else (part or ()))]
    assert len(flat) == len(set(flat))


def test_scan_dims_never_sharded():
    spec = resolve_pspec((64, 4096, 14336), ("layers", "embed", "ffn"), RULES, MESH)
    assert spec[0] is None


@pytest.mark.parametrize("arch", list_archs())
def test_all_arch_params_resolve_on_production_mesh(arch):
    model = build_model(get_arch(arch))
    sh = param_shardings(model.specs, MESH, ShardingLayout())
    n_sharded = 0
    for spec, s in zip(
        jax.tree_util.tree_leaves(model.specs, is_leaf=lambda x: isinstance(x, ParamSpec)),
        jax.tree_util.tree_leaves(sh),
    ):
        # every dim must divide cleanly under the chosen spec
        parts = list(s.spec) + [None] * (len(spec.shape) - len(s.spec))
        for dim, part in zip(spec.shape, parts):
            axes = (part,) if isinstance(part, str) else (part or ())
            k = 1
            for a in axes:
                k *= dict(zip(MESH.axis_names, MESH.devices.shape))[a]
            assert dim % k == 0, (arch, spec.shape, s.spec)
        if any(p is not None for p in parts):
            n_sharded += 1
    # the overwhelming majority of weight bytes must be sharded
    assert n_sharded > 0


def test_batch_shardings_batch_of_one_replicates():
    x = jax.ShapeDtypeStruct((1, 1), np.int32)
    sh = batch_shardings({"tokens": x}, MESH)["tokens"]
    assert sh.spec == P(None, None)


def test_batch_shardings_multipod():
    mesh3 = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    x = jax.ShapeDtypeStruct((256, 4096), np.int32)
    sh = batch_shardings({"tokens": x}, mesh3)["tokens"]
    assert sh.spec[0] == ("pod", "data")
