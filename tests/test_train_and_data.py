"""Training loop, optimizer, data pipeline, and watchdog behaviour."""

from hypothesis import given, settings, strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShardingLayout, TrainConfig, get_arch
from repro.data import Prefetcher, SyntheticLM
from repro.models import build_model
from repro.optim import adamw_update, clip_by_global_norm, global_norm, init_opt_state
from repro.optim.schedule import linear, warmup_cosine
from repro.train.loop import Revoked, run_segment
from repro.train.steps import (
    chunked_cross_entropy,
    cross_entropy,
    init_train_state,
)
from repro.train.watchdog import StragglerWatchdog


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    tc = TrainConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=0, total_steps=100)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state = adamw_update(grads, state, params, jnp.float32(0.1), tc)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(warmup_cosine(jnp.int32(s), tc)) for s in range(0, 100, 5)]
    assert lrs[0] < lrs[1]                       # warming up
    assert lrs[-1] < tc.learning_rate            # decayed
    assert max(lrs) <= tc.learning_rate * 1.001
    assert float(linear(jnp.int32(99), tc)) < tc.learning_rate


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

@given(
    b=st.integers(1, 3), s=st.integers(2, 8), v=st.integers(4, 32),
    chunk=st.integers(1, 8),
)
@settings(max_examples=20, deadline=None)
def test_chunked_ce_matches_unfused(b, s, v, chunk):
    key = jax.random.key(b * 100 + s * 10 + v)
    d = 16
    x = jax.random.normal(key, (b, s, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, v))
    labels = jax.random.randint(jax.random.fold_in(key, 2), (b, s), 0, v)
    fused = chunked_cross_entropy(x, w, labels, chunk=chunk)
    ref = cross_entropy(jnp.einsum("bsd,dv->bsv", x, w), labels)
    np.testing.assert_allclose(float(fused), float(ref), rtol=1e-5)


def test_ce_grads_match():
    key = jax.random.key(0)
    b, s, d, v = 2, 8, 16, 32
    x = jax.random.normal(key, (b, s, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, v))
    labels = jax.random.randint(jax.random.fold_in(key, 2), (b, s), 0, v)
    g1 = jax.grad(lambda xx: chunked_cross_entropy(xx, w, labels, chunk=4))(x)
    g2 = jax.grad(lambda xx: cross_entropy(jnp.einsum("bsd,dv->bsv", xx, w), labels))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restartable():
    ds = SyntheticLM(vocab_size=1000, seq_len=16, global_batch=4, seed=7)
    a = ds.batch(3)
    b = ds.batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are the shifted tokens
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_data_shards_partition_global_batch():
    full = SyntheticLM(1000, 16, 4, seed=7)
    s0 = SyntheticLM(1000, 16, 4, seed=7, shard=0, num_shards=2)
    s1 = SyntheticLM(1000, 16, 4, seed=7, shard=1, num_shards=2)
    f = full.batch(5)["tokens"]
    np.testing.assert_array_equal(np.concatenate([s0.batch(5)["tokens"], s1.batch(5)["tokens"]]), f)


def test_prefetcher_in_order():
    ds = SyntheticLM(100, 8, 2, seed=1)
    pre = Prefetcher(ds, start_step=0)
    try:
        for step in range(4):
            np.testing.assert_array_equal(pre.next()["tokens"], ds.batch(step)["tokens"])
    finally:
        pre.close()


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_watchdog_flags_straggler():
    wd = StragglerWatchdog(warmup=3, k_sigma=4.0)
    for i in range(20):
        wd.observe(i, 0.1 + 0.001 * (i % 3))
    assert wd.observe(20, 1.0)  # 10× the mean
    assert 20 in wd.flagged
    # anomaly must not poison the EWMA
    assert wd.mean < 0.2


def test_watchdog_quiet_on_steady_steps():
    wd = StragglerWatchdog(warmup=3)
    for i in range(50):
        assert not wd.observe(i, 0.1)


# ---------------------------------------------------------------------------
# training loop + revocation
# ---------------------------------------------------------------------------

def test_loss_decreases_and_revocation_raises(host_mesh):
    cfg = get_arch("qwen1.5-4b").reduced()
    model = build_model(cfg)
    ds = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=4, seed=0)
    tc = TrainConfig(total_steps=40, warmup_steps=4, learning_rate=1e-3)
    state = init_train_state(model, jax.random.key(0))
    res = run_segment(
        model, state, ds, host_mesh, tc, ShardingLayout(), num_steps=30
    )
    assert np.mean(res.losses[:5]) > np.mean(res.losses[-5:])

    with pytest.raises(Revoked) as e:
        run_segment(
            model, res.state, ds, host_mesh, tc, ShardingLayout(),
            num_steps=10, start_step=30,
            revoke_at_step=lambda s: s >= 33,
        )
    assert e.value.last_step == 32
