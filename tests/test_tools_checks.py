"""Tests for the CI gate scripts: tools/check_bench.py (schema gate,
generic fallback, breakdown registry mirror) and tools/check_docs.py
(required-docs list, markdown link check)."""
import json
from pathlib import Path
import sys

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools import check_bench, check_docs  # noqa: E402


# ---------------------------------------------------------------------------
# check_bench
# ---------------------------------------------------------------------------

def test_check_bench_accepts_committed_files():
    assert check_bench.main() == 0


def test_check_bench_rejects_malformed_json(tmp_path, capsys):
    (tmp_path / "BENCH_orchestrator.json").write_text("{not json", encoding="utf-8")
    assert check_bench.main(tmp_path) == 1
    assert "invalid JSON" in capsys.readouterr().err


def test_check_bench_unknown_name_uses_generic_fallback(tmp_path, capsys):
    # an object with dense monotonic scenario ids passes the fallback ...
    good = {"bench": "novel", "scenarios": [{"id": 0}, {"id": 1}, {"id": 2}]}
    (tmp_path / "BENCH_novel.json").write_text(json.dumps(good), encoding="utf-8")
    assert check_bench.main(tmp_path) == 0
    # ... but never silently: the unvalidated file is warned about
    assert "unvalidated bench" in capsys.readouterr().err


def test_check_bench_strict_fails_unvalidated_files(tmp_path, capsys):
    good = {"bench": "novel", "scenarios": [{"id": 0}]}
    (tmp_path / "BENCH_novel.json").write_text(json.dumps(good), encoding="utf-8")
    assert check_bench.main(tmp_path, strict=True) == 1
    err = capsys.readouterr().err
    assert "unvalidated bench" in err and "ERROR" in err


def test_check_bench_strict_passes_known_files():
    # every committed bench has a registered checker, so strict == default
    assert check_bench.main(strict=True) == 0


def test_check_bench_generic_rejects_non_object_and_bad_ids(tmp_path, capsys):
    (tmp_path / "BENCH_list.json").write_text("[1, 2, 3]", encoding="utf-8")
    # ... but non-monotonic / sparse ids are the rot the gate exists to catch
    sparse = {"scenarios": [{"id": 0}, {"id": 2}]}
    (tmp_path / "BENCH_sparse.json").write_text(json.dumps(sparse), encoding="utf-8")
    assert check_bench.main(tmp_path) == 1
    err = capsys.readouterr().err
    assert "top level must be an object" in err
    assert "dense and monotonic" in err


def test_check_bench_missing_dir_reports_no_files(tmp_path, capsys):
    assert check_bench.main(tmp_path / "empty") == 1
    assert "no BENCH_" in capsys.readouterr().err


def test_check_bench_breakdown_components_must_be_registry_names(tmp_path, capsys):
    data = {
        "bench": "novel",
        "scenarios": [
            {"id": 0, "time_breakdown": {"execution": 1.0, "warmup": 0.5}}
        ],
    }
    (tmp_path / "BENCH_novel.json").write_text(json.dumps(data), encoding="utf-8")
    assert check_bench.main(tmp_path) == 1
    assert "warmup" in capsys.readouterr().err
    # registry names pass, including the cost-only billing_buffer
    ok = {
        "bench": "novel",
        "scenarios": [{"id": 0}],
        "cost_breakdown": {"execution": 1.0, "billing_buffer": 0.1},
    }
    (tmp_path / "BENCH_novel.json").write_text(json.dumps(ok), encoding="utf-8")
    assert check_bench.main(tmp_path) == 0


def test_check_bench_registry_mirrors_accounting():
    sys.path.insert(0, str(REPO / "src"))
    from repro.core.accounting import COST_COMPONENTS, TIME_COMPONENTS

    assert check_bench.KNOWN_TIME_COMPONENTS == TIME_COMPONENTS
    assert check_bench.KNOWN_COST_COMPONENTS == COST_COMPONENTS


# ---------------------------------------------------------------------------
# check_docs
# ---------------------------------------------------------------------------

def _make_doc_tree(root: Path):
    for rel in check_docs.REQUIRED:
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(f"# {rel}\n", encoding="utf-8")


def test_check_docs_accepts_committed_tree():
    assert check_docs.main() == 0


def test_check_docs_requires_invariants_doc(tmp_path, capsys):
    assert "docs/invariants.md" in check_docs.REQUIRED
    _make_doc_tree(tmp_path)
    (tmp_path / "docs" / "invariants.md").unlink()
    assert check_docs.main(tmp_path) == 1
    assert "docs/invariants.md" in capsys.readouterr().err


def test_check_docs_catches_broken_markdown_link(tmp_path, capsys):
    _make_doc_tree(tmp_path)
    (tmp_path / "README.md").write_text(
        "see [the gone doc](docs/missing.md)\n", encoding="utf-8"
    )
    assert check_docs.main(tmp_path) == 1
    assert "broken link -> docs/missing.md" in capsys.readouterr().err
    # anchors and external links are not treated as file targets
    (tmp_path / "README.md").write_text(
        "see [acct](docs/accounting.md#totals) and "
        "[paper](https://example.com/x) and [top](#top)\n",
        encoding="utf-8",
    )
    assert check_docs.main(tmp_path) == 0


def test_check_docs_skips_quoted_exemplar_files(tmp_path):
    _make_doc_tree(tmp_path)
    (tmp_path / "SNIPPETS.md").write_text(
        "[external tree](some/other/repo/file.py)\n", encoding="utf-8"
    )
    assert check_docs.main(tmp_path) == 0


def test_check_bench_and_docs_cli_entrypoints():
    import subprocess

    for script in ("tools/check_bench.py", "tools/check_docs.py"):
        res = subprocess.run(
            [sys.executable, script], cwd=REPO, capture_output=True, text=True
        )
        assert res.returncode == 0, (script, res.stdout, res.stderr)
        assert "0 problem(s)" in res.stdout


def test_repro_lint_cli_entrypoint():
    import subprocess

    res = subprocess.run(
        [sys.executable, "-m", "tools.analysis", "src/", "benchmarks/"],
        cwd=REPO,
        capture_output=True,
        text=True,
    )
    assert res.returncode == 0, (res.stdout, res.stderr)
    assert "0 problem(s)" in res.stdout
