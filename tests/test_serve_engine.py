"""Continuous-batching decode engine: admission under block-pool
pressure, lane-isolation (batched ≡ solo greedy streams), shed→resume
token identity, throughput-tracker feeding, and the int8 paged-path
dequant-scoping bugfix pinned bitwise."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShardingLayout, get_arch
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.serve import DecodeEngine, Request

PROMPT_LENS = (5, 17, 9, 30)
NEW = 6


@pytest.fixture(scope="module")
def served():
    """One batched run under page pressure, plus everything needed to
    re-serve the same requests solo."""
    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    layout = ShardingLayout()
    mesh = make_host_mesh(model_parallel=1)
    params = jax.device_put(model.init(jax.random.key(0)))
    rng = np.random.RandomState(0)
    prompts = [
        rng.randint(0, cfg.vocab_size, n).astype(np.int32) for n in PROMPT_LENS
    ]
    reqs = [
        Request(rid=i, prompt=p, max_new_tokens=NEW)
        for i, p in enumerate(prompts)
    ]
    # pool holds ~2 requests at a time: admission must stagger
    eng = DecodeEngine(model, layout, mesh, lanes=2, num_pages=7, max_context=48)
    for r in reqs:
        eng.submit(r)
    done = eng.run(params)
    return cfg, model, layout, mesh, params, reqs, eng, done


def test_engine_serves_all_requests_under_page_pressure(served):
    *_, reqs, eng, done = served
    assert sorted(c.rid for c in done) == [r.rid for r in reqs]
    assert all(len(c.tokens) == NEW for c in done)
    assert all(c.reason == "length" for c in done)
    # every reserved page came back to the pool at drain
    assert eng.in_flight == 0
    assert eng.free_pages == 7 - 1  # all but the reserved trash page
    assert eng.measured_tokens_per_sec > 0


def test_engine_batched_matches_solo_streams(served):
    """Continuous batching must not leak state across lanes: each request
    decoded alone produces the same greedy stream as the contended run."""
    cfg, model, layout, mesh, params, reqs, _, done = served
    by_rid = {c.rid: c for c in done}
    for r in reqs[:2]:
        solo = DecodeEngine(
            model, layout, mesh, lanes=1, num_pages=4, max_context=48
        )
        solo.submit(Request(rid=r.rid, prompt=r.prompt, max_new_tokens=NEW))
        (sd,) = solo.run(params)
        assert sd.tokens == by_rid[r.rid].tokens, r.rid


def test_engine_shed_resume_token_identical(served):
    """Evicting mid-stream (spot revocation) and resuming on a fresh
    engine replays to the exact uninterrupted stream — the engine-level
    form of the --plan round-trip guarantee."""
    cfg, model, layout, mesh, params, reqs, _, done = served
    by_rid = {c.rid: c for c in done}
    eng1 = DecodeEngine(model, layout, mesh, lanes=2, num_pages=9, max_context=48)
    for r in reqs[:2]:
        eng1.submit(r)
    for _ in range(3):
        eng1.step(params)
    resumed = eng1.shed()
    assert {q.rid for q in resumed} == {0, 1}
    assert all(len(q.resume_tokens) > 0 for q in resumed)
    assert not eng1.completions
    eng2 = DecodeEngine(model, layout, mesh, lanes=2, num_pages=9, max_context=48)
    for q in resumed:
        eng2.submit(q)
    for c in eng2.run(params):
        assert c.tokens == by_rid[c.rid].tokens, c.rid


def test_engine_scale_down_drain_token_identical(served):
    """The autoscaler's scale-down path: ``drain_replica`` sheds every
    in-flight stream from the retiring engine and resubmits on a
    survivor that is already serving its own traffic — every stream,
    moved or resident, completes token-identically to uninterrupted
    serving. A scale-down is as invisible as a revocation."""
    from repro.serve import drain_replica

    cfg, model, layout, mesh, params, reqs, _, done = served
    by_rid = {c.rid: c for c in done}
    retiring = DecodeEngine(model, layout, mesh, lanes=2, num_pages=9, max_context=48)
    survivor = DecodeEngine(model, layout, mesh, lanes=2, num_pages=9, max_context=48)
    for r in reqs[:2]:
        retiring.submit(r)
    survivor.submit(reqs[2])
    for _ in range(3):
        retiring.step(params)
    n = drain_replica(retiring, survivor)
    assert n == 2
    assert not retiring.completions and retiring.occupancy == 0.0
    for c in survivor.run(params):
        assert c.tokens == by_rid[c.rid].tokens, c.rid
    assert {c.rid for c in survivor.completions} == {0, 1, 2}


def test_engine_occupancy_and_page_pool_under_drain(served):
    """The drain telemetry triple: before a scale-down the retiring engine
    holds lanes and pages, during the drain every shed event carries
    enough to re-prefill the stream elsewhere, and after it both gauges
    read exactly zero — with the recorded gauge series agreeing with the
    engine properties at every sample."""
    from repro.obs import events as E
    from repro.obs.recorder import recording
    from repro.serve import drain_replica

    cfg, model, layout, mesh, params, reqs, *_ = served
    with recording() as rec:
        retiring = DecodeEngine(
            model, layout, mesh, lanes=2, num_pages=9, max_context=48
        )
        survivor = DecodeEngine(
            model, layout, mesh, lanes=2, num_pages=9, max_context=48
        )
        for r in reqs[:2]:
            retiring.submit(r)
        for _ in range(3):
            retiring.step(params)

        # before: both lanes live, pages reserved up front for both streams
        assert retiring.occupancy == 1.0
        assert retiring.page_pool_used_frac > 0.0
        occ_before = retiring.occupancy
        pool_before = retiring.page_pool_used_frac

        moved = drain_replica(retiring, survivor)
        assert moved == 2

        # after: the retiring engine is empty on BOTH axes — every lane
        # free and every reserved page back in the pool
        assert retiring.occupancy == 0.0
        assert retiring.page_pool_used_frac == 0.0

    sheds = [e for e in rec.events if isinstance(e, E.Shed)]
    evicts = [e for e in rec.events if isinstance(e, E.Evict)]
    drains = [e for e in rec.events if isinstance(e, E.Drain)]
    assert len(sheds) == 2 and len(drains) == 1
    assert drains[0].moved_requests == 2
    assert all(e.reason == "shed" for e in evicts)
    # during: each shed event carries what re-prefilling needs — the
    # prompt length and the committed tokens (prompt + resume[:-1] is the
    # re-prefill; resume[-1] rides the next decode step)
    by_rid = {r.rid: r for r in reqs}
    for s in sheds:
        assert s.prompt_tokens == len(by_rid[s.request_id].prompt)
        # prefill's argmax token + one per decode step
        assert s.resume_tokens == 4
        total = s.prompt_tokens + s.resume_tokens + by_rid[s.request_id].max_new_tokens
        assert total <= 48  # re-prefill still fits the survivor's context

    # the gauge series brackets the drain: a sample at admission matching
    # the pre-drain properties, and a final sample at zero/zero
    occ = rec.gauge_series["engine.occupancy"]
    pool = rec.gauge_series["engine.page_pool_used_frac"]
    assert occ[0][1] == 0.5 and occ[-1][1] == 0.0
    # second sample: both streams admitted — matches the pre-drain state
    assert (occ[1][1], pool[1][1]) == (occ_before, pool_before)
    assert pool[-1][1] == 0.0
    assert rec.gauge_values["engine.occupancy"] == 0.0
    assert rec.gauge_values["engine.page_pool_used_frac"] == 0.0


def test_engine_occupancy_tracks_live_lanes(served):
    cfg, model, layout, mesh, params, reqs, *_ = served
    eng = DecodeEngine(model, layout, mesh, lanes=2, num_pages=9, max_context=48)
    assert eng.occupancy == 0.0
    eng.submit(reqs[0])
    eng.step(params)
    assert eng.occupancy == 0.5
    eng.run(params)
    assert eng.occupancy == 0.0


def test_engine_feeds_throughput_tracker(served):
    cfg, model, layout, mesh, params, reqs, *_ = served
    from repro.dist.meshplan import ThroughputTracker

    tracker = ThroughputTracker()
    eng = DecodeEngine(
        model, layout, mesh, lanes=2, num_pages=9, max_context=48,
        tracker=tracker, tracker_key="1x1",
    )
    for r in reqs[:2]:
        eng.submit(r)
    eng.run(params)
    # one observation per decode batch step, real wall-clock rates; the
    # measured steps/sec for this shape anchors fleet rate corrections
    assert tracker._sps.get("1x1", 0.0) > 0.0
    assert eng.measured_tokens_per_sec > 0.0


def test_paged_int8_scoped_dequant_pins_dense_fallback_bitwise():
    """The bugfix: the paged int8 path dequantizes ONLY the gathered
    pages. That scoping must be invisible — byte-identical attention
    output to the dense fallback that dequantizes the entire pool before
    the same gather."""
    import dataclasses

    from repro.models import layers
    from repro.models.common import init_params

    cfg = dataclasses.replace(get_arch("qwen3-4b").reduced(), num_layers=1)
    params = init_params(layers.attention_spec(cfg), jax.random.key(0))
    B, nb, ps = 2, 3, layers.PAGE_SIZE
    P = B * nb + 1
    KVH, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    key = jax.random.key(7)
    kq, ks = layers._quantize_kv(
        jax.random.normal(key, (P, ps, KVH, hd), jnp.bfloat16)
    )
    vq, vs = layers._quantize_kv(
        jax.random.normal(jax.random.fold_in(key, 1), (P, ps, KVH, hd), jnp.bfloat16)
    )
    cache = {"k_pages": kq, "v_pages": vq, "k_scale": ks, "v_scale": vs}
    table = jnp.asarray([[0, 1, 2], [3, 4, -1]], jnp.int32)
    lens = jnp.asarray([40, 21], jnp.int32)
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, cfg.d_model), jnp.bfloat16)

    y_scoped, nc = layers.decode_attention_paged(params, cache, x, lens, table, cfg)
    assert nc["k_pages"].dtype == jnp.int8

    # dense fallback: dequantize the WHOLE pool, then the identical
    # gather + masked attention the shipped path runs
    q, _, _ = layers._project_qkv(params, x, x, cfg)
    q = layers.rope(q, lens[:, None].astype(jnp.float32), cfg.rope_theta)
    full_k = layers._dequantize_kv(nc["k_pages"], nc["k_scale"], x.dtype)
    full_v = layers._dequantize_kv(nc["v_pages"], nc["v_scale"], x.dtype)
    tbl = jnp.maximum(table, 0)
    kg = jnp.take(full_k, tbl, axis=0).reshape(B, nb * ps, KVH, hd)
    vg = jnp.take(full_v, tbl, axis=0).reshape(B, nb * ps, KVH, hd)
    from repro.models import common

    att = layers._paged_attend_gathered(q[:, 0], kg, vg, lens + 1)
    att = att.reshape(B, 1, cfg.num_heads * hd)
    y_full = common.dense(att, params["wo"], cfg.dtype)

    a = np.asarray(y_scoped, np.float32)
    b = np.asarray(y_full, np.float32)
    assert np.array_equal(a, b), np.abs(a - b).max()
