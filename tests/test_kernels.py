"""Pallas kernel correctness: shape/dtype sweeps against the pure-jnp
oracles, in interpret mode (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.mlstm import mlstm_chunkwise, mlstm_ref
from repro.kernels.ssm_scan import ssm_scan, ssm_scan_ref

KEY = jax.random.key(0)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # B, S, H, KVH, hd, causal, window, dtype
    (2, 256, 4, 4, 64, True, 0, jnp.float32),
    (1, 256, 8, 2, 64, True, 0, jnp.float32),     # GQA 4:1
    (2, 128, 4, 1, 32, True, 64, jnp.float32),    # MQA + sliding window
    (1, 384, 4, 4, 128, True, 0, jnp.float32),    # ragged (pad path)
    (1, 256, 4, 2, 64, True, 0, jnp.bfloat16),
    (2, 128, 2, 2, 128, True, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,H,KVH,hd,causal,window,dtype", FLASH_CASES)
def test_flash_attention_matches_oracle(B, S, H, KVH, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, S * H + hd + window), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KVH, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KVH, hd), dtype)
    out = flash_attention(q, k, v, causal, window, 0, 128, 128, True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


FLASH_BWD_CASES = [
    # B, S, H, KVH, hd, window — backward PALLAS kernels vs jax.grad(oracle)
    (1, 128, 2, 2, 32, 0),
    (1, 128, 4, 2, 32, 0),      # GQA: dk/dv accumulate over the group dim
    (1, 128, 4, 1, 64, 32),     # MQA + sliding window
    (1, 192, 2, 2, 32, 0),      # ragged (pad path): inert pad rows
]


@pytest.mark.parametrize("B,S,H,KVH,hd,window", FLASH_BWD_CASES)
def test_flash_attention_bwd_kernels_match_oracle_grad(B, S, H, KVH, hd, window):
    ks = jax.random.split(jax.random.fold_in(KEY, 77 + S + H + window), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KVH, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KVH, hd), jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, window, 0, 64, 64, True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(
            attention_ref(q, k, v, causal=True, window=window).astype(jnp.float32) ** 2
        )

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip(("dq", "dk", "dv"), gk, gr):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4, err_msg=name
        )


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

SSM_CASES = [
    (2, 128, 256, 16, 32, jnp.float32),
    (1, 96, 128, 8, 64, jnp.float32),    # ragged seq (pad path)
    (2, 64, 512, 16, 16, jnp.float32),
    (1, 128, 256, 16, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("B,S,inner,N,chunk,dtype", SSM_CASES)
def test_ssm_scan_matches_oracle(B, S, inner, N, chunk, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, S * inner + N), 6)
    u = jax.random.normal(ks[0], (B, S, inner), dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (B, S, inner))) * 0.1).astype(dtype)
    B_ = jax.random.normal(ks[2], (B, S, N), dtype)
    C_ = jax.random.normal(ks[3], (B, S, N), dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (inner, N)) * 0.5)
    D = jax.random.normal(ks[5], (inner,))
    h0 = jax.random.normal(jax.random.fold_in(KEY, 9), (B, inner, N))
    y, h = ssm_scan(u, dt, B_, C_, A, D, h0, chunk=chunk, interpret=True)
    yr, hr = ssm_scan_ref(u, dt, B_, C_, A, D, h0)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), **tol(dtype)
    )
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

MLSTM_CASES = [
    (2, 2, 128, 64, 32, jnp.float32),
    (1, 4, 64, 32, 64, jnp.float32),     # single chunk
    (2, 1, 96, 128, 16, jnp.float32),    # hd 128, odd chunk count
    (1, 2, 128, 64, 64, jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,S,hd,chunk,dtype", MLSTM_CASES)
def test_mlstm_matches_oracle(B, H, S, hd, chunk, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, S * hd + chunk), 4)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, H, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, H, S, hd), dtype)
    g = (jax.random.normal(ks[3], (B, H, S, 2)) * 2.0).astype(dtype)
    h, (C, n, m) = mlstm_chunkwise(q, k, v, g, chunk=chunk, interpret=True)
    hr, (Cr, nr, mr) = mlstm_ref(q, k, v, g)
    np.testing.assert_allclose(
        np.asarray(h, np.float32), np.asarray(hr, np.float32), **tol(dtype)
    )
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), atol=1e-3, rtol=1e-3)


def test_mlstm_state_carry_composes():
    """Running two chunks separately == running them jointly (state carry)."""
    B, H, S, hd = 1, 2, 64, 32
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    g = jax.random.normal(ks[3], (B, H, S, 2))
    _, joint = mlstm_ref(q, k, v, g)
    _, st = mlstm_ref(q[:, :, :32], k[:, :, :32], v[:, :, :32], g[:, :, :32])
    _, split = mlstm_ref(q[:, :, 32:], k[:, :, 32:], v[:, :, 32:], g[:, :, 32:], state=st)
    for a, b in zip(joint, split):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------

from repro.kernels.paged_attention import paged_attention_ref, paged_decode_attention  # noqa: E402


def _paged_case(B, H, KVH, hd, page_size, max_blocks, lens, dtype, seed=0):
    """Random pool + a block table that scatters each sequence's pages
    non-contiguously (the pool is shared — physical page order must not
    matter), with unassigned tail entries left at -1."""
    rng = np.random.RandomState(seed)
    num_pages = B * max_blocks + 1  # +1: a never-referenced spare page
    ks = jax.random.split(jax.random.fold_in(KEY, seed + B * hd), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k_pages = jax.random.normal(ks[1], (num_pages, page_size, KVH, hd), dtype)
    v_pages = jax.random.normal(ks[2], (num_pages, page_size, KVH, hd), dtype)
    perm = rng.permutation(B * max_blocks)
    table = np.full((B, max_blocks), -1, np.int32)
    for b, n in enumerate(lens):
        used = -(-n // page_size)  # ceil
        table[b, :used] = perm[b * max_blocks: b * max_blocks + used]
    return q, k_pages, v_pages, jnp.asarray(table), jnp.asarray(np.asarray(lens, np.int32))


PAGED_CASES = [
    # B, H, KVH, hd, page_size, max_blocks, lens, dtype
    (2, 4, 4, 64, 16, 4, [64, 33], jnp.float32),
    (3, 8, 2, 64, 16, 4, [1, 50, 64], jnp.float32),   # GQA 4:1, len-1 lane
    (2, 4, 1, 32, 8, 6, [41, 17], jnp.float32),       # MQA, ragged pages
    (2, 4, 2, 64, 16, 4, [64, 7], jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,KVH,hd,ps,mb,lens,dtype", PAGED_CASES)
def test_paged_attention_kernel_matches_ref(B, H, KVH, hd, ps, mb, lens, dtype):
    q, kp, vp, table, sl = _paged_case(B, H, KVH, hd, ps, mb, lens, dtype)
    out = paged_decode_attention(q, kp, vp, table, sl, interpret=True)
    ref = paged_attention_ref(q, kp, vp, table, sl)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


def test_paged_attention_ref_matches_dense_sdpa():
    """The paged oracle itself against plain masked attention on the
    gathered, densified cache — the ref is only a layout change."""
    B, H, KVH, hd, ps, mb = 2, 4, 2, 64, 16, 4
    lens = [37, 64]
    q, kp, vp, table, sl = _paged_case(B, H, KVH, hd, ps, mb, lens, jnp.float32)
    out = paged_attention_ref(q, kp, vp, table, sl)

    G = H // KVH
    k = jnp.take(kp, jnp.maximum(table, 0), axis=0).reshape(B, mb * ps, KVH, hd)
    v = jnp.take(vp, jnp.maximum(table, 0), axis=0).reshape(B, mb * ps, KVH, hd)
    qg = q.reshape(B, KVH, G, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k) / np.sqrt(hd)
    mask = jnp.arange(mb * ps)[None, None, None, :] < sl[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    ref = jnp.einsum("bkgt,btkd->bkgd", jax.nn.softmax(s, axis=-1), v)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.reshape(B, H, hd)), atol=1e-5, rtol=1e-5
    )


def test_paged_attention_dead_lane_is_zero_and_isolated():
    """seq_len 0 lanes finalize to exactly zero and never perturb live
    lanes — the engine parks evicted lanes on the trash page and relies on
    this."""
    B, H, KVH, hd, ps, mb = 3, 4, 2, 32, 16, 3
    q, kp, vp, table, sl = _paged_case(B, H, KVH, hd, ps, mb, [40, 17, 25], jnp.float32)
    dead_sl = sl.at[1].set(0)
    out = paged_decode_attention(q, kp, vp, table, dead_sl, interpret=True)
    ref = paged_attention_ref(q, kp, vp, table, dead_sl)
    assert np.all(np.asarray(out[1]) == 0.0)
    assert np.all(np.asarray(ref[1]) == 0.0)
    # live lanes unchanged vs the all-live run
    full = paged_decode_attention(q, kp, vp, table, sl, interpret=True)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(full[0]), atol=0, rtol=0)
    np.testing.assert_allclose(np.asarray(out[2]), np.asarray(full[2]), atol=0, rtol=0)
