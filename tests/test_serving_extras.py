"""Serving-path extras: int8 KV-cache correctness, ring-buffer windows,
decode-unroll equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch
from repro.models import build_model
from repro.models.transformer import RunOpts


@pytest.fixture(scope="module")
def dense():
    cfg = get_arch("qwen3-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _prefill_decode(model, params, tokens, opts, S):
    _, cache = model.prefill(params, {"tokens": tokens[:, :S]}, S + 4, opts)
    logits = []
    for i in range(3):
        lg, cache = model.decode_step(
            params, cache, tokens[:, S + i : S + i + 1], jnp.int32(S + i), opts
        )
        logits.append(np.asarray(lg[:, 0], np.float32))
    return logits


def test_int8_cache_matches_bf16_topk(dense):
    cfg, model, params = dense
    S = 16
    tokens = jax.random.randint(jax.random.key(5), (2, S + 4), 0, cfg.vocab_size, jnp.int32)
    ref = _prefill_decode(model, params, tokens, RunOpts(), S)
    q = _prefill_decode(model, params, tokens, RunOpts(int8_kv_cache=True), S)
    for a, b in zip(ref, q):
        # int8 quantization noise must not change the decisions materially
        assert np.argmax(a) == np.argmax(b) or np.corrcoef(a.ravel(), b.ravel())[0, 1] > 0.98


def test_decode_unroll_matches_scan(dense):
    cfg, model, params = dense
    S = 12
    tokens = jax.random.randint(jax.random.key(6), (1, S + 4), 0, cfg.vocab_size, jnp.int32)
    a = _prefill_decode(model, params, tokens, RunOpts(decode_unroll=False), S)
    b = _prefill_decode(model, params, tokens, RunOpts(decode_unroll=True), S)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, atol=2e-2, rtol=2e-2)


def test_sliding_window_single_layer_evicts():
    """Single attention layer: a KV slot whose position left the window must
    not influence the decode output (ring-buffer masking)."""
    import dataclasses

    from repro.models import layers
    from repro.models.common import init_params

    cfg = dataclasses.replace(
        get_arch("mixtral-8x7b").reduced(), num_layers=1, window=4
    )
    params = init_params(layers.attention_spec(cfg), jax.random.key(0))
    B, T, KVH, hd = 1, 8, cfg.num_kv_heads, cfg.resolved_head_dim

    key = jax.random.key(1)
    cache = {
        "k": jax.random.normal(key, (B, T, KVH, hd), jnp.bfloat16),
        "v": jax.random.normal(jax.random.fold_in(key, 1), (B, T, KVH, hd), jnp.bfloat16),
        "pos_ids": jnp.arange(T, dtype=jnp.int32),  # positions 0..7 resident
    }
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, cfg.d_model), jnp.bfloat16)
    pos = jnp.int32(8)  # new token at position 8: window covers 5..8 only

    y1, _ = layers.decode_attention(params, cache, x, pos, cfg)
    # clobber slots holding positions 1 and 2 (evicted: 8 - pos >= window 4)
    cache2 = dict(cache)
    cache2["k"] = cache["k"].at[:, 1:3].set(99.0)
    cache2["v"] = cache["v"].at[:, 1:3].set(-99.0)
    y2, _ = layers.decode_attention(params, cache2, x, pos, cfg)
    np.testing.assert_allclose(
        np.asarray(y1, np.float32), np.asarray(y2, np.float32), atol=1e-6
    )
    # ...while a slot INSIDE the window does change the output
    cache3 = dict(cache)
    cache3["v"] = cache["v"].at[:, 6].set(-99.0)
    y3, _ = layers.decode_attention(params, cache3, x, pos, cfg)
    assert np.abs(np.asarray(y1, np.float32) - np.asarray(y3, np.float32)).max() > 1e-3
