"""Beyond-paper portfolio provisioning: chain properties + volatile-regime
comparison vs pure Algorithm 1 (deterministic seeds)."""
import numpy as np
import pytest

from repro.core import Job, Simulator, SiwoftPolicy, generate_markets, split_history_future
from repro.core import provisioner as alg
from repro.core.portfolio import (
    PortfolioPolicy,
    max_chain_correlation,
    portfolio_failover_order,
    select_portfolio,
)


@pytest.fixture(scope="module")
def volatile_sims():
    sims = []
    for seed in range(8):
        ms = generate_markets(
            seed=100 + seed, n_hours=24 * 150, rare_market_fraction=0.0
        )
        hist, fut = split_history_future(ms, 24 * 90)
        sims.append(Simulator(hist, fut, seed=seed))
    return sims


def test_chain_has_requested_size_and_admissible_markets(volatile_sims):
    sim = volatile_sims[0]
    job = Job(24, 16)
    policy = PortfolioPolicy(size=4)
    chain = select_portfolio(job, sim.feats, policy)
    assert len(chain) == 4
    assert len(set(chain)) == 4
    suitable = set(alg.find_suitable_servers(job, sim.feats))
    assert set(chain) <= suitable


def test_chain_diversity_no_worse_than_naive(volatile_sims):
    """Greedy diversification never yields a MORE correlated prefix than the
    naive MTTR ordering."""
    job = Job(48, 16)
    policy = PortfolioPolicy(size=4)
    for sim in volatile_sims:
        feats = sim.feats
        suitable = alg.find_suitable_servers(job, feats)
        lifetimes = alg.compute_lifetime(feats, suitable)
        naive = alg.server_based_lifetime(job, lifetimes, SiwoftPolicy(), feats)[:4]
        chain = select_portfolio(job, feats, policy)
        assert max_chain_correlation(feats, chain) <= max_chain_correlation(feats, naive) + 1e-9


def test_failover_order_covers_all_suitable(volatile_sims):
    sim = volatile_sims[0]
    job = Job(24, 16)
    order = portfolio_failover_order(job, sim.feats, PortfolioPolicy())
    assert sorted(order) == sorted(alg.find_suitable_servers(job, sim.feats))


def test_portfolio_cheaper_in_volatile_regime(volatile_sims):
    """With no rare markets (the paper's premise broken), price-aware
    diversification beats pure MTTR ordering on mean cost."""
    job = Job(48, 16)
    c_s, c_p = [], []
    for sim in volatile_sims:
        c_s.append(sim.run_job(job, SiwoftPolicy()).total_cost)
        c_p.append(sim.run_job(job, PortfolioPolicy()).total_cost)
    assert np.mean(c_p) < np.mean(c_s)


def test_portfolio_equivalent_in_calm_regime():
    """With rare markets available (the paper's regime), both policies
    complete without revocation at comparable cost."""
    ms = generate_markets(seed=0, n_hours=24 * 150)
    hist, fut = split_history_future(ms, 24 * 90)
    sim = Simulator(hist, fut, seed=0)
    job = Job(24, 16)
    a = sim.run_job(job, SiwoftPolicy())
    b = sim.run_job(job, PortfolioPolicy())
    assert a.revocations == 0 and b.revocations == 0
    assert abs(a.total_cost - b.total_cost) / a.total_cost < 0.35
